"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Heavy shared setup (the trained
SCOPE estimator) is cached under benchmarks/_cache.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only routing,tokens
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    bench_ablation, bench_adaptation, bench_budget, bench_kernels,
    bench_pareto, bench_portfolio, bench_predictive, bench_roofline,
    bench_routing, bench_serve_latency, bench_serve_throughput, bench_tokens)

BENCHES = {
    "routing": bench_routing,          # Table 1
    "predictive": bench_predictive,    # Table 2
    "pareto": bench_pareto,            # Fig. 4 / 6 / 13
    "portfolio": bench_portfolio,      # Fig. 5 / 14
    "ablation": bench_ablation,        # Fig. 7
    "budget": bench_budget,            # Fig. 8 / App. D
    "tokens": bench_tokens,            # Fig. 9 / App. E
    "adaptation": bench_adaptation,    # App. F
    "kernels": bench_kernels,          # kernel latency
    "roofline": bench_roofline,        # §Roofline (from dry-run artifacts)
    "serve_latency": bench_serve_latency,  # serve-path p50/p95 + transfer
    "serve_throughput": bench_serve_throughput,  # streaming q/s + recompiles
}

NEEDS_BUNDLE = {"routing", "predictive", "pareto", "portfolio", "ablation",
                "budget", "tokens", "adaptation", "serve_latency",
                "serve_throughput"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args(argv)
    names = list(BENCHES) if not args.only else args.only.split(",")

    bundle = None
    if any(n in NEEDS_BUNDLE for n in names):
        from benchmarks.common import get_bundle
        t0 = time.time()
        bundle = get_bundle()
        print(f"# bundle ready in {time.time()-t0:.0f}s", file=sys.stderr)

    print("name,us_per_call,derived")
    failed = 0
    for n in names:
        mod = BENCHES[n]
        try:
            t0 = time.time()
            rows = mod.run(bundle)
            for name, us, derived in rows:
                print(f"{name},{us:.2f},{derived}")
            print(f"# {n} done in {time.time()-t0:.0f}s", file=sys.stderr)
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"{n},0.00,EXCEPTION", flush=True)
            traceback.print_exc()
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
