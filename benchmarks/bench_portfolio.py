"""Fig. 5 / 14: the adaptive model portfolio as alpha varies."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from benchmarks.common import Bundle, pool_predictions_cached, route_alpha
from repro.core.evaluation import evaluate_choices


def run(bundle: Bundle) -> List[Tuple[str, float, str]]:
    rows = []
    for ood in (False, True):
        tag = "ood" if ood else "test"
        engine, pool, qids, data, models = pool_predictions_cached(
            bundle, ood=ood)
        for a in (0.0, 0.5, 1.0):
            ch = route_alpha(engine, pool, a)
            ev = evaluate_choices(data, qids, models, ch)
            top = sorted(ev.per_model_share.items(), key=lambda kv: -kv[1])
            desc = ";".join(f"{m}={v:.2f}" for m, v in top[:3] if v > 0)
            rows.append((f"portfolio/{tag}/alpha{a:g}", 0.0, desc))
        # cheap-model dominance at alpha=0, diversification at alpha=1
        ch0 = route_alpha(engine, pool, 0.0)
        ch1 = route_alpha(engine, pool, 1.0)
        ev0 = evaluate_choices(data, qids, models, ch0)
        ev1 = evaluate_choices(data, qids, models, ch1)
        ent = lambda sh: float(-sum(v * np.log(v + 1e-12)
                                    for v in sh.values() if v > 0))
        rows.append((f"portfolio/{tag}/entropy", 0.0,
                     f"alpha0={ent(ev0.per_model_share):.2f};"
                     f"alpha1={ent(ev1.per_model_share):.2f}"))
    return rows
