"""Table 1: routing performance (PGR / Avg-A / Cost) on Test and OOD sets,
SCOPE at alpha in {0, 0.6, 1.0} vs baselines."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks.common import Bundle, pool_predictions_cached, route_alpha
from repro.core.baselines import (
    KNNRouter, LinearSVMRouter, MLPRouter, oracle_labels, random_choices)
from repro.core.evaluation import evaluate_choices


def _fit_supervised(bundle: Bundle, data, models, train_qids):
    world = bundle.world
    embs = np.stack([world.embed(data.queries[int(q)]) for q in train_qids])
    labels = oracle_labels(data, train_qids, models)
    routers = {}
    for name, r in (("knn_router", KNNRouter(k=8)),
                    ("mlp_router", MLPRouter(steps=300)),
                    ("svm_router", LinearSVMRouter(steps=300))):
        r.fit(embs, labels, len(models))
        routers[name] = r
    return routers


def run(bundle: Bundle) -> List[Tuple[str, float, str]]:
    rows = []
    for ood in (False, True):
        tag = "ood" if ood else "test"
        engine, pool, qids, data, models = pool_predictions_cached(
            bundle, ood=ood)
        world = bundle.world
        Q = len(qids)

        def emit(name, choices, dt_us):
            ev = evaluate_choices(data, qids, models, choices)
            rows.append((f"routing/{tag}/{name}", dt_us,
                         f"pgr={ev.pgr:.3f};acc={ev.avg_acc:.3f};"
                         f"cost={ev.total_cost:.4f}"))

        # static baselines
        emit("random", random_choices(Q, len(models), seed=1), 0.0)
        prices = [world.models[m].price_out for m in models]
        emit("cheapest", np.full(Q, int(np.argmin(prices))), 0.0)
        emit("most_expensive", np.full(Q, int(np.argmax(prices))), 0.0)

        # supervised baselines: trained on train split (test) or anchors (ood)
        if ood:
            # retrain on anchor-set-sized data from the OOD pool (paper's
            # adaptation protocol for baselines)
            train_q = data.train_qids[:200]
        else:
            train_q = data.train_qids
        sup = _fit_supervised(bundle, data, models, train_q)
        test_embs = np.stack([world.embed(data.queries[int(q)])
                              for q in qids])
        for name, r in sup.items():
            t0 = time.perf_counter()
            ch = r.predict(test_embs)
            emit(name, ch, (time.perf_counter() - t0) / Q * 1e6)

        # SCOPE at the paper's three alphas
        for alpha in (0.0, 0.6, 1.0):
            t0 = time.perf_counter()
            ch = route_alpha(engine, pool, alpha)
            dt = (time.perf_counter() - t0) / Q * 1e6
            emit(f"scope_alpha{alpha:g}", ch, dt)

        # prediction-cache hot path: cold vs warm predict_pool through the
        # repro.api engine (warm run never touches the estimator)
        from repro.api import RouteRequest
        cache_engine = bundle.engine(models)
        queries = [data.queries[int(q)] for q in qids]
        req = RouteRequest(queries)
        t0 = time.perf_counter()
        cold = cache_engine.predict(req)
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm = cache_engine.predict(req)
        t_warm = time.perf_counter() - t0
        assert warm.cache_misses == 0 and warm.cache_hits == cold.cache_misses
        rows.append((f"routing/{tag}/predict_cache",
                     t_warm / Q * 1e6,
                     f"cold_ms={t_cold * 1e3:.1f};warm_ms={t_warm * 1e3:.1f};"
                     f"speedup={t_cold / max(t_warm, 1e-9):.1f}x;"
                     f"pairs={cold.cache_misses}"))
    return rows
