"""Roofline terms per (arch x shape x mesh), read from the dry-run
artifacts (experiments/artifacts/dryrun_*.json).  No devices touched."""
from __future__ import annotations

import json
import os
from typing import List, Tuple

ART = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "artifacts")


def model_flops(arch: str, shape: dict) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) per training step; forward-only
    (2*N*D) for prefill; 2*N_active per token for decode."""
    from repro.configs import get_config, INPUT_SHAPES
    cfg = get_config(arch)
    import numpy as np
    import jax
    from repro.launch import specs as S
    params = S.abstract_params(cfg)
    n_total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    if cfg.has_moe():
        # active params: replace expert count by k experts (+shared)
        dense_frac_per_layer = (cfg.num_experts_per_tok
                                + cfg.num_shared_experts) / max(
            cfg.num_experts + cfg.num_shared_experts, 1)
        expert_params = (cfg.num_experts * 3 * cfg.d_model
                         * cfg.resolved_moe_d_ff * cfg.num_layers)
        n_active = n_total - expert_params * (1 - dense_frac_per_layer)
    else:
        n_active = n_total
    sh = INPUT_SHAPES[shape["shape"]]
    if sh.mode == "train":
        return 6.0 * n_active * sh.seq_len * sh.global_batch
    if sh.mode == "prefill":
        return 2.0 * n_active * sh.seq_len * sh.global_batch
    return 2.0 * n_active * sh.global_batch          # one token / seq


def run(bundle=None) -> List[Tuple[str, float, str]]:
    rows = []
    for mesh_tag, fname in (("16x16", "dryrun_single_pod.json"),
                            ("2x16x16", "dryrun_multi_pod.json")):
        path = os.path.join(ART, fname)
        if not os.path.exists(path):
            rows.append((f"roofline/{mesh_tag}/missing", 0.0,
                         f"run=python -m repro.launch.dryrun --all"))
            continue
        results = json.load(open(path))
        for r in results:
            name = f"roofline/{mesh_tag}/{r['arch']}/{r['shape']}"
            if r["status"] == "skipped":
                rows.append((name, 0.0, f"skipped={r['reason'][:40]}"))
                continue
            if r["status"] != "ok":
                rows.append((name, 0.0, f"FAILED={r.get('error','')[:60]}"))
                continue
            t = r["roofline"]
            mf = model_flops(r["arch"], r)
            nd = r["num_devices"]
            useful = mf / max(r["hlo_flops_per_device"] * nd, 1.0)
            rows.append((
                name, t["compute_s"] * 1e6,
                f"compute_s={t['compute_s']:.4f};memory_s={t['memory_s']:.4f};"
                f"collective_s={t['collective_s']:.4f};"
                f"bottleneck={t['bottleneck'].replace('_s','')};"
                f"model_vs_hlo_flops={useful:.2f}"))
    return rows
