"""Fig. 8 / Appendix D: budget-aware control — given a set-level budget,
SCOPE solves for alpha* (finite breakpoint search) and the realized cost
tracks the budget."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks.common import Bundle, pool_predictions_cached
from repro.api import SetBudgetPolicy
from repro.core.evaluation import evaluate_choices


def run(bundle: Bundle) -> List[Tuple[str, float, str]]:
    rows = []
    engine, pool, qids, data, models = pool_predictions_cached(bundle,
                                                               ood=False)
    min_cost = float(pool.cost_hat.min(axis=1).sum())
    max_cost = float(pool.cost_hat.max(axis=1).sum())
    budgets = np.geomspace(max(min_cost * 1.05, 1e-4), max_cost, 6)
    for b in budgets:
        t0 = time.perf_counter()
        d = engine.decide(pool, SetBudgetPolicy(float(b)))
        alpha, choices, info = d.alpha, d.choices, d.info
        dt_us = (time.perf_counter() - t0) * 1e6
        ev = evaluate_choices(data, qids, models, choices)
        ok = info["expected_cost"] <= b + 1e-9
        rows.append((f"budget/B{b:.3f}", dt_us,
                     f"alpha={alpha:.3f};pred_cost={info['expected_cost']:.4f};"
                     f"within_budget={ok};realized_cost={ev.total_cost:.4f};"
                     f"acc={ev.avg_acc:.3f}"))
    return rows
