"""Fig. 8 / Appendix D: budget-aware control — given a set-level budget,
SCOPE solves for alpha* (finite breakpoint search) and the realized cost
tracks the budget."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks.common import Bundle, pool_predictions_cached
from repro.api import SetBudgetPolicy
from repro.core import alpha_search
from repro.core.evaluation import evaluate_choices


# ---------------------------------------------------------------------------
# Pre-vectorization reference (pinned here for the scaling comparison and
# imported by tests/test_core_scope.py as the parity oracle): the pure-Python
# O(Q*M^2) breakpoint triple loop + per-candidate routing loop that
# SetBudgetPolicy/AccuracyFloorPolicy used to run per serve batch.
# ---------------------------------------------------------------------------
def _breakpoints_loop(p_hat, s_hat):
    Q, M = p_hat.shape
    slopes = p_hat - s_hat
    pts = []
    for q in range(Q):
        for i in range(M):
            di = slopes[q, i]
            for j in range(i + 1, M):
                dj = slopes[q, j]
                if abs(di - dj) < 1e-12:
                    continue
                a = (s_hat[q, j] - s_hat[q, i]) / (di - dj)
                if 0.0 < a < 1.0:
                    pts.append(a)
    return np.asarray(sorted(set(pts)))


def _budget_alpha_loop(p_hat, s_hat, c_hat, budget):
    bps = _breakpoints_loop(p_hat, s_hat)
    grid = np.concatenate([[0.0], bps, [1.0]])
    cands = np.unique(np.concatenate([grid, (grid[:-1] + grid[1:]) / 2.0]))
    best = cheapest = None
    for a in cands:
        choice = alpha_search.route_for_alpha(p_hat, s_hat, a)
        rows = np.arange(len(choice))
        cost = float(np.sum(c_hat[rows, choice]))
        perf = float(np.sum(p_hat[rows, choice]))
        if cheapest is None or cost < cheapest[1]:
            cheapest = (a, cost, perf, choice)
        if cost <= budget and (best is None or perf > best[2]
                               or (perf == best[2] and cost < best[1])):
            best = (a, cost, perf, choice)
    return best if best is not None else cheapest


def _bench_alpha_scaling(pool, repeats: int = 3) -> List[Tuple[str, float, str]]:
    """Policy-path scaling: vectorized vs loop budget search at growing Q."""
    rows = []
    rng = np.random.default_rng(0)
    Qs = (32, 128, 512)
    for Q in Qs:
        take = rng.integers(0, pool.p_hat.shape[0], size=Q)
        p, c = pool.p_hat[take], pool.cost_hat[take]
        s = 1.0 - c / max(c.max(), 1e-12)
        budget = float(c.min(axis=1).sum() * 1.5)
        t0 = time.perf_counter()
        for _ in range(repeats):
            a_vec, _, info = alpha_search.budget_alpha(p, s, c, budget)
        t_vec = (time.perf_counter() - t0) / repeats * 1e6
        if Q <= 128:                       # the loop is why this PR exists
            t0 = time.perf_counter()
            a_loop, _, perf_loop, _ = _budget_alpha_loop(p, s, c, budget)
            t_loop = (time.perf_counter() - t0) * 1e6
            extra = (f";loop_us={t_loop:.0f}"
                     f";speedup={t_loop / max(t_vec, 1e-9):.1f}"
                     f";alpha_delta={abs(a_vec - a_loop):.2e}"
                     f";perf_delta={abs(info['expected_perf'] - perf_loop):.2e}")
        else:
            extra = ";loop_us=skipped"
        rows.append((f"budget/alpha_search_Q{Q}", t_vec,
                     f"candidates={info['num_candidates']}"
                     f";feasible={info['feasible']}{extra}"))
    return rows


def run(bundle: Bundle) -> List[Tuple[str, float, str]]:
    rows = []
    engine, pool, qids, data, models = pool_predictions_cached(bundle,
                                                               ood=False)
    min_cost = float(pool.cost_hat.min(axis=1).sum())
    max_cost = float(pool.cost_hat.max(axis=1).sum())
    budgets = np.geomspace(max(min_cost * 1.05, 1e-4), max_cost, 6)
    for b in budgets:
        t0 = time.perf_counter()
        d = engine.decide(pool, SetBudgetPolicy(float(b)))
        alpha, choices, info = d.alpha, d.choices, d.info
        dt_us = (time.perf_counter() - t0) * 1e6
        ev = evaluate_choices(data, qids, models, choices)
        ok = info["expected_cost"] <= b + 1e-9
        rows.append((f"budget/B{b:.3f}", dt_us,
                     f"alpha={alpha:.3f};pred_cost={info['expected_cost']:.4f};"
                     f"within_budget={ok};realized_cost={ev.total_cost:.4f};"
                     f"acc={ev.avg_acc:.3f}"))
    rows += _bench_alpha_scaling(pool)
    return rows
