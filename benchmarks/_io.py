"""Shared benchmark output helper."""
import json
import os


def write_bench_json(bench_path: str, payload: dict) -> None:
    """Write a bench payload to ``benchmarks/BENCH_*.json`` and mirror it
    to the repo-root ``BENCH_*.json`` — the tracked perf-trajectory
    snapshot."""
    root = os.path.join(os.path.dirname(bench_path), "..",
                        os.path.basename(bench_path))
    for path in (bench_path, root):
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {path}")
