"""Fig. 4 / 6 / 13: SCOPE's accuracy-cost frontier vs every individual
model.  Headline numbers: max accuracy boost at comparable cost (paper:
+24-25.7%) and max cost cut at comparable accuracy (paper: -95.1%)."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from benchmarks.common import Bundle, pool_predictions_cached, route_alpha
from repro.core.evaluation import evaluate_choices

ALPHAS = np.linspace(0.0, 1.0, 11)


def frontier(bundle: Bundle, *, ood: bool):
    engine, pool, qids, data, models = pool_predictions_cached(bundle,
                                                               ood=ood)
    pts = []
    for a in ALPHAS:
        ch = route_alpha(engine, pool, float(a))
        ev = evaluate_choices(data, qids, models, ch)
        pts.append((float(a), ev.avg_acc, ev.total_cost))
    singles = {}
    for mi, m in enumerate(models):
        ev = evaluate_choices(data, qids, models,
                              np.full(len(qids), mi))
        singles[m] = (ev.avg_acc, ev.total_cost)
    return pts, singles


def run(bundle: Bundle) -> List[Tuple[str, float, str]]:
    rows = []
    for ood in (False, True):
        tag = "ood" if ood else "test"
        pts, singles = frontier(bundle, ood=ood)
        accs = np.array([p[1] for p in pts])
        costs = np.array([p[2] for p in pts])

        best_single_acc = max(a for a, _ in singles.values())
        boost = (accs.max() - best_single_acc) / max(best_single_acc, 1e-9)

        # cost cut vs the most expensive single model at >= comparable acc
        exp_model = max(singles, key=lambda m: singles[m][1])
        exp_acc, exp_cost = singles[exp_model]
        ok = accs >= exp_acc - 0.03
        cut = (1.0 - costs[ok].min() / exp_cost) if ok.any() else 0.0

        for a, acc, cost in pts:
            rows.append((f"pareto/{tag}/alpha{a:.1f}", 0.0,
                         f"acc={acc:.3f};cost={cost:.4f}"))
        for m, (acc, cost) in singles.items():
            rows.append((f"pareto/{tag}/single/{m}", 0.0,
                         f"acc={acc:.3f};cost={cost:.4f}"))
        rows.append((f"pareto/{tag}/headline", 0.0,
                     f"acc_boost_vs_best_single={boost*100:.1f}%;"
                     f"cost_cut_vs_{exp_model}={cut*100:.1f}%"))
    return rows
