"""End-to-end serve-path latency: p50/p95 per-query latency and host
transfer, legacy per-token decode loop vs the fused scan pipeline.

Three sections:

  decode  — ``sampler.generate`` (one jitted scan, YES/NO logit pair to
            host) against the pre-fusion reference loop (one jitted
            dispatch per token, full (b, T, V) float32 logits to host)
  predict — ``ScopeEngine.predict`` per query, cold cache (estimator runs)
            and warm cache (pure assembly)
  route   — predict + ``FixedAlphaPolicy`` decide per query

Rows go to stdout CSV (via ``benchmarks.run``) and to
``benchmarks/BENCH_serve_latency.json`` — the start of the BENCH_*.json
trajectory.  Standalone:

  PYTHONPATH=src python benchmarks/bench_serve_latency.py --smoke
"""
from __future__ import annotations

import argparse
import functools
import os
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BENCH_PATH = os.path.join(os.path.dirname(__file__),
                          "BENCH_serve_latency.json")


# ---------------------------------------------------------------------------
# Legacy decode loop (pre-fusion reference, pinned here for the comparison)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnums=(1,), donate_argnums=(3,))
def _legacy_decode_step(params, cfg, token, caches, pos):
    from repro.models import model as M
    logits, caches = M.decode_step(params, cfg, token, caches, pos)
    return logits[:, 0], caches


def legacy_generate(params, cfg, prompts, *, max_new_tokens=12,
                    temperature=0.0, rng=None, stop_at_eos=True):
    """One jitted dispatch per token; full (b, T, V) logits copied to host."""
    from repro.data.tokenizer import EOS, PAD
    from repro.models import model as M
    from repro.serving.sampler import _pad_caches
    prompts = jnp.asarray(prompts, jnp.int32)
    b, lp = prompts.shape
    logits, caches = M.prefill(params, cfg, {"tokens": prompts})
    caches = _pad_caches(caches, lp + max_new_tokens, lp)
    last = logits[:, -1].astype(jnp.float32)
    outs, step_logits = [], []
    done = jnp.zeros((b,), bool)
    key = rng if rng is not None else jax.random.PRNGKey(0)
    for t in range(max_new_tokens):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, last / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last, axis=-1)
        nxt = jnp.where(done, PAD, nxt).astype(jnp.int32)
        outs.append(nxt)
        step_logits.append(last)
        if stop_at_eos:
            done = done | (nxt == EOS)
        last, caches = _legacy_decode_step(params, cfg, nxt[:, None], caches,
                                           lp + t)
        last = last.astype(jnp.float32)
    gen = np.asarray(jnp.stack(outs, axis=1))
    lg = np.asarray(jnp.stack(step_logits, axis=1))
    return gen, lg


# ---------------------------------------------------------------------------
# Measurement helpers
# ---------------------------------------------------------------------------
def _percentiles(times_s: List[float]) -> Dict[str, float]:
    a = np.asarray(times_s, np.float64) * 1e6          # us
    return {"p50_us": float(np.percentile(a, 50)),
            "p95_us": float(np.percentile(a, 95)),
            "mean_us": float(a.mean())}


def _time_calls(fn: Callable[[], None], repeats: int, *,
                warmup: int = 2) -> Dict[str, float]:
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return _percentiles(times)


# ---------------------------------------------------------------------------
# Sections
# ---------------------------------------------------------------------------
def bench_decode(cfg, params, *, batch: int, prompt_len: int,
                 max_new_tokens: int, repeats: int) -> List[Dict]:
    from repro.serving import sampler
    rng = np.random.default_rng(0)
    prompts = rng.integers(3, min(100, cfg.vocab_size),
                           size=(batch, prompt_len)).astype(np.int32)
    bytes_legacy = batch * max_new_tokens * (cfg.vocab_size * 4 + 4)
    bytes_fused = batch * max_new_tokens * (2 * 4 + 4)

    t_old = _time_calls(
        lambda: legacy_generate(params, cfg, prompts,
                                max_new_tokens=max_new_tokens), repeats)
    t_new = _time_calls(
        lambda: sampler.generate(params, cfg, prompts,
                                 max_new_tokens=max_new_tokens), repeats)
    speedup = t_old["p50_us"] / max(t_new["p50_us"], 1e-9)
    per_q = 1.0 / batch
    return [
        {"name": "serve/decode_legacy_loop",
         **{k: v * per_q for k, v in t_old.items()},
         "detail": {"batch": batch, "new_tokens": max_new_tokens,
                    "host_bytes_per_batch": bytes_legacy}},
        {"name": "serve/decode_fused_scan",
         **{k: v * per_q for k, v in t_new.items()},
         "detail": {"batch": batch, "new_tokens": max_new_tokens,
                    "host_bytes_per_batch": bytes_fused,
                    "speedup_vs_legacy": round(speedup, 2),
                    "transfer_cut":
                        round(bytes_legacy / max(bytes_fused, 1), 1)}},
    ]


def bench_predict_route(engine, queries, *, alpha: float = 0.6) -> List[Dict]:
    """Per-query p50/p95 for predict (cold + warm) and route (warm)."""
    from repro.api import FixedAlphaPolicy, RouteRequest
    policy = FixedAlphaPolicy(alpha)
    # warm the jit caches on a throwaway prefix so cold rows measure the
    # serve path, not one-off XLA compilation
    for q in queries[:2]:
        engine.predict(RouteRequest([q]))
    engine.cache.clear()

    cold, warm, route = [], [], []
    for q in queries:
        t0 = time.perf_counter()
        engine.predict(RouteRequest([q]))
        cold.append(time.perf_counter() - t0)
    for q in queries:
        t0 = time.perf_counter()
        engine.predict(RouteRequest([q]))
        warm.append(time.perf_counter() - t0)
    for q in queries:
        t0 = time.perf_counter()
        engine.route(RouteRequest([q]), policy)
        route.append(time.perf_counter() - t0)

    t_cold, t_warm, t_route = (_percentiles(x) for x in (cold, warm, route))
    n_models = len(engine.registry.routable())
    return [
        {"name": "serve/predict_cold", **t_cold,
         "detail": {"models": n_models, "queries": len(queries)}},
        {"name": "serve/predict_warm", **t_warm,
         "detail": {"models": n_models,
                    "speedup_vs_cold":
                        round(t_cold["p50_us"] / max(t_warm["p50_us"], 1e-9),
                              1)}},
        {"name": "serve/route_warm", **t_route,
         "detail": {"policy": "fixed_alpha", "alpha": alpha}},
    ]


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------
def _emit(rows: List[Dict], *, smoke: bool) -> None:
    from benchmarks._io import write_bench_json
    write_bench_json(BENCH_PATH, {
        "bench": "serve_latency", "smoke": smoke,
        "unix_time": int(time.time()), "rows": rows})


def _as_csv_rows(rows: List[Dict]) -> List[Tuple[str, float, str]]:
    out = []
    for r in rows:
        detail = ";".join(f"{k}={v}" for k, v in r["detail"].items())
        out.append((r["name"], r["p50_us"],
                    f"p95_us={r['p95_us']:.1f};{detail}"))
    return out


def run(bundle) -> List[Tuple[str, float, str]]:
    """benchmarks.run entry point: full trained-estimator measurement."""
    rows = bench_decode(bundle.cfg, bundle.params, batch=32, prompt_len=49,
                        max_new_tokens=12, repeats=20)
    engine = bundle.engine(bundle.seen)
    queries = [bundle.data.queries[int(q)]
               for q in bundle.data.test_qids[:32]]
    rows += bench_predict_route(engine, queries)
    _emit(rows, smoke=False)
    return _as_csv_rows(rows)


def _smoke_setup():
    """Tiny untrained world — latency only, no training, CI-sized."""
    from repro.api import EngineConfig, ScopeEngine
    from repro.configs.scope_estimator import TINY
    from repro.core.estimator import ReasoningEstimator
    from repro.core.fingerprint import FingerprintLibrary, build_anchor_set
    from repro.core.retrieval import AnchorRetriever
    from repro.data.datasets import build_scope_data, stratified_anchors
    from repro.data.worldsim import World
    from repro.models import model as M

    world = World(seed=0)
    data = build_scope_data(world, n_queries=240, seed=0)
    aset = build_anchor_set(world, stratified_anchors(world, n=60, seed=7))
    library = FingerprintLibrary(aset)
    for m in data.models:
        library.onboard(world, m, seed=3)
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    engine = ScopeEngine.build(EngineConfig(
        estimator=ReasoningEstimator(TINY, params),
        retriever=AnchorRetriever(aset), library=library,
        models_meta={m: world.models[m] for m in data.models}))
    queries = [data.queries[int(q)] for q in data.test_qids[:12]]
    return TINY, params, engine, queries


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny untrained setup (CI gate), no bundle training")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)

    if args.smoke:
        cfg, params, engine, queries = _smoke_setup()
        repeats = args.repeats or 5
        rows = bench_decode(cfg, params, batch=8, prompt_len=49,
                            max_new_tokens=12, repeats=repeats)
        rows += bench_predict_route(engine, queries)
        _emit(rows, smoke=True)
    else:
        from benchmarks.common import get_bundle
        rows_csv = run(get_bundle())
        for name, us, derived in rows_csv:
            print(f"{name},{us:.2f},{derived}")
        return 0
    print("name,us_per_call,derived")
    for name, us, derived in _as_csv_rows(rows):
        print(f"{name},{us:.2f},{derived}")
    return 0


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    raise SystemExit(main())
