"""Table 2: pre-hoc predictive accuracy (ACC) and token MAE, per category —
SCOPE vs SCOPE_NoCoT vs the untrained base model (5-shot and 0-shot)."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks.common import Bundle
from repro.core import serialization
from repro.core.evaluation import predictive_metrics
from repro.data.worldsim import DOMAINS


def _eval(bundle: Bundle, which: str, *, anchors: int, n_queries: int = 64):
    world, data = bundle.world, bundle.data
    est = bundle.estimator(which)
    qids = data.test_qids[:n_queries]
    queries = [data.queries[int(q)] for q in qids]
    embs = np.stack([world.embed(q) for q in queries])
    sims, idx = bundle.retriever.retrieve(embs, max(anchors, 1))
    if anchors == 0:
        sims = sims[:, :0]
        idx = idx[:, :0]
    mi = {m: i for i, m in enumerate(bundle.seen)}
    prompts, gts, doms = [], [], []
    for qi, q in enumerate(queries):
        for m in bundle.seen:
            prompts.append(serialization.serialize_prompt(
                world.models[m], mi[m], bundle.library.anchor_set,
                bundle.library.get(m), sims[qi], idx[qi], q))
            r = data.record(q.qid, m)
            gts.append((r.y, r.tokens))
            doms.append(q.domain)
    t0 = time.perf_counter()
    preds = est.predict(prompts)
    dt_us = (time.perf_counter() - t0) / len(prompts) * 1e6
    y_hat = np.array([p.y_hat for p in preds])
    len_hat = np.array([p.len_hat for p in preds])
    y_gt = np.array([g[0] for g in gts])
    len_gt = np.array([g[1] for g in gts])
    m = predictive_metrics(y_hat, y_gt, len_hat, len_gt, np.array(doms))
    m["well_formed"] = float(np.mean([p.well_formed for p in preds]))
    return m, dt_us


def run(bundle: Bundle) -> List[Tuple[str, float, str]]:
    rows = []
    settings = [("scope", 5), ("nocot", 5), ("untrained", 5),
                ("untrained", 0)]
    for which, k in settings:
        m, dt = _eval(bundle, which, anchors=k)
        per_dom = ";".join(
            f"{DOMAINS[d][:4]}={m.get(f'acc_d{d}', float('nan')):.2f}"
            for d in range(4))
        rows.append((
            f"predictive/{which}_k{k}", dt,
            f"acc={m['acc']:.3f};mae={m['mae']:.0f};"
            f"wf={m['well_formed']:.2f};{per_dom}"))
    return rows
