"""Kernel micro-benchmarks: XLA twins (jitted, wall time) and Pallas
interpret-mode parity cost.  On CPU the Pallas numbers measure the
interpreter, not the TPU — the roofline benchmark covers the TPU story."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(fn, *args, iters=5) -> float:
    fn(*args)                                   # compile / warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(bundle=None) -> List[Tuple[str, float, str]]:
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention (XLA blocked path)
    for (b, hq, hkv, s, d) in [(1, 8, 2, 2048, 128), (1, 8, 8, 4096, 64)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, hq, s, d), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.bfloat16)
        f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True))
        us = _time(f, q, k, v)
        flops = 2 * 2 * b * hq * s * s * d / 2   # causal half
        rows.append((f"kernel/attn_xla_b{b}h{hq}s{s}d{d}", us,
                     f"gflops_s={flops/us/1e3:.1f}"))
        fw = jax.jit(lambda q, k, v: ops.flash_attention(
            q, k, v, causal=True, window=512))
        rows.append((f"kernel/attn_xla_window512_s{s}", _time(fw, q, k, v),
                     "banded"))

    # ssd scan (ref path)
    b, l, h, p, n = 2, 2048, 8, 64, 64
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, n)) * 0.3
    C = jax.random.normal(ks[4], (b, l, n)) * 0.3
    f = jax.jit(lambda *a: ops.ssd(*a, chunk=128)[0])
    rows.append((f"kernel/ssd_xla_l{l}h{h}p{p}n{n}",
                 _time(f, x, dt, A, B, C), "chunked_dual_form"))

    # paged decode attention (XLA gather path) vs the dense decode twin
    b, hq, hkv, S, d, page = 4, 8, 2, 2048, 64, 16
    n_pages = b * (S // page) + 1                # + trash page
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, 1, d))
    kc = jax.random.normal(ks[1], (b, hkv, S, d))
    vc = jax.random.normal(ks[2], (b, hkv, S, d))
    kp = jnp.zeros((n_pages, hkv, page, d))
    vp = jnp.zeros((n_pages, hkv, page, d))
    table = np.full((b, S // page), n_pages - 1, np.int32)
    nxt = 0
    for i in range(b):                           # scatter rows into pages
        for j in range(S // page):
            kp = kp.at[nxt].set(kc[i, :, j * page:(j + 1) * page])
            vp = vp.at[nxt].set(vc[i, :, j * page:(j + 1) * page])
            table[i, j] = nxt
            nxt += 1
    lens = jnp.full((b,), S, jnp.int32)
    table = jnp.asarray(table)
    fd = jax.jit(lambda q, k, v, n: ops.decode_attention(q, k, v, n))
    rows.append((f"kernel/decode_attn_dense_b{b}s{S}",
                 _time(fd, q, kc, vc, lens), "dense_cache"))
    fp = jax.jit(lambda q, k, v, n, t: ops.paged_decode_attention(
        q, k, v, n, t, page_size=page, kv_cap=S))
    rows.append((f"kernel/decode_attn_paged_b{b}s{S}p{page}",
                 _time(fp, q, kp, vp, lens, table), "paged_gather"))

    # topk retrieval
    q = jax.random.normal(ks[0], (256, 32))
    a = jax.random.normal(ks[1], (250, 32))
    f = jax.jit(lambda q, a: ops.topk_retrieval(q, a, 5)[0])
    rows.append(("kernel/topk_xla_q256_a250", _time(f, q, a),
                 "anchor_retrieval"))

    # pallas interpret parity spot (correctness tax on CPU, not perf)
    qs = jax.random.normal(ks[0], (1, 4, 256, 64))
    kk = jax.random.normal(ks[1], (1, 2, 256, 64))
    vv = jax.random.normal(ks[2], (1, 2, 256, 64))
    t0 = time.perf_counter()
    ops.flash_attention(qs, kk, vv, impl="pallas")
    rows.append(("kernel/attn_pallas_interpret_s256",
                 (time.perf_counter() - t0) * 1e6, "interpret_mode"))
    return rows
