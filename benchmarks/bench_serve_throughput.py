"""Streaming serve throughput: queries/sec, time-to-first-decision, and
recompile counts for the continuous-batching serve runtime vs naive ragged
dispatch, across bucket configs and device counts.

Sections:

  stream_overlap  — ``ScopeEngine.predict_stream`` over ragged traffic
                    ticks with double-buffered dispatch (microbatch N+1's
                    host assembly overlaps N's device decode); after the
                    bucket warmup, varying per-tick batch sizes must add
                    **zero** new executables (asserted in --smoke)
  stream_sync     — the same stream with ``overlap=False`` (the pre-runtime
                    synchronous loop); the overlap row's qps / ttfd gains
                    are reported against this
  deadline_flush  — paced single-query traffic against an under-filled
                    bucket with ``max_queue_age`` set: partially-filled
                    buckets ship when the latency budget expires, keeping
                    queue age bounded (asserted in --smoke)
  engine_refill   — segment-chunked continuous batching
                    (``predict_stream(refill=True)``): decode runs in
                    fixed-size scan segments and drained-at-EOS rows admit
                    the next queued prompt mid-batch instead of idling
                    until the microbatch retires; measured against
                    ``engine_whole_retire`` (the same stream with
                    ``refill=False``) on a ragged-generation-length
                    workload.  Decode-slot occupancy + refill counters
                    come straight from ``SchedulerStats``; --smoke asserts
                    the refill stream beats whole-retire q/s at higher
                    occupancy with zero recompiles after warmup, and that
                    both streams make identical routing decisions
  engine_chaos    — the refill workload under a deterministic
                    ``FaultPlan``: segment teardowns retry/quarantine,
                    a simulated KV-pool exhaustion fails one row, a parse
                    group is scrambled, and a clock stall blows SLO
                    deadlines; --smoke asserts exactly-once delivery, a
                    consistent fault ledger, zero recompiles after
                    warmup, and that the zero-fault plan is bit-identical
                    to running with no plan at all
  tier0_sweep     — two-tier routing: a tier-0 pre-router head distilled
                    from the engine's own estimator answers high-confidence
                    (query, model) pairs in one jitted forward, and only
                    the rest escalate to the reasoning decode.  The same
                    ragged stream runs at ~0% / ~10% / ~50% / 100%
                    escalation (confidence quantiles of the head); every
                    row carries the scheduler's tier ledger plus decision
                    quality vs the 100%-escalation reference.  --smoke
                    asserts zero recompiles after warmup in every row, the
                    ~10% row at >= 3x the full-reasoning q/s, and — with
                    caching on — that threshold > 1 is bit-identical to
                    running without a tier-0 head at all (predictions,
                    cache contents, deterministic scheduler stats modulo
                    the tier ledger)
  engine_drift    — drift-aware self-healing closed loop: a ``model_drift``
                    fault corrupts one model's served outcomes mid-stream;
                    the Page–Hinkley monitor over calibration residuals
                    must alarm within a few ticks, quarantine the model,
                    re-fingerprint it from the replay buffer, and hot-swap
                    the estimator version live.  --smoke asserts the
                    detector-on no-fault stream is bit-identical to
                    detector-off (collection is passive), the alarm fires
                    within 4 ticks of the drift, post-heal decisions match
                    a clean engine on the healed state, the outcome ledger
                    balances, and warmup onward adds zero executables
  stream_naive    — ``predict`` called per ragged tick (the pre-scheduler
                    behavior): every distinct tick size compiles a fresh
                    (batch, len) executable
  batch_oracle    — one big ``predict`` over all queries (the throughput
                    ceiling a scheduler can approach); --smoke also
                    asserts the stream results are bit-identical to it
  sharded         — bucketed stream with the estimator sharded over the
                    serve mesh (only when >1 device is visible; multiply
                    CPU devices with
                    XLA_FLAGS=--xla_force_host_platform_device_count=N or
                    the --devices flag, which sets it before jax loads)

Rows go to stdout CSV (via ``benchmarks.run``) and to
``benchmarks/BENCH_serve_throughput.json``.  Standalone:

  PYTHONPATH=src python benchmarks/bench_serve_throughput.py --smoke
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Dict, List, Tuple

import numpy as np

BENCH_PATH = os.path.join(os.path.dirname(__file__),
                          "BENCH_serve_throughput.json")


def _tick_sizes(n_queries: int, seed: int = 0, max_tick: int = 8) -> List[int]:
    """Deterministic ragged traffic: tick sizes in [1, max_tick]."""
    rng = np.random.default_rng(seed)
    sizes, left = [], n_queries
    while left > 0:
        s = int(rng.integers(1, max_tick + 1))
        sizes.append(min(s, left))
        left -= sizes[-1]
    return sizes


def _as_ticks(queries, sizes):
    out, i = [], 0
    for s in sizes:
        out.append(queries[i: i + s])
        i += s
    return out


def _compile_delta(before: Dict[str, int], after: Dict[str, int]) -> int:
    return sum(after[k] - before[k] for k in after)


# ---------------------------------------------------------------------------
# Sections
# ---------------------------------------------------------------------------
def _stream_once(engine, ticks, cfg, *, overlap: bool):
    """One full stream pass; returns (pools, total_s, ttfd_s, scheduler)."""
    from repro.api import RouteRequest
    from repro.serving.scheduler import MicrobatchScheduler
    sched = MicrobatchScheduler(cfg)
    t0 = time.perf_counter()
    it = engine.predict_stream((RouteRequest(t) for t in ticks),
                               scheduler=sched, use_cache=False,
                               overlap=overlap)
    first = next(it)
    ttfd = time.perf_counter() - t0
    pools = [first] + list(it)
    return pools, time.perf_counter() - t0, ttfd, sched


def bench_stream(engine, queries, *, bucket_sizes, repeats: int = 3,
                 max_tick: int = 8, smoke: bool = False) -> List[Dict]:
    from repro.api import RouteRequest
    from repro.serving.scheduler import BucketConfig, decode_compile_counts

    sizes = _tick_sizes(len(queries), max_tick=max_tick)
    ticks = _as_ticks(queries, sizes)
    n_models = len(engine.registry.routable())

    # -- bucketed stream: warm the bucket executables, then measure ----
    cfg = BucketConfig(batch_sizes=bucket_sizes)
    _stream_once(engine, ticks, cfg, overlap=True)
    warmed = decode_compile_counts()

    def measure(overlap):
        times, ttfds, pools, sched = [], [], None, None
        for _ in range(repeats):
            pools, dt, ttfd, sched = _stream_once(engine, ticks, cfg,
                                                  overlap=overlap)
            times.append(dt)
            ttfds.append(ttfd)
        return pools, len(queries) / min(times), min(ttfds), sched

    # sync first so progressive warming cannot flatter the overlap row
    sync_pools, qps_sync, ttfd_sync, _ = measure(False)
    overlap_pools, qps_overlap, ttfd_overlap, sched = measure(True)
    recompiles = _compile_delta(warmed, decode_compile_counts())

    # -- naive ragged dispatch: one predict per tick -------------------
    before = decode_compile_counts()
    t0 = time.perf_counter()
    naive_pools = [engine.predict(RouteRequest(t), use_cache=False)
                   for t in ticks]
    t_naive = time.perf_counter() - t0
    naive_recompiles = _compile_delta(before, decode_compile_counts())
    qps_naive = len(queries) / t_naive

    # -- batch oracle: the whole query set in one predict (warm shape) -
    engine.predict(RouteRequest(list(queries)), use_cache=False)
    t0 = time.perf_counter()
    batch_pool = engine.predict(RouteRequest(list(queries)), use_cache=False)
    t_batch = time.perf_counter() - t0
    qps_batch = len(queries) / t_batch

    overlap_p = np.concatenate([p.p_hat for p in overlap_pools])
    sync_p = np.concatenate([p.p_hat for p in sync_pools])
    naive_p = np.concatenate([p.p_hat for p in naive_pools])
    identical_stream = bool(np.array_equal(overlap_p, batch_pool.p_hat))
    identical_sync = bool(np.array_equal(sync_p, batch_pool.p_hat))
    identical_naive = bool(np.array_equal(naive_p, batch_pool.p_hat))
    if smoke:
        assert recompiles == 0, (
            f"stream runtime recompiled {recompiles} executables after "
            f"warmup — each (bucket, shape) must compile exactly once")
        assert identical_stream, "overlap stream p_hat != batch predict"
        assert identical_sync, "sync stream p_hat != batch predict"

    st = sched.stats.as_dict()
    return [
        {"name": "serve_throughput/stream_overlap", "qps": qps_overlap,
         "detail": {"ticks": len(ticks), "queries": len(queries),
                    "models": n_models, "buckets": st["buckets"],
                    "pad_fraction": st["pad_fraction"],
                    "microbatches": st["microbatches"],
                    "ttfd_ms": round(ttfd_overlap * 1e3, 2),
                    "queue_age_ms": st["queue_age_ms"],
                    "recompiles_after_warmup": recompiles,
                    "speedup_vs_sync":
                        round(qps_overlap / max(qps_sync, 1e-9), 3),
                    "identical_to_batch": identical_stream}},
        {"name": "serve_throughput/stream_sync", "qps": qps_sync,
         "detail": {"ticks": len(ticks),
                    "ttfd_ms": round(ttfd_sync * 1e3, 2),
                    "identical_to_batch": identical_sync}},
        {"name": "serve_throughput/stream_naive", "qps": qps_naive,
         "detail": {"ticks": len(ticks),
                    "distinct_tick_sizes": len(set(sizes)),
                    "recompiles": naive_recompiles,
                    "identical_to_batch": identical_naive}},
        {"name": "serve_throughput/batch_oracle", "qps": qps_batch,
         "detail": {"queries": len(queries),
                    "speedup_stream_vs_naive":
                        round(qps_overlap / max(qps_naive, 1e-9), 2)}},
    ]


def bench_deadline(engine, queries, *, full_bucket: int = 16,
                   max_queue_ms: float = 5.0, inter_arrival_ms: float = 1.0,
                   smoke: bool = False) -> List[Dict]:
    """Paced single-query traffic against an under-filled bucket.

    Each tick contributes (1 query x M models) prompts — far short of the
    ``full_bucket`` batch — so without a deadline nothing would ship until
    stream end.  With ``max_queue_age`` set, ``tick()`` emits
    partially-filled buckets the moment the oldest prompt ages out.  The
    deadline is **tick-granular**: ticks fire on request arrival in the
    single-threaded drain loop, so realized queue age is bounded by
    ``max_queue_age`` plus the time to the next tick (including any
    microbatch execution the loop blocks on) — the warmup pass below keeps
    one-off XLA compiles out of the measured ages.
    """
    from repro.api import RouteRequest
    from repro.serving.scheduler import BucketConfig, MicrobatchScheduler

    def paced():
        for q in queries:
            time.sleep(inter_arrival_ms / 1e3)
            yield RouteRequest([q])

    def run():
        sched = MicrobatchScheduler(
            BucketConfig(batch_sizes=(full_bucket,)),
            max_queue_age=max_queue_ms / 1e3)
        t0 = time.perf_counter()
        pools = list(engine.predict_stream(paced(), scheduler=sched,
                                           use_cache=False))
        return pools, time.perf_counter() - t0, sched

    run()                       # warm the (full/partial bucket) executables
    pools, dt, sched = run()
    st = sched.stats
    ages = st.queue_age_percentiles()
    # steady-state bound: deadline + a handful of warm microbatch
    # executions the drain loop may block on before the next tick
    exec_ms = dt * 1e3 / max(st.microbatches, 1)
    bound_ms = max_queue_ms + 4 * exec_ms
    if smoke:
        assert st.deadline_flushes > 0, (
            "deadline never fired: paced sub-bucket traffic must trigger "
            "max_queue_age partial flushes")
        assert st.partial_microbatches > 0, (
            "no partially-filled buckets were emitted under the deadline")
        assert len(pools) == len(queries)
        assert ages["max"] * 1e3 <= bound_ms, (
            f"warm queue age {ages['max'] * 1e3:.1f}ms exceeds the "
            f"tick-granular bound {bound_ms:.1f}ms")
    return [{
        "name": "serve_throughput/deadline_flush",
        "qps": len(queries) / dt,
        "detail": {"max_queue_ms": max_queue_ms,
                   "inter_arrival_ms": inter_arrival_ms,
                   "full_bucket": full_bucket,
                   "deadline_flushes": st.deadline_flushes,
                   "partial_microbatches": st.partial_microbatches,
                   "microbatches": st.microbatches,
                   "pad_fraction": round(st.pad_fraction, 4),
                   "age_bound_ms": round(bound_ms, 2),
                   "queue_age_ms": {k: round(v * 1e3, 2)
                                    for k, v in ages.items()}}}]


def bench_refill(engine, queries, *, bucket_sizes, segment_len: int = 4,
                 repeats: int = 3, max_tick: int = 3,
                 smoke: bool = False) -> List[Dict]:
    """Segment-chunked slot refill vs whole-retire on a ragged workload.

    ``engine`` must carry an EOS-emitting (trained) estimator: rows then
    drain at different decode steps, which is the regime where mid-batch
    refill pays — ``refill=True`` admits the oldest queued prompt into a
    drained slot between scan segments, while ``refill=False`` idles the
    slot until the whole microbatch retires.  Occupancy and refill
    counters are read straight from ``SchedulerStats`` (both modes account
    ``slot_steps_active/total`` at token granularity, so the comparison is
    one counter pair, not a recompute).  Routing-decision identity between
    the two modes is checked on every field the router consumes:
    token-derived fields bit-equal, confidences to f32 ulp, and the final
    ``FixedAlphaPolicy`` choices equal.
    """
    from repro.api import FixedAlphaPolicy, RouteRequest
    from repro.serving.scheduler import BucketConfig, MicrobatchScheduler
    from repro.serving.scheduler import decode_compile_counts

    seg = max(1, min(segment_len, int(engine.estimator.max_new_tokens)))
    ticks = _as_ticks(queries, _tick_sizes(len(queries), max_tick=max_tick))
    cfg = BucketConfig(batch_sizes=bucket_sizes)

    def stream(refill):
        sched = MicrobatchScheduler(cfg)
        t0 = time.perf_counter()
        pools = list(engine.predict_stream(
            (RouteRequest(t) for t in ticks), scheduler=sched,
            use_cache=False, refill=refill, segment_len=seg))
        return pools, time.perf_counter() - t0, sched

    stream(False)                   # warm both modes' executables
    stream(True)
    warmed = decode_compile_counts()

    # interleaved pairs (off, on) so wall-clock drift on a shared machine
    # hits both modes alike; best-of per mode
    t_off = t_on = None
    off_pools = on_pools = s_off = s_on = None
    for _ in range(repeats):
        off_pools, dt, s_off = stream(False)
        t_off = dt if t_off is None else min(t_off, dt)
        on_pools, dt, s_on = stream(True)
        t_on = dt if t_on is None else min(t_on, dt)
    recompiles = _compile_delta(warmed, decode_compile_counts())
    qps_off = len(queries) / t_off
    qps_on = len(queries) / t_on

    def cat(pools, field):
        return np.concatenate([np.asarray(getattr(p, field)).reshape(-1)
                               for p in pools])

    token_identical = all(
        np.array_equal(cat(on_pools, f), cat(off_pools, f))
        for f in ("y_hat", "len_hat", "well_formed", "cost_hat",
                  "pred_overhead"))
    conf_close = bool(np.allclose(cat(on_pools, "p_hat"),
                                  cat(off_pools, "p_hat"),
                                  atol=1e-6, rtol=1e-6))
    policy = FixedAlphaPolicy(0.6)
    choices_on = np.concatenate(
        [np.asarray(policy.decide(p, engine).choices) for p in on_pools])
    choices_off = np.concatenate(
        [np.asarray(policy.decide(p, engine).choices) for p in off_pools])
    identical_decisions = bool(np.array_equal(choices_on, choices_off))

    st_on, st_off = s_on.stats, s_off.stats
    if smoke:
        assert recompiles == 0, (
            f"refill stream recompiled {recompiles} executables after "
            f"warmup — segments and refill prefills must reuse the warmed "
            f"bucket shapes")
        assert token_identical, (
            "refill-on vs refill-off streams disagree on token-derived "
            "prediction fields")
        assert conf_close, "refill-on vs refill-off confidences diverge"
        assert identical_decisions, (
            "refill-on vs refill-off streams routed differently")
        assert st_on.slots_refilled > 0, (
            "no slot was refilled: the ragged workload must drain rows "
            "at EOS mid-batch")
        assert st_on.slot_occupancy > st_off.slot_occupancy, (
            f"refill occupancy {st_on.slot_occupancy:.3f} does not beat "
            f"whole-retire {st_off.slot_occupancy:.3f}")
        assert st_on.slot_steps_total < st_off.slot_steps_total, (
            f"refill ran {st_on.slot_steps_total} decode slot-steps vs "
            f"whole-retire's {st_off.slot_steps_total} for identical "
            "output — the deterministic work saving disappeared")
        assert qps_on > qps_off, (
            f"refill q/s {qps_on:.2f} does not beat whole-retire "
            f"{qps_off:.2f} on the ragged workload")
    return [
        {"name": "serve_throughput/engine_refill", "qps": qps_on,
         "detail": {"queries": len(queries), "ticks": len(ticks),
                    "segment_len": seg,
                    "slot_occupancy": round(st_on.slot_occupancy, 4),
                    "slots_refilled": st_on.slots_refilled,
                    "refill_steps_saved": st_on.refill_steps_saved,
                    "slot_steps": st_on.slot_steps_total,
                    "recompiles_after_warmup": recompiles,
                    "speedup_vs_whole_retire":
                        round(qps_on / max(qps_off, 1e-9), 3),
                    "identical_decisions": identical_decisions}},
        {"name": "serve_throughput/engine_whole_retire", "qps": qps_off,
         "detail": {"queries": len(queries),
                    "slot_occupancy": round(st_off.slot_occupancy, 4),
                    "slot_steps": st_off.slot_steps_total,
                    "identical_decisions": identical_decisions}},
    ]


def bench_paged(dense_engine, paged_engine, queries, *, bucket_sizes,
                segment_len: int = 4, repeats: int = 3, max_tick: int = 3,
                smoke: bool = False) -> List[Dict]:
    """Block-paged KV cache vs the dense per-slot horizon, same workload.

    Both engines carry the same trained parameters and stream the same
    ragged refill workload; the only difference is the decode-cache
    layout.  The paged engine's XLA gather path reconstructs exactly the
    contiguous cache the dense kernel reads, so every token-derived field
    must be bit-equal and the final routing decisions identical — the
    page pool buys peak-KV headroom (``kv_peak_tokens`` scales with live
    tokens rather than slots x horizon), not different outputs.
    """
    from repro.api import FixedAlphaPolicy, RouteRequest
    from repro.serving.scheduler import BucketConfig, MicrobatchScheduler
    from repro.serving.scheduler import decode_compile_counts

    seg = max(1, min(segment_len,
                     int(dense_engine.estimator.max_new_tokens)))
    ticks = _as_ticks(queries, _tick_sizes(len(queries), max_tick=max_tick))
    cfg = BucketConfig(batch_sizes=bucket_sizes)

    def stream(engine):
        sched = MicrobatchScheduler(cfg)
        t0 = time.perf_counter()
        pools = list(engine.predict_stream(
            (RouteRequest(t) for t in ticks), scheduler=sched,
            use_cache=False, refill=True, segment_len=seg))
        return pools, time.perf_counter() - t0, sched

    stream(dense_engine)            # warm both cache layouts' executables
    stream(paged_engine)
    warmed = decode_compile_counts()

    t_dense = t_paged = None
    dense_pools = paged_pools = s_dense = s_paged = None
    for _ in range(repeats):
        dense_pools, dt, s_dense = stream(dense_engine)
        t_dense = dt if t_dense is None else min(t_dense, dt)
        paged_pools, dt, s_paged = stream(paged_engine)
        t_paged = dt if t_paged is None else min(t_paged, dt)
    recompiles = _compile_delta(warmed, decode_compile_counts())
    qps_dense = len(queries) / t_dense
    qps_paged = len(queries) / t_paged

    def cat(pools, field):
        return np.concatenate([np.asarray(getattr(p, field)).reshape(-1)
                               for p in pools])

    token_identical = all(
        np.array_equal(cat(paged_pools, f), cat(dense_pools, f))
        for f in ("y_hat", "len_hat", "well_formed", "cost_hat",
                  "pred_overhead"))
    conf_close = bool(np.allclose(cat(paged_pools, "p_hat"),
                                  cat(dense_pools, "p_hat"),
                                  atol=1e-6, rtol=1e-6))
    policy = FixedAlphaPolicy(0.6)
    choices_paged = np.concatenate(
        [np.asarray(policy.decide(p, dense_engine).choices)
         for p in paged_pools])
    choices_dense = np.concatenate(
        [np.asarray(policy.decide(p, dense_engine).choices)
         for p in dense_pools])
    identical_decisions = bool(np.array_equal(choices_paged, choices_dense))

    st_p, st_d = s_paged.stats, s_dense.stats
    if smoke:
        assert recompiles == 0, (
            f"paged stream recompiled {recompiles} executables after "
            f"warmup — page tables are traced, so steady-state segments "
            f"must reuse the warmed bucket shapes")
        assert token_identical, (
            "paged vs dense streams disagree on token-derived prediction "
            "fields — the gather path lost bit parity")
        assert conf_close, "paged vs dense confidences diverge"
        assert identical_decisions, (
            "paged vs dense streams routed differently")
        assert st_p.pages_peak > 0 and st_p.kv_page_size > 0, (
            "the paged stream never touched the page pool")
        assert st_p.kv_peak_tokens < st_d.kv_peak_tokens, (
            f"paged peak KV {st_p.kv_peak_tokens} tokens does not beat "
            f"the dense horizon's {st_d.kv_peak_tokens} — paging must "
            f"cap KV at live tokens, not slots x horizon")
    return [
        {"name": "serve_throughput/engine_paged", "qps": qps_paged,
         "detail": {"queries": len(queries), "segment_len": seg,
                    "kv_page_size": st_p.kv_page_size,
                    "pages_peak": st_p.pages_peak,
                    "kv_peak_tokens": st_p.kv_peak_tokens,
                    "kv_peak_tokens_dense": st_d.kv_peak_tokens,
                    "page_fragmentation":
                        round(st_p.page_fragmentation, 4),
                    "deferred_on_pages":
                        st_p.admissions_deferred_on_pages,
                    "recompiles_after_warmup": recompiles,
                    "qps_vs_dense": round(qps_paged / max(qps_dense, 1e-9),
                                          3),
                    "identical_decisions": identical_decisions}},
    ]


def bench_chaos(engine, queries, *, bucket_sizes, segment_len: int = 4,
                smoke: bool = False) -> List[Dict]:
    """Fault-tolerant serving under a deterministic chaos plan.

    Runs the ``engine_refill`` workload three ways on the paged engine:
    no fault plan at all, ``FaultPlan.none()`` (must be bit-identical —
    the asserted no-op), and a deterministic chaos plan mixing segment
    teardowns (bounded retry + quarantine), a simulated KV-pool row
    failure, a scrambled parse group, and one huge clock stall that blows
    the SLO deadline of every prompt in flight.  Under chaos the smoke
    gate asserts exactly-once delivery (every (query, model) pair answered
    once), ledger consistency (non-OK pairs == degraded + failed ==
    quarantined + deadline-expired prompts), and zero recompiles after
    warmup — the retry/requeue machinery must reuse the warmed bucket
    shapes, never invent new ones.
    """
    from repro.api import RouteRequest
    from repro.core.status import STATUS_OK
    from repro.serving.faults import FaultPlan, FaultSpec
    from repro.serving.scheduler import BucketConfig, MicrobatchScheduler
    from repro.serving.scheduler import decode_compile_counts

    seg = max(1, min(segment_len, int(engine.estimator.max_new_tokens)))
    ticks = _as_ticks(queries, _tick_sizes(len(queries), max_tick=3))
    cfg = BucketConfig(batch_sizes=bucket_sizes)
    n_models = len(engine.registry.routable())

    def stream():
        sched = MicrobatchScheduler(cfg)
        t0 = time.perf_counter()
        pools = list(engine.predict_stream(
            (RouteRequest(t) for t in ticks), scheduler=sched,
            use_cache=False, refill=True, segment_len=seg))
        return pools, time.perf_counter() - t0, sched

    def cat(pools, field):
        return np.concatenate([np.asarray(getattr(p, field)).reshape(-1)
                               for p in pools])

    # -- the asserted no-op: an empty plan must not perturb the stream --
    engine.config.fault_plan = None
    base_pools, _, _ = stream()
    engine.config.fault_plan = FaultPlan.none()
    none_pools, _, _ = stream()
    noop_identical = all(
        np.array_equal(cat(none_pools, f), cat(base_pools, f))
        for f in ("p_hat", "y_hat", "len_hat", "well_formed", "cost_hat",
                  "pred_overhead", "status"))

    # -- deterministic chaos: replays identically on identical traffic -
    chaos = FaultPlan([FaultSpec("segment", 1), FaultSpec("segment", 2),
                       FaultSpec("segment", 4),
                       FaultSpec("pool", 3, arg=1.0),
                       FaultSpec("parse", 2),
                       FaultSpec("stall", 8, arg=1e6)])
    engine.config.fault_plan = chaos
    engine.config.deadline_ms = 60_000.0
    engine.config.max_retries = 1
    try:
        stream()                        # warm the retry/flush shapes
        warmed = decode_compile_counts()
        pools, dt, sched = stream()
        recompiles = _compile_delta(warmed, decode_compile_counts())
    finally:
        engine.config.fault_plan = None
        engine.config.deadline_ms = None
        engine.config.max_retries = 2
    st = sched.stats
    status = cat(pools, "status")
    n_pairs = len(queries) * n_models
    exactly_once = status.size == n_pairs
    n_degraded = int((status != STATUS_OK).sum())
    ledger_consistent = (
        n_degraded == st.degraded + st.failed_pairs
        == st.quarantined + st.deadline_expired)
    if smoke:
        assert noop_identical, (
            "FaultPlan.none() perturbed the stream — the zero-fault path "
            "must be bit-identical to running without a plan")
        assert exactly_once, (
            f"chaos stream answered {status.size} pairs for {n_pairs} "
            f"submitted — exactly-once delivery broke")
        assert st.injected_faults > 0 and st.retries > 0, (
            "the chaos plan never fired / never reached the retry path")
        assert st.quarantined > 0, (
            "no prompt exhausted max_retries under repeated segment faults")
        assert st.deadline_expired > 0, (
            "the injected clock stall expired no deadlines")
        assert st.kv_exhausted_rows > 0, (
            "the injected pool fault failed no row")
        assert n_degraded > 0 and ledger_consistent, (
            f"fault ledger inconsistent: {n_degraded} non-OK pairs, "
            f"degraded={st.degraded} failed={st.failed_pairs} "
            f"quarantined={st.quarantined} "
            f"deadline_expired={st.deadline_expired}")
        assert recompiles == 0, (
            f"chaos stream recompiled {recompiles} executables after "
            f"warmup — retries and requeues must reuse the warmed bucket "
            f"shapes")
    return [{
        "name": "serve_throughput/engine_chaos",
        "qps": len(queries) / dt,
        "detail": {"queries": len(queries), "pairs": n_pairs,
                   "injected_faults": st.injected_faults,
                   "retries": st.retries, "requeued": st.requeued,
                   "quarantined": st.quarantined,
                   "deadline_expired": st.deadline_expired,
                   "kv_exhausted_rows": st.kv_exhausted_rows,
                   "degraded_fraction": round(st.degraded_fraction, 4),
                   "noop_identical": noop_identical,
                   "exactly_once": exactly_once,
                   "ledger_consistent": ledger_consistent,
                   "recompiles_after_warmup": recompiles}}]


def bench_drift(mk, data, *, bucket_sizes,
                n_queries: int = 16, tick_size: int = 4, n_ticks: int = 10,
                smoke: bool = False) -> List[Dict]:
    """Drift-aware self-healing: inject -> detect -> quarantine -> refresh
    -> recover, closed loop over served traffic.

    Four streams over the same cycled qid ticks (``n_ticks`` ticks of
    ``tick_size``, cycling ``n_queries`` qids so the victim model
    accumulates observations):

      1. detector-off reference;
      2. detector-on, no fault — the asserted no-op: decisions, cache
         contents, and deterministic scheduler stats outside the drift
         block must be bit-identical to (1), collection is passive.  Its
         monitor ledger also picks the *victim*: the model with the most
         well-formed served observations, so drift events land on rows
         the detector scores;
      3. the drift run: a ``model_drift`` fault forces the victim's
         observed outcomes wrong from event K on.  The Page–Hinkley
         detector must alarm within a few ticks; at the alarm tick the
         loop heals live — ``onboard(refresh=True)`` re-fingerprints the
         victim from the replay buffer's observed outcomes (no offline
         dataset) and ``hot_swap`` bumps the estimator version mid-stream
         — and the stream keeps serving;
      4. a clean engine over the same ticks against the *refreshed*
         library: every post-heal tick of (3) must make identical routing
         decisions — the healed serve path converged to what a fresh
         engine computes from the healed state.

    Streams run whole-retire with ``overlap=False`` so tick boundaries
    align with prompt serialization and the recovery comparison is exact
    (the refill runtime serializes ticks ahead of their reports; swap
    correctness *inside* a refill stream is covered by the engine tests).
    --smoke additionally asserts exactly-once delivery (every tick answers
    exactly its queries; the replay buffer holds one row per executed
    query) and zero recompiles after warmup across the drift run and the
    recovery reference — healing swaps fingerprint *values* and the
    params pointer, never shapes.
    """
    from repro.api import FixedAlphaPolicy
    from repro.serving.faults import FaultPlan, FaultSpec
    from repro.serving.scheduler import BucketConfig, MicrobatchScheduler
    from repro.serving.scheduler import decode_compile_counts

    world = data.world
    policy = FixedAlphaPolicy(0.6)
    cfg = BucketConfig(batch_sizes=bucket_sizes)
    qids = [int(q) for q in data.test_qids[:n_queries]]
    ticks = [[qids[(t * tick_size + j) % len(qids)]
              for j in range(tick_size)] for t in range(n_ticks)]
    n_served = n_ticks * tick_size
    drift_at = 2 * tick_size            # event index: first query of tick 2
    # alarm-fast knobs for the injected run only; the no-op identity
    # stream (2) keeps the defaults so clean traffic can't false-alarm
    sensitive = dict(drift_detect=True, drift_threshold=2.5,
                     drift_delta=0.05, drift_min_obs=3)

    def serve(eng, *, use_cache, on_tick=None):
        sched = MicrobatchScheduler(cfg)
        reports = []
        t0 = time.perf_counter()
        for i, r in enumerate(eng.serve_stream(
                data, [list(t) for t in ticks], policy, scheduler=sched,
                use_cache=use_cache, overlap=False, refill=False)):
            reports.append(r)
            if on_tick is not None:
                on_tick(eng, i)
        return reports, time.perf_counter() - t0, sched

    def tick_models(reports):
        return [[d.model for d in r.decisions] for r in reports]

    # -- (1) detector-off reference --------------------------------------
    eng_off = mk()
    off_reports, _, s_off = serve(eng_off, use_cache=True)

    # -- (2) passive collection: detector-on == detector-off ------------
    eng_on = mk(drift_detect=True)
    on_reports, _, s_on = serve(eng_on, use_cache=True)

    # the victim comes from the monitor's own ledger: the model with the
    # most *well-formed* served observations (malformed parse-fallback
    # rows are buffered but never scored, so drift events must land on
    # rows the detector actually sees)
    wf_share: Dict[str, int] = {}
    for row in eng_on.monitor.buffer.rows():
        if row.well_formed:
            wf_share[row.model] = wf_share.get(row.model, 0) + 1
    victim = max(sorted(wf_share), key=lambda m: wf_share[m])

    def det_stats(sched):
        return {k: v for k, v in sched.stats.as_dict().items()
                if k not in ("queue_age_ms", "drift")}

    noop_decisions = tick_models(on_reports) == tick_models(off_reports)
    noop_cache = eng_on.cache._store == eng_off.cache._store
    noop_stats = det_stats(s_on) == det_stats(s_off)

    # -- (3) the drift run: inject, detect, heal live --------------------
    plan = FaultPlan([FaultSpec("model_drift", drift_at, arg=1.0,
                                model=victim)])
    eng_d = mk(fault_plan=plan, **sensitive)
    fp_before = eng_d.library.get(victim)
    fp_mean_before = float(np.mean(fp_before.y))
    state = {"alarm_tick": None, "heal_tick": None}

    def heal(eng, i):
        if state["alarm_tick"] is not None:
            return
        if victim not in eng.monitor.drifted:
            return
        state["alarm_tick"] = i
        # live heal between ticks: replay-buffer re-fingerprint (no
        # offline dataset) + estimator hot-swap under a bumped version
        eng.onboard(world, victim, refresh=True)
        eng.hot_swap(eng.estimator,
                     eng.config.estimator_version + "+heal")
        state["heal_tick"] = i

    warmed = decode_compile_counts()
    try:
        d_reports, dt, s_d = serve(eng_d, use_cache=False, on_tick=heal)
        fp_mean_after = float(np.mean(eng_d.library.get(victim).y))

        # -- (4) recovery reference: clean engine, healed library --------
        clean_reports, _, _ = serve(mk(), use_cache=False)
    finally:
        # the heal mutated the *shared* fingerprint library (that sharing
        # is what lets (4) see the refresh); put the original back so
        # later benches see pristine fingerprints
        eng_d.library.add(fp_before)
    recompiles = _compile_delta(warmed, decode_compile_counts())

    alarm_tick, heal_tick = state["alarm_tick"], state["heal_tick"]
    drift_tick = drift_at // tick_size
    post = (heal_tick + 1) if heal_tick is not None else len(ticks)
    recovered = (tick_models(d_reports)[post:]
                 == tick_models(clean_reports)[post:])
    dst = s_d.stats
    ledger_balanced = (
        sum(r.n_queries for r in d_reports) == n_served
        and all(len(r.decisions) == len(t)
                for r, t in zip(d_reports, ticks, strict=True))
        and dst.replay_buffer_len == n_served)
    if smoke:
        assert noop_decisions and noop_cache and noop_stats, (
            f"detector-on serving with no drift fault diverged from "
            f"detector-off (decisions equal: {noop_decisions}, cache "
            f"equal: {noop_cache}, stats equal: {noop_stats}) — outcome "
            f"collection must be passive")
        assert s_on.stats.drift_alarms == 0, (
            "the detector false-alarmed on clean traffic")
        assert s_on.stats.replay_buffer_len == n_served, (
            f"detector-on stream buffered {s_on.stats.replay_buffer_len} "
            f"outcomes for {n_served} served queries")
        assert alarm_tick is not None, (
            f"the drift detector never fired on {victim!r} drifting at "
            f"tick {drift_tick}")
        assert alarm_tick - drift_tick <= 4, (
            f"detector fired at tick {alarm_tick}, "
            f"{alarm_tick - drift_tick} ticks after the drift at tick "
            f"{drift_tick} — the closed loop is too slow")
        assert fp_mean_after < fp_mean_before, (
            f"replay-buffer refresh did not move the victim fingerprint "
            f"({fp_mean_before:.3f} -> {fp_mean_after:.3f})")
        assert recovered, (
            "post-heal ticks routed differently from a clean engine on "
            "the healed state — the swap/refresh left stale serve state")
        assert ledger_balanced, (
            f"drift ledger does not balance: "
            f"{sum(r.n_queries for r in d_reports)} answered for "
            f"{n_served} served, buffer {dst.replay_buffer_len}")
        assert dst.drift_alarms >= 1 and dst.hot_swaps == 1, (
            f"drift stats block wrong: alarms={dst.drift_alarms} "
            f"hot_swaps={dst.hot_swaps}")
        assert recompiles == 0, (
            f"the drift run recompiled {recompiles} executables after "
            f"warmup — fingerprint refresh and hot-swap must never "
            f"change shapes")
    return [{
        "name": "serve_throughput/engine_drift",
        "qps": n_served / dt,
        "detail": {"queries": n_served, "victim": victim,
                   "drift_tick": drift_tick, "alarm_tick": alarm_tick,
                   "ticks_to_alarm": (None if alarm_tick is None
                                      else alarm_tick - drift_tick),
                   "victim_fp_mean": [round(fp_mean_before, 3),
                                      round(fp_mean_after, 3)],
                   "noop_identical": bool(noop_decisions and noop_cache
                                          and noop_stats),
                   "recovered_decisions": recovered,
                   "ledger_balanced": ledger_balanced,
                   "drift": s_d.stats.as_dict()["drift"],
                   "recompiles_after_warmup": recompiles}}]


def bench_tier0(engine, queries, *, bucket_sizes, data, mk,
                distill_steps: int = 200, max_pairs: int = 1200,
                repeats: int = 2, smoke: bool = False) -> List[Dict]:
    """Escalation-threshold sweep for two-tier routing + identity gate.

    A tier-0 pre-router head is distilled from ``engine``'s own estimator
    (teacher labels come from the reasoning decode's parsed outputs), then
    the same ragged stream runs at four escalation thresholds: 0 (every
    pair answered by the head), the head's 10% and 50% confidence
    quantiles over this exact workload (~10% / ~50% of pairs escalate),
    and 2.0 (every pair pays the full reasoning decode — the reference
    row).  Tier-0 answered pairs never enter the microbatch scheduler, so
    the q/s gain tracks the decode tokens the ledger says were saved.

    Decision quality is measured against the 100%-escalation reference:
    ``FixedAlphaPolicy`` choice agreement and confidence MAE per row.
    The separate identity check streams with caching *on* through two
    fresh engines — tier-0 at threshold 2.0 vs no tier-0 at all — and
    compares every prediction field, the cache stores, and the
    deterministic scheduler stats (everything except wall-clock queue
    ages and the new tier ledger); --smoke asserts all of it.
    """
    from benchmarks.common import tier_ledger
    from repro.api import FixedAlphaPolicy, RouteRequest
    from repro.serving.scheduler import BucketConfig, MicrobatchScheduler
    from repro.serving.scheduler import decode_compile_counts
    from repro.training.tier0 import distill_tier0

    head = distill_tier0(data, engine.config.library,
                         engine.config.retriever, engine.estimator,
                         max_pairs=max_pairs, steps=distill_steps, seed=0)
    ticks = _as_ticks(queries, _tick_sizes(len(queries), max_tick=3))
    cfg = BucketConfig(batch_sizes=bucket_sizes)
    n_pairs = len(queries) * len(engine.registry.routable())

    def stream(eng, *, use_cache=False):
        sched = MicrobatchScheduler(cfg)
        t0 = time.perf_counter()
        pools = list(eng.predict_stream(
            (RouteRequest(t) for t in ticks), scheduler=sched,
            use_cache=use_cache))
        return pools, time.perf_counter() - t0, sched

    def cat(pools, field):
        return np.concatenate([np.asarray(getattr(p, field)).reshape(-1)
                               for p in pools])

    # head confidences over this exact workload: at threshold 0 every
    # pair is answered by the head, so p_hat IS the calibrated tier-0
    # probability and max(p, 1-p) the escalation signal the gate sees
    policy = FixedAlphaPolicy(0.6)
    results = {}
    try:
        engine.config.tier0 = head
        engine.config.escalation_threshold = 0.0
        probe_pools, _, _ = stream(engine)
        p0 = cat(probe_pools, "p_hat")
        conf = np.maximum(p0, 1.0 - p0)
        sweep = [("esc_0", 0.0),
                 ("esc_10", float(np.quantile(conf, 0.10))),
                 ("esc_50", float(np.quantile(conf, 0.50))),
                 ("esc_100", 2.0)]
        for tag, thr in sweep:
            engine.config.escalation_threshold = thr
            stream(engine)          # warm this row's decode bucket shapes
            warmed = decode_compile_counts()
            best = pools = sched = None
            for _ in range(repeats):
                pools, dt, sched = stream(engine)
                best = dt if best is None else min(best, dt)
            choices = np.concatenate(
                [np.asarray(policy.decide(p, engine).choices)
                 for p in pools])
            results[tag] = {
                "thr": thr, "qps": len(queries) / best, "pools": pools,
                "stats": sched.stats, "choices": choices,
                "recompiles": _compile_delta(warmed,
                                             decode_compile_counts())}
    finally:
        engine.config.tier0 = None
        engine.config.escalation_threshold = 0.9

    # -- identity gate: threshold > 1 must equal no tier-0 head at all --
    ref_eng, t0_eng = mk(), mk(tier0=head, escalation_threshold=2.0)
    ref_pools, _, ref_sched = stream(ref_eng, use_cache=True)
    t0_pools, dt_id, t0_sched = stream(t0_eng, use_cache=True)
    fields = ("p_hat", "y_hat", "len_hat", "well_formed", "cost_hat",
              "pred_overhead", "status")
    identical_fields = all(
        np.array_equal(cat(t0_pools, f), cat(ref_pools, f))
        for f in fields)
    identical_cache = t0_eng.cache._store == ref_eng.cache._store

    def det_stats(sched_stats):
        return {k: v for k, v in sched_stats.as_dict().items()
                if k not in ("queue_age_ms", "tiers")}

    identical_stats = det_stats(t0_sched.stats) == det_stats(ref_sched.stats)

    rate = {tag: results[tag]["stats"].escalation_rate
            for tag, _ in sweep}
    if smoke:
        for tag, _ in sweep:
            assert results[tag]["recompiles"] == 0, (
                f"tier-0 row {tag} recompiled "
                f"{results[tag]['recompiles']} executables after warmup — "
                f"the gate must reuse the warmed pair buckets and decode "
                f"shapes")
        assert rate["esc_0"] == 0.0, (
            f"threshold 0 escalated {rate['esc_0']:.2%} of pairs — "
            f"conf = max(p, 1-p) >= 0.5 must answer everything")
        assert rate["esc_100"] == 1.0, (
            f"threshold 2.0 escalated only {rate['esc_100']:.2%} — "
            f"a threshold > 1 must escalate every pair")
        assert 0.0 < rate["esc_10"] <= 0.3, (
            f"10%-quantile threshold escalated {rate['esc_10']:.2%}")
        assert 0.2 <= rate["esc_50"] <= 0.8, (
            f"50%-quantile threshold escalated {rate['esc_50']:.2%}")
        assert results["esc_10"]["qps"] >= 3.0 * results["esc_100"]["qps"], (
            f"~10% escalation q/s {results['esc_10']['qps']:.2f} is not "
            f">= 3x full reasoning {results['esc_100']['qps']:.2f} — "
            f"tier-0 answers are not skipping the decode")
        assert identical_fields, (
            "tier-0 at threshold 2.0 changed prediction fields vs no "
            "tier-0 head — 100% escalation must be bit-identical")
        assert identical_cache, (
            "tier-0 at threshold 2.0 left different cache contents vs no "
            "tier-0 head")
        assert identical_stats, (
            "tier-0 at threshold 2.0 perturbed deterministic scheduler "
            "stats vs no tier-0 head")

    ref_p = cat(results["esc_100"]["pools"], "p_hat")
    ref_choices = results["esc_100"]["choices"]
    rows = []
    for tag, thr in sweep:
        r = results[tag]
        agree = float(np.mean(r["choices"] == ref_choices))
        p_mae = float(np.mean(np.abs(cat(r["pools"], "p_hat") - ref_p)))
        rows.append({
            "name": f"serve_throughput/tier0_{tag}", "qps": r["qps"],
            "detail": {"threshold": round(thr, 4), "pairs": n_pairs,
                       "tiers": tier_ledger(r["stats"]),
                       "decision_agreement": round(agree, 4),
                       "p_conf_mae": round(p_mae, 4),
                       "recompiles_after_warmup": r["recompiles"],
                       "speedup_vs_full_reasoning": round(
                           r["qps"] / max(results["esc_100"]["qps"], 1e-9),
                           3)}})
    rows.append({
        "name": "serve_throughput/tier0_identity",
        "qps": len(queries) / dt_id,
        "detail": {"threshold": 2.0,
                   "identical_fields": identical_fields,
                   "identical_cache": identical_cache,
                   "identical_stats": identical_stats,
                   "temperature": round(head.temperature, 3)}})
    return rows


def bench_sharded(engine, queries, *, bucket_sizes) -> List[Dict]:
    """Bucketed stream with the estimator placed on the serve mesh."""
    import jax

    from repro.api import RouteRequest
    from repro.launch.mesh import make_serve_mesh
    from repro.serving.scheduler import BucketConfig, MicrobatchScheduler

    n_dev = jax.local_device_count()
    if n_dev < 2:
        return []
    mesh = make_serve_mesh()
    engine.estimator.shard(mesh)
    ticks = _as_ticks(queries, _tick_sizes(len(queries)))
    cfg = BucketConfig(batch_sizes=bucket_sizes)
    run = lambda: list(engine.predict_stream(                  # noqa: E731
        (RouteRequest(t) for t in ticks),
        scheduler=MicrobatchScheduler(cfg), use_cache=False))
    run()                                   # compile sharded executables
    t0 = time.perf_counter()
    run()
    dt = time.perf_counter() - t0
    return [{"name": "serve_throughput/stream_sharded",
             "qps": len(queries) / dt,
             "detail": {"devices": n_dev,
                        "mesh": dict(zip(mesh.axis_names,
                                         mesh.devices.shape,
                                         strict=True))}}]


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------
def _emit(rows: List[Dict], *, smoke: bool) -> None:
    import jax

    from benchmarks._io import write_bench_json
    write_bench_json(BENCH_PATH, {
        "bench": "serve_throughput", "smoke": smoke,
        "unix_time": int(time.time()),
        "devices": jax.local_device_count(), "rows": rows})


def _as_csv_rows(rows: List[Dict]) -> List[Tuple[str, float, str]]:
    out = []
    for r in rows:
        detail = ";".join(f"{k}={v}" for k, v in r["detail"].items())
        out.append((r["name"], 1e6 / max(r["qps"], 1e-9),
                    f"qps={r['qps']:.1f};{detail}"))
    return out


BUCKETS = (1, 2, 4, 8, 16)


def run(bundle) -> List[Tuple[str, float, str]]:
    """benchmarks.run entry point: trained estimator, seen pool."""
    engine = bundle.engine(bundle.seen)
    queries = [bundle.data.queries[int(q)]
               for q in bundle.data.test_qids[:48]]
    rows = bench_stream(engine, queries, bucket_sizes=BUCKETS)
    rows += bench_deadline(engine, queries[:24])
    rows += bench_refill(bundle.engine(bundle.seen), queries,
                         bucket_sizes=BUCKETS)
    rows += bench_paged(bundle.engine(bundle.seen),
                        bundle.engine(bundle.seen, kv_paged=True,
                                      kv_page_size=8),
                        queries, bucket_sizes=BUCKETS)
    rows += bench_chaos(bundle.engine(bundle.seen, kv_paged=True,
                                      kv_page_size=8),
                        queries, bucket_sizes=BUCKETS)
    rows += bench_tier0(bundle.engine(bundle.seen), queries,
                        bucket_sizes=BUCKETS, data=bundle.data,
                        mk=lambda **kw: bundle.engine(bundle.seen, **kw))
    rows += bench_drift(lambda **kw: bundle.engine(bundle.seen, **kw),
                        bundle.data, bucket_sizes=BUCKETS)
    rows += bench_sharded(bundle.engine(bundle.seen), queries,
                          bucket_sizes=BUCKETS)
    _emit(rows, smoke=False)
    return _as_csv_rows(rows)


def _smoke_world():
    """Tiny CI-sized world shared by the smoke engines."""
    from repro.core.fingerprint import FingerprintLibrary, build_anchor_set
    from repro.core.retrieval import AnchorRetriever
    from repro.data.datasets import build_scope_data, stratified_anchors
    from repro.data.worldsim import World

    world = World(seed=0)
    data = build_scope_data(world, n_queries=240, seed=0)
    aset = build_anchor_set(world, stratified_anchors(world, n=60, seed=7))
    library = FingerprintLibrary(aset)
    for m in data.models:
        library.onboard(world, m, seed=3)
    return world, data, library, AnchorRetriever(aset)


def _smoke_engine(world, data, library, retriever, params,
                  max_new_tokens: int = 12, **ekw):
    from repro.api import EngineConfig, ScopeEngine
    from repro.configs.scope_estimator import TINY
    from repro.core.estimator import ReasoningEstimator

    return ScopeEngine.build(EngineConfig(
        estimator=ReasoningEstimator(TINY, params,
                                     max_new_tokens=max_new_tokens),
        retriever=retriever, library=library,
        models_meta={m: world.models[m] for m in data.models}, **ekw))


def _smoke_setup():
    """Tiny untrained world — shapes and scheduling only, CI-sized."""
    import jax

    from repro.configs.scope_estimator import TINY
    from repro.models import model as M

    world, data, library, retriever = _smoke_world()
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    engine = _smoke_engine(world, data, library, retriever, params)
    queries = [data.queries[int(q)] for q in data.test_qids[:10]]
    return engine, queries


def _smoke_trained_setup():
    """Tiny SFT-bootstrapped engine for the refill row.

    A briefly-trained estimator emits EOS at genuinely varying decode
    steps well short of the ``max_new_tokens`` budget (the budget is sized
    for worst-case rationale length, typical generations are much
    shorter), which is the ragged-generation-length regime where mid-batch
    slot refill pays; an untrained one never emits EOS, so every row would
    retire at the same boundary and the refill row would measure nothing.
    """
    import jax

    from repro.configs.scope_estimator import TINY
    from repro.models import model as M
    from repro.training.sft import build_sft_dataset, train_sft

    world, data, library, retriever = _smoke_world()
    ds = build_sft_dataset(data, library, retriever, cot=True,
                           max_examples=800, seed=0)
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    # 130 steps (not 50): enough for most rows to parse well-formed — the
    # drift row's detector only scores well-formed residuals, so a mostly-
    # malformed estimator would starve it of observations
    params, _ = train_sft(params, TINY, ds, steps=130, batch_size=32)

    def mk(**ekw):
        return _smoke_engine(world, data, library, retriever, params,
                             max_new_tokens=16, **ekw)

    engine = mk()
    # paged twin: same params and pool, block-paged decode KV — streams
    # must be bit-identical to the dense engine's refill streams
    paged = mk(kv_paged=True, kv_page_size=8)
    queries = [data.queries[int(q)] for q in data.test_qids[:16]]
    return engine, paged, queries, data, mk


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny untrained setup (CI gate), asserts "
                         "one-compile-per-bucket + stream==batch")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host CPU devices (before jax loads)")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")

    if args.smoke:
        engine, queries = _smoke_setup()
        rows = bench_stream(engine, queries, bucket_sizes=(1, 2, 4, 8),
                            repeats=args.repeats or 2, max_tick=3,
                            smoke=True)
        rows += bench_deadline(engine, queries[:6], smoke=True)
        trained, tpaged, tqueries, tdata, tmk = _smoke_trained_setup()
        rows += bench_refill(trained, tqueries, bucket_sizes=(1, 2, 4, 8),
                             repeats=args.repeats or 2, smoke=True)
        rows += bench_paged(trained, tpaged, tqueries,
                            bucket_sizes=(1, 2, 4, 8),
                            repeats=args.repeats or 2, smoke=True)
        rows += bench_chaos(tpaged, tqueries, bucket_sizes=(1, 2, 4, 8),
                            smoke=True)
        rows += bench_tier0(trained, tqueries, bucket_sizes=(1, 2, 4, 8),
                            data=tdata, mk=tmk, distill_steps=60,
                            max_pairs=256, repeats=args.repeats or 2,
                            smoke=True)
        rows += bench_drift(tmk, tdata, bucket_sizes=(1, 2, 4, 8),
                            smoke=True)
        rows += bench_sharded(engine, queries, bucket_sizes=(1, 2, 4, 8))
        _emit(rows, smoke=True)
        print("# smoke asserts passed: zero recompiles after warmup, "
              "overlap+sync streams bit-identical to batch predict, "
              "deadline flush ships partial buckets, refill stream beats "
              "whole-retire q/s at higher slot occupancy with identical "
              "routing decisions, paged KV bit-identical to dense at "
              "lower peak KV tokens, chaos stream delivers every pair "
              "exactly once with a consistent fault ledger and the "
              "zero-fault plan bit-identical to no plan, tier-0 gating "
              "answers high-confidence pairs at >= 3x full-reasoning q/s "
              "with 100% escalation bit-identical to no tier-0 head, "
              "drift detector fires within 4 ticks of injected model "
              "drift and the live refresh+hot-swap recovers clean-engine "
              "decisions with zero recompiles")
    else:
        from benchmarks.common import get_bundle
        rows_csv = run(get_bundle())
        for name, us, derived in rows_csv:
            print(f"{name},{us:.2f},{derived}")
        return 0
    print("name,us_per_query,derived")
    for name, us, derived in _as_csv_rows(rows):
        print(f"{name},{us:.2f},{derived}")
    return 0


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    raise SystemExit(main())
