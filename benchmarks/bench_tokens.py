"""Fig. 9 / Appendix E: token cost of SCOPE (pool-wide prediction overhead
+ ONE executed model) vs test-time scaling (execute everything).  Also the
hindsight-distillation compression of the prediction traces (238.7 vs
2354.9 tokens in the paper; here: trained trace length vs the untrained
model's budget-exhausting rambles)."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from benchmarks.common import Bundle, pool_predictions_cached, route_alpha
from repro.core.baselines import tts_outcome
from repro.core.evaluation import evaluate_choices


def run(bundle: Bundle) -> List[Tuple[str, float, str]]:
    rows = []
    engine, pool, qids, data, models = pool_predictions_cached(bundle,
                                                               ood=False)
    ch = route_alpha(engine, pool, 0.9)
    ev = evaluate_choices(data, qids, models, ch)
    scope_exec = ev.exec_tokens
    scope_pred = int(pool.pred_overhead.sum())
    scope_total = scope_exec + scope_pred

    tts_tokens = sum(tts_outcome(data, int(q), models)[1] for q in qids)
    tts_acc = np.mean([tts_outcome(data, int(q), models)[0] for q in qids])
    saving = 1.0 - scope_total / max(tts_tokens, 1)
    Q = len(qids)
    rows.append(("tokens/tts_all_models", 0.0,
                 f"tokens_per_query={tts_tokens/Q:.0f};acc={tts_acc:.3f}"))
    rows.append(("tokens/scope", 0.0,
                 f"tokens_per_query={scope_total/Q:.0f};"
                 f"pred_overhead_per_query={scope_pred/Q:.0f};"
                 f"acc={ev.avg_acc:.3f}"))
    rows.append(("tokens/savings", 0.0, f"saving={saving*100:.1f}%"))

    # prediction-trace compression (App. E): trained vs untrained trace len
    trained_len = float(pool.pred_overhead.mean())
    _, pool_u, _, _, _ = pool_predictions_cached(bundle, ood=False,
                                                 which="untrained",
                                                 n_queries=16)
    untrained_len = float(pool_u.pred_overhead.mean())
    rows.append(("tokens/trace_compression", 0.0,
                 f"trained={trained_len:.1f};untrained={untrained_len:.1f};"
                 f"note=untrained_capped_at_12_new_tokens"))
    return rows
