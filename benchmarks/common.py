"""Shared benchmark setup: world, datasets, fingerprints, and a trained
SCOPE estimator (SFT + GRPO), cached on disk so repeated benchmark runs
don't retrain."""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs.scope_estimator import TINY
from repro.core.estimator import ReasoningEstimator
from repro.core.fingerprint import FingerprintLibrary, build_anchor_set
from repro.core.retrieval import AnchorRetriever
from repro.data.datasets import ScopeData, build_scope_data, stratified_anchors
from repro.data.worldsim import World
from repro.models import model as M
from repro.training import checkpoint
from repro.training.grpo import GRPOConfig, GRPOTrainer
from repro.training.sft import build_sft_dataset, train_sft

CACHE_DIR = os.path.join(os.path.dirname(__file__), "_cache")

N_QUERIES = 2400
N_ANCHORS = 250
SFT_STEPS = 2000
SFT_EXAMPLES = 15000
GRPO_STEPS = 60
SEED = 0


@dataclasses.dataclass
class Bundle:
    world: World
    data: ScopeData               # seen pool, train+test
    ood_data: ScopeData           # unseen pool, frontier difficulty
    library: FingerprintLibrary
    retriever: AnchorRetriever
    params: Dict                  # SCOPE (SFT+GRPO, CoT)
    params_nocot: Dict            # SCOPE_NoCoT ablation
    params_untrained: Dict        # base model analogue
    cfg: object
    seen: List[str]
    unseen: List[str]

    def estimator(self, which: str = "scope") -> ReasoningEstimator:
        p = {"scope": self.params, "nocot": self.params_nocot,
             "untrained": self.params_untrained}[which]
        return ReasoningEstimator(self.cfg, p, cot=(which != "nocot"))

    def engine(self, models: List[str], which: str = "scope", **kw):
        """A cache-enabled ScopeEngine over the given pool."""
        from repro.api import EngineConfig, ScopeEngine
        return ScopeEngine.build(EngineConfig(
            estimator=self.estimator(which), retriever=self.retriever,
            library=self.library,
            models_meta={m: self.world.models[m] for m in models}, **kw))


_BUNDLE: Optional[Bundle] = None


def _train_variant(data, library, retriever, *, cot: bool, grpo: bool,
                   tag: str) -> Dict:
    path = os.path.join(CACHE_DIR, f"scope_{tag}.npz")
    params = M.init_params(jax.random.PRNGKey(SEED), TINY)
    if os.path.exists(path):
        return checkpoint.load(path, params)
    t0 = time.time()
    ds = build_sft_dataset(data, library, retriever, cot=cot,
                           max_examples=SFT_EXAMPLES, seed=SEED)
    params, losses = train_sft(params, TINY, ds, steps=SFT_STEPS,
                               batch_size=64)
    if grpo:
        tr = GRPOTrainer(TINY, params, data, library, retriever,
                         gcfg=GRPOConfig(), cot=cot, seed=SEED)
        tr.train(GRPO_STEPS)
        params = tr.params
    os.makedirs(CACHE_DIR, exist_ok=True)
    checkpoint.save(path, params)
    print(f"# trained {tag}: sft {np.mean(losses[:10]):.3f}->"
          f"{np.mean(losses[-10:]):.3f} in {time.time()-t0:.0f}s")
    return params


def get_bundle() -> Bundle:
    global _BUNDLE
    if _BUNDLE is not None:
        return _BUNDLE
    world = World(seed=SEED)
    seen = [m.name for m in world.pool if m.seen]
    unseen = [m.name for m in world.pool if not m.seen]
    data = build_scope_data(world, n_queries=N_QUERIES, seed=SEED)
    ood_data = build_scope_data(world, n_queries=300, models=unseen,
                                seed=SEED + 1, difficulty_shift=0.9,
                                test_frac=0.5)
    aset = build_anchor_set(world, stratified_anchors(world, n=N_ANCHORS,
                                                      seed=SEED + 7))
    library = FingerprintLibrary(aset)
    for m in seen + unseen:       # unseen: fingerprints only, zero training
        library.onboard(world, m, seed=SEED + 13)
    retriever = AnchorRetriever(aset)

    params = _train_variant(data, library, retriever, cot=True, grpo=True,
                            tag="cot_grpo")
    params_nocot = _train_variant(data, library, retriever, cot=False,
                                  grpo=True, tag="nocot_grpo")
    params_untrained = M.init_params(jax.random.PRNGKey(SEED + 5), TINY)

    _BUNDLE = Bundle(world, data, ood_data, library, retriever, params,
                     params_nocot, params_untrained, TINY, seen, unseen)
    return _BUNDLE


def pool_predictions_cached(bundle: Bundle, *, ood: bool, which: str = "scope",
                            n_queries: int = 110):
    """Pool-wide predictions for the eval split (computed once per run),
    served through a cache-enabled ``repro.api.ScopeEngine``."""
    from repro.api import RouteRequest
    key = (ood, which, n_queries)
    cache = getattr(bundle, "_pp_cache", None)
    if cache is None:
        cache = {}
        bundle._pp_cache = cache
    if key in cache:
        return cache[key]
    data = bundle.ood_data if ood else bundle.data
    models = bundle.unseen if ood else bundle.seen
    qids = data.test_qids[:n_queries]
    queries = [data.queries[int(q)] for q in qids]
    engine = bundle.engine(models, which)
    pool = engine.predict(RouteRequest(queries))
    cache[key] = (engine, pool, qids, data, models)
    return cache[key]


def route_alpha(engine, pool, alpha: float, **kw) -> np.ndarray:
    """argmax-utility choices at a fixed alpha (Eq. 15) via the engine."""
    return np.argmax(engine.utilities(pool, float(alpha), **kw), axis=1)


def tier_ledger(stats) -> Dict[str, object]:
    """Two-tier routing ledger for bench JSON rows.

    Pulls the ``tiers`` block straight from ``SchedulerStats.as_dict()``
    (tier-0 answered pairs, escalations, escalation rate, degraded
    fallbacks to the stashed tier-0 answer, and decode tokens saved) so
    every bench that streams through a scheduler attaches the same ledger
    shape to its rows.
    """
    return stats.as_dict()["tiers"]
