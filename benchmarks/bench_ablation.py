"""Fig. 7: decision-logic ablations — dynamic utility maximization vs
augmented Chebyshev and Highest-Cost; calibration weight w sweep."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from benchmarks.common import Bundle, pool_predictions_cached, route_alpha
from repro.core.baselines import chebyshev_choices, highest_cost_choices
from repro.core.evaluation import evaluate_choices


def _curve_area(pts):
    """Area under the (cost, acc) frontier, cost-normalized (higher=better)."""
    pts = sorted(pts)
    if len(pts) < 2:
        return 0.0
    xs = np.array([p[0] for p in pts])
    ys = np.array([p[1] for p in pts])
    xs = (xs - xs.min()) / max(xs.max() - xs.min(), 1e-9)
    return float(np.trapezoid(ys, xs))


def run(bundle: Bundle) -> List[Tuple[str, float, str]]:
    rows = []
    engine, pool, qids, data, models = pool_predictions_cached(bundle,
                                                               ood=False)
    alphas = np.linspace(0, 1, 9)

    # --- utility-rule comparison (Fig. 7 left) ---------------------------
    curves = {"scope_dynamic": [], "chebyshev": [], "highest_cost": []}
    for a in alphas:
        ch = route_alpha(engine, pool, float(a))
        ev = evaluate_choices(data, qids, models, ch)
        curves["scope_dynamic"].append((ev.total_cost, ev.avg_acc))

        ch = chebyshev_choices(pool.p_hat, pool.cost_hat, float(a))
        ev = evaluate_choices(data, qids, models, ch)
        curves["chebyshev"].append((ev.total_cost, ev.avg_acc))

        budget_q = np.quantile(pool.cost_hat, 0.2 + 0.75 * a)
        ch = highest_cost_choices(pool.cost_hat, float(budget_q))
        ev = evaluate_choices(data, qids, models, ch)
        curves["highest_cost"].append((ev.total_cost, ev.avg_acc))
    for name, pts in curves.items():
        rows.append((f"ablation/utility/{name}", 0.0,
                     f"frontier_auc={_curve_area(pts):.4f};"
                     f"max_acc={max(p[1] for p in pts):.3f}"))

    # --- calibration weight sweep (Fig. 7 right) -------------------------
    for w_base in (0.0, 0.2, 0.5, 1.0):
        e2 = bundle.engine(models, w_base=w_base)
        pts = []
        for a in alphas:
            ch = route_alpha(e2, pool, float(a))
            ev = evaluate_choices(data, qids, models, ch)
            pts.append((ev.total_cost, ev.avg_acc))
        costs = sorted(p[0] for p in pts)
        gaps = np.diff(costs) / max(costs[-1] - costs[0], 1e-9)
        rows.append((f"ablation/calibration/w{w_base:g}", 0.0,
                     f"frontier_auc={_curve_area(pts):.4f};"
                     f"max_cost_gap={gaps.max() if len(gaps) else 0:.3f}"))
    return rows
