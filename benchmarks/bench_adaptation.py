"""Appendix F: computational cost of domain adaptation — baseline
(label-generation + router retraining) vs SCOPE (anchor inference only).
Reproduces the 38x analytic derivation with the paper's constants and
reports our world-sim equivalents."""
from __future__ import annotations

from typing import List, Tuple

from benchmarks.common import Bundle


def flops_ratio(P=37e9, P_router=4e9, N_tr=4778, L=4873, E=3, K=250):
    T_inf = N_tr * L
    F_inf = 2 * P * T_inf
    T_train = E * N_tr * L
    F_train = 6 * P_router * T_train
    F_baseline = F_inf + F_train
    F_scope = 2 * P * K * L
    return F_baseline, F_scope, F_baseline / F_scope


def run(bundle: Bundle) -> List[Tuple[str, float, str]]:
    rows = []
    fb, fs, ratio = flops_ratio()
    rows.append(("adaptation/paper_constants", 0.0,
                 f"baseline_flops={fb:.3e};scope_flops={fs:.3e};"
                 f"ratio={ratio:.1f}x"))
    # closed form (Eq. 35): (N_tr/K) * (1 + 6*P_r*E / (2*P))
    analytic = (4778 / 250) * (1 + (6 * 4 * 3) / (2 * 37))
    rows.append(("adaptation/closed_form", 0.0, f"ratio={analytic:.1f}x"))

    # our world: onboarding the 4 unseen models cost = anchor passes only
    n_anchor = len(bundle.library.anchor_set)
    n_train = len(bundle.data.train_qids)
    fb2, fs2, r2 = flops_ratio(N_tr=n_train, K=n_anchor)
    rows.append(("adaptation/worldsim_scale", 0.0,
                 f"train_queries={n_train};anchors={n_anchor};"
                 f"ratio={r2:.1f}x"))

    # serving-path adaptation: onboard one unseen model onto an already-
    # served query set — the prediction cache cuts the estimator work from
    # O(Q x M) to O(Q) (measured, not analytic)
    import time

    from repro.api import RouteRequest

    engine = bundle.engine(bundle.seen)
    qids = bundle.data.test_qids[:40]
    queries = [bundle.data.queries[int(q)] for q in qids]
    t0 = time.perf_counter()
    cold = engine.predict(RouteRequest(queries))
    t_full = time.perf_counter() - t0
    engine.onboard(bundle.world, bundle.unseen[0])
    t0 = time.perf_counter()
    incr = engine.predict(RouteRequest(queries))
    t_incr = time.perf_counter() - t0
    # work ratio (estimator pairs / Eq. 24 tokens) is the honest metric:
    # wall time on the incremental pass can be dominated by one-off XLA
    # compilation for the smaller batch shape
    rows.append(("adaptation/onboard_cached", t_incr * 1e6,
                 f"pairs_full={cold.cache_misses};"
                 f"pairs_incremental={incr.cache_misses};"
                 f"work_ratio={cold.cache_misses / max(incr.cache_misses, 1):.1f}x;"
                 f"overhead_tok_full={int(cold.pred_overhead.sum())};"
                 f"overhead_tok_incr={int(incr.pred_overhead.sum())};"
                 f"full_ms={t_full * 1e3:.1f};incr_ms={t_incr * 1e3:.1f}"))
    return rows
