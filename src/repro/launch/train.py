"""End-to-end SCOPE estimator training driver.

Runs the paper's full three-stage pipeline on the world simulator:
  1. fingerprint the seen pool on the anchor set,
  2. SFT via hindsight distillation,
  3. GRPO with the gated composite reward,
then evaluates predictive accuracy on the held-out split and saves a
checkpoint.

  PYTHONPATH=src python -m repro.launch.train --size tiny --sft-steps 300 \
      --grpo-steps 50 --out checkpoints/scope_tiny
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.scope_estimator import TINY
from repro.core.estimator import ReasoningEstimator
from repro.core.fingerprint import FingerprintLibrary, build_anchor_set
from repro.core.retrieval import AnchorRetriever
from repro.core import serialization
from repro.core.evaluation import predictive_metrics
from repro.data.datasets import build_scope_data, stratified_anchors
from repro.data.worldsim import World
from repro.models import model as M
from repro.training import checkpoint
from repro.training.grpo import GRPOConfig, GRPOTrainer
from repro.training.optimizer import AdamWConfig
from repro.training.sft import build_sft_dataset, train_sft


def estimator_config(size: str):
    if size == "tiny":
        return TINY
    if size == "100m":
        return dataclasses.replace(
            TINY, name="scope-100m", num_layers=12, d_model=768, num_heads=12,
            num_kv_heads=4, head_dim=64, d_ff=2304)
    if size == "qwen3-4b":
        return get_config("scope-qwen3-4b")
    raise ValueError(size)


def build_world(n_queries: int, n_anchors: int, seed: int):
    world = World(seed=seed)
    seen = [m.name for m in world.pool if m.seen]
    data = build_scope_data(world, n_queries=n_queries, seed=seed)
    aset = build_anchor_set(world, stratified_anchors(world, n=n_anchors,
                                                      seed=seed + 7))
    lib = FingerprintLibrary(aset)
    for m in seen:
        lib.onboard(world, m, seed=seed + 13)
    retr = AnchorRetriever(aset)
    return world, data, lib, retr


def evaluate(cfg, params, data, lib, retr, *, k=5, n_eval=64, cot=True):
    world = data.world
    est = ReasoningEstimator(cfg, params, cot=cot)
    qids = data.test_qids[:n_eval]
    queries = [data.queries[q] for q in qids]
    embs = np.stack([world.embed(q) for q in queries])
    sims, idx = retr.retrieve(embs, k)
    mi = {m: i for i, m in enumerate(data.models)}
    prompts, gts, doms = [], [], []
    for qi, q in enumerate(queries):
        for m in data.models:
            prompts.append(serialization.serialize_prompt(
                world.models[m], mi[m], lib.anchor_set, lib.get(m),
                sims[qi], idx[qi], q))
            r = data.record(q.qid, m)
            gts.append((r.y, r.tokens))
            doms.append(q.domain)
    preds = est.predict(prompts)
    y_hat = np.array([p.y_hat for p in preds])
    len_hat = np.array([p.len_hat for p in preds])
    y_gt = np.array([g[0] for g in gts])
    len_gt = np.array([g[1] for g in gts])
    m = predictive_metrics(y_hat, y_gt, len_hat, len_gt, np.array(doms))
    m["well_formed"] = float(np.mean([p.well_formed for p in preds]))
    m["mean_pred_tokens"] = float(np.mean([p.pred_tokens for p in preds]))
    return m


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny",
                    choices=["tiny", "100m", "qwen3-4b"])
    ap.add_argument("--queries", type=int, default=800)
    ap.add_argument("--anchors", type=int, default=250)
    ap.add_argument("--sft-steps", type=int, default=300)
    ap.add_argument("--sft-examples", type=int, default=4000)
    ap.add_argument("--grpo-steps", type=int, default=40)
    ap.add_argument("--no-cot", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    t0 = time.time()
    cfg = estimator_config(args.size)
    cot = not args.no_cot
    world, data, lib, retr = build_world(args.queries, args.anchors,
                                         args.seed)
    print(f"[{time.time()-t0:6.1f}s] world ready: "
          f"{len(data.queries)} queries x {len(data.models)} models")

    ds = build_sft_dataset(data, lib, retr, cot=cot,
                           max_examples=args.sft_examples, seed=args.seed)
    print(f"[{time.time()-t0:6.1f}s] SFT dataset {ds['tokens'].shape}")
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    params, losses = train_sft(params, cfg, ds, steps=args.sft_steps,
                               batch_size=64, verbose=True)
    print(f"[{time.time()-t0:6.1f}s] SFT done: loss "
          f"{np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f}")

    if args.grpo_steps > 0:
        trainer = GRPOTrainer(cfg, params, data, lib, retr,
                              gcfg=GRPOConfig(), cot=cot, seed=args.seed)
        trainer.train(args.grpo_steps, verbose=True)
        params = trainer.params
        hist = trainer.reward_history
        print(f"[{time.time()-t0:6.1f}s] GRPO done: reward "
              f"{np.mean(hist[:5]):.3f} -> {np.mean(hist[-5:]):.3f}")

    metrics = evaluate(cfg, params, data, lib, retr, cot=cot)
    print(f"[{time.time()-t0:6.1f}s] eval: "
          + json.dumps({k: round(v, 4) for k, v in metrics.items()
                        if not k.startswith(("acc_d", "mae_d"))}))
    if args.out:
        checkpoint.save(args.out, params)
        print(f"checkpoint -> {args.out}.npz")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
