"""Post-SPMD HLO analysis with while-loop (scan) trip-count scaling.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically), so a 94-layer scanned model under-reports FLOPs ~94x.  This
module re-derives roofline terms from ``compiled.as_text()``:

  * matmul FLOPs from every ``dot`` (output elems x contraction size x 2),
  * HBM traffic from the I/O of post-fusion ops (fusion boundaries ~= HBM
    materialization points),
  * collective bytes per op kind,

each scaled by the enclosing scans' trip counts, which are recovered from
the loop-condition computations' integer constants.  All numbers are
PER-DEVICE (post-partitioning shapes).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
                "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose inputs/outputs approximate HBM traffic in post-fusion HLO
_IO_OPS = set(COLLECTIVES) | {
    "fusion", "dot", "convolution", "copy", "dynamic-slice",
    "dynamic-update-slice", "scatter", "gather", "transpose", "reduce",
    "reduce-window", "concatenate", "slice", "pad", "convert", "broadcast",
    "select-and-scatter", "sort", "reverse", "custom-call",
}


def _shapes_in(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((dt, dims))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shapes_in(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class Instruction:
    __slots__ = ("name", "type_str", "op", "rest")

    def __init__(self, name, type_str, op, rest):
        self.name = name
        self.type_str = type_str
        self.op = op
        self.rest = rest


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\((.*)$")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")


def parse_hlo(text: str):
    comps: Dict[str, List[Instruction]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in text.splitlines():
        hm = _HEADER_RE.match(line)
        if hm:
            cur = hm.group(2)
            comps[cur] = []
            if hm.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if im:
            comps[cur].append(Instruction(im.group(1), im.group(2),
                                          im.group(3), im.group(4)))
    return comps, entry


def analyze(text: str) -> Dict:
    comps, entry = parse_hlo(text)

    symbols: Dict[str, Dict[str, str]] = {
        c: {i.name: i.type_str for i in instrs}
        for c, instrs in comps.items()
    }

    def fused_param_reads(fname: str) -> Dict[int, int]:
        """Actual read bytes per fusion parameter: a parameter consumed
        only through dynamic-slice/gather reads just the slice."""
        reads: Dict[int, int] = {}
        if fname not in comps:
            return reads
        pnames: Dict[str, int] = {}
        for ins in comps[fname]:
            if ins.op == "parameter":
                m = re.match(r"(\d+)", ins.rest)
                if m:
                    pnames[ins.name] = int(m.group(1))
        for pname, idx in pnames.items():
            full = _type_bytes(symbols[fname].get(pname, ""))
            slice_b = None
            sliced_only = True
            for ins in comps[fname]:
                if ins.op == "parameter":
                    continue
                if re.search(r"%" + re.escape(pname) + r"\b", ins.rest):
                    if ins.op in ("dynamic-slice", "gather"):
                        b = _type_bytes(ins.type_str)
                        slice_b = b if slice_b is None else max(slice_b, b)
                    else:
                        sliced_only = False
            if sliced_only and slice_b is not None:
                reads[idx] = slice_b
            else:
                reads[idx] = full
        return reads

    def comp_direct(cname: str):
        flops = 0.0
        io_bytes = 0.0
        coll = {c: 0.0 for c in COLLECTIVES}
        coll_n = {c: 0 for c in COLLECTIVES}
        whiles: List[Tuple[str, Optional[str]]] = []
        syms = symbols[cname]
        for ins in comps[cname]:
            if ins.op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                if bm:
                    whiles.append((bm.group(1), cm.group(1) if cm else None))
                continue
            if ins.op == "dot":
                out_elems = 1
                shapes = _shapes_in(ins.type_str)
                if shapes:
                    for d in shapes[0][1]:
                        out_elems *= d
                arg = re.search(r"%([\w.\-]+)", ins.rest)
                contract = 1
                cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
                if arg and cd and arg.group(1) in syms:
                    lhs_shapes = _shapes_in(syms[arg.group(1)])
                    if lhs_shapes:
                        dims = lhs_shapes[0][1]
                        for ax in (cd.group(1).split(",") if cd.group(1) else []):
                            ia = int(ax)
                            if ia < len(dims):
                                contract *= dims[ia]
                flops += 2.0 * out_elems * contract
            if ins.op in _IO_OPS:
                out_b = _type_bytes(ins.type_str)
                args_part = ins.rest.split("),")[0]
                arg_names = [am.group(1) for am in
                             re.finditer(r"%([\w.\-]+)", args_part)]
                arg_b = [(_type_bytes(syms[a]) if a in syms else 0)
                         for a in arg_names]
                # per-op HBM policy: sliced reads/writes touch only the
                # slice, not the buffer they index into
                if ins.op in ("dynamic-slice", "gather"):
                    b = 2 * out_b
                elif ins.op == "dynamic-update-slice":
                    upd = arg_b[1] if len(arg_b) > 1 else out_b
                    b = 2 * upd
                elif ins.op == "scatter":
                    upd = arg_b[-1] if arg_b else out_b
                    b = 2 * upd
                elif ins.op in ("broadcast", "iota"):
                    b = out_b
                elif ins.op in COLLECTIVES:
                    b = 2 * out_b
                elif ins.op == "fusion":
                    fm = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                    if fm:
                        reads = fused_param_reads(fm.group(1))
                        b = out_b + sum(
                            reads.get(i, ab)
                            for i, ab in enumerate(arg_b))
                    else:
                        b = out_b + sum(arg_b)
                else:
                    b = out_b + sum(arg_b)
                io_bytes += b
                if ins.op in COLLECTIVES:
                    coll[ins.op] += out_b
                    coll_n[ins.op] += 1
        return flops, io_bytes, coll, coll_n, whiles

    direct = {c: comp_direct(c) for c in comps}

    def trip_count(cond: Optional[str]) -> int:
        if cond is None or cond not in comps:
            return 1
        ints = []
        # constants appear in instruction text; scan raw rest strings
        for ins in comps[cond]:
            ints += [int(x) for x in re.findall(r"constant\((\d+)\)",
                                                f"{ins.op}({ins.rest}")]
        # also plain 'constant(N)' lines parse as op 'constant'
        return max(ints) if ints else 1

    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def total(cname: str, depth: int = 0):
        if cname in memo:
            return memo[cname]
        if depth > 16 or cname not in direct:
            return 0.0, 0.0, {c: 0.0 for c in COLLECTIVES}
        fl, io, coll, _, whiles = direct[cname]
        fl_t, io_t, coll_t = fl, io, dict(coll)
        for body, cond in whiles:
            t = trip_count(cond)
            bf, bio, bcoll = total(body, depth + 1)
            fl_t += bf * t
            io_t += bio * t
            for c in COLLECTIVES:
                coll_t[c] += bcoll[c] * t
        memo[cname] = (fl_t, io_t, coll_t)
        return memo[cname]

    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c]))
    fl, io, coll = total(entry)
    counts = {c: sum(direct[b][3][c] for b in direct) for c in COLLECTIVES}
    return {
        "flops": fl,
        "hbm_bytes": io,
        "collective_bytes": coll,
        "collective_total_bytes": float(sum(coll.values())),
        "collective_op_counts": counts,
        "entry": entry,
    }
