"""ShapeDtypeStruct input specs + step functions for the dry-run.

``input_specs(cfg, shape)`` follows the brief: weak-type-correct, shardable
stand-ins, no device allocation.  Decode shapes lower ``serve_step`` (ONE
token against a seq_len cache); train/prefill lower the full sequence.
Audio/VLM stub frontends surface here as precomputed embedding inputs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (
    INPUT_SHAPES, InputShape, ModelConfig, long_context_variant,
    shape_applicable)
from repro.models import model as M
from repro.models.common import dtype_of
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def resolved_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    if shape.name == "long_500k":
        return long_context_variant(cfg)
    return cfg


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    act_dt = dtype_of(cfg.dtype)
    if shape.mode in ("train", "prefill"):
        batch: Dict[str, Any] = {"tokens": _sds((b, s), jnp.int32)}
        if shape.mode == "train":
            batch["labels"] = _sds((b, s), jnp.int32)
        if cfg.is_encoder_decoder:
            batch["enc_features"] = _sds((b, cfg.encoder_seq_len, cfg.d_model),
                                         act_dt)
        if cfg.num_stub_patches > 0:
            batch["image_embeds"] = _sds((b, cfg.num_stub_patches, cfg.d_model),
                                         act_dt)
        if cfg.rope_kind == "mrope":
            batch["positions_3d"] = _sds((3, b, s), jnp.int32)
        return {"batch": batch}
    # decode: ONE new token + caches holding seq_len entries
    token = _sds((b, 1), jnp.int32)
    caches = jax.eval_shape(lambda: M.init_cache(cfg, b, s))
    return {"token": token, "caches": caches}


def abstract_params(cfg: ModelConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: M.init_params(k, cfg), key)


def abstract_opt_state(params_shapes):
    return jax.eval_shape(adamw_init, params_shapes)


# ---------------------------------------------------------------------------
# Step functions (what actually lowers)
# ---------------------------------------------------------------------------
def _split_microbatches(batch, m: int):
    """Reshape every batch leaf to (m, b/m, ...); positions_3d batches on
    axis 1."""
    out = {}
    for k, v in batch.items():
        if k == "positions_3d":
            b = v.shape[1]
            out[k] = v.reshape(v.shape[0], m, b // m, *v.shape[2:]
                               ).swapaxes(0, 1)
        else:
            b = v.shape[0]
            out[k] = v.reshape(m, b // m, *v.shape[1:])
    return out


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None,
                    microbatches: int = 1):
    """Train step with optional gradient accumulation.

    ``microbatches`` > 1 scans over batch slices accumulating f32 grads
    (sharded like the params, so the accumulator is tiny) — the standard
    lever for fitting large-activation train steps into HBM; the dry-run
    auto-doubles it until memory_analysis() fits the 16 GB chip budget.
    """
    if opt_cfg is None:
        opt_cfg = AdamWConfig()

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
        else:
            mb = _split_microbatches(batch, microbatches)

            def body(carry, mbatch):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(
                    lambda p: M.loss_fn(p, cfg, mbatch), has_aux=True)(params)
                gsum = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        params, opt_state = adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, loss
    return train_step


def make_prefill_step(cfg: ModelConfig, microbatches: int = 1):
    def prefill_step(params, batch):
        if microbatches == 1:
            logits, _ = M.forward_train(params, cfg, batch)
            return logits
        mb = _split_microbatches(batch, microbatches)

        def body(_, mbatch):
            logits, _ = M.forward_train(params, cfg, mbatch)
            return None, logits

        _, out = jax.lax.scan(body, None, mb)
        return out.reshape(-1, *out.shape[2:])
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, token, caches):
        # the new token lands at the last cache slot (cache holds seq_len)
        pos = _cache_capacity(caches) - 1
        logits, new_caches = M.decode_step(params, cfg, token, caches, pos)
        return logits, new_caches
    return serve_step


def _cache_capacity(caches) -> int:
    """Max sequence capacity across KV leaves (static)."""
    best = 1
    for path, leaf in jax.tree_util.tree_flatten_with_path(caches)[0]:
        name = str(getattr(path[-1], "key", ""))
        if name in ("k", "v") and leaf.ndim == 5:
            best = max(best, leaf.shape[3])
        if name in ("c_kv", "k_rope") and leaf.ndim == 4:
            best = max(best, leaf.shape[2])
    return best
