"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers.

NOTE: do not import ``repro.launch.dryrun`` from library code — it forces
XLA_FLAGS=--xla_force_host_platform_device_count=512 at import time.
"""
