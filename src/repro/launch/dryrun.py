"""Multi-pod dry-run: lower + compile every (arch, input-shape, mesh) combo.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices back the production meshes; ``.lower().compile()`` must
succeed, and the compiled artifact yields the roofline terms:

  * ``cost_analysis()``   -> HLO FLOPs / bytes
  * ``memory_analysis()`` -> per-device footprint (falls back to an
    analytic parameter+optimizer+cache estimate on backends that return
    nothing)
  * collective bytes      -> parsed from the post-SPMD HLO, with while-loop
    (scan) trip counts recovered from loop-condition constants so per-layer
    collectives are counted per iteration.

Usage:
  python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k \
      [--multi-pod] [--out artifacts/foo.json]
  python -m repro.launch.dryrun --all [--multi-pod]
"""
# The VERY FIRST action before ANY jax import: force 512 placeholder
# devices so jax.make_mesh can build the production meshes.  This is why
# this module must not be imported by tests/benchmarks (they need 1 device).
import os  # noqa: E402
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import sys
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch import hlo_analysis
from repro.configs.base import shape_applicable
from repro.distributed import sharding as shd
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models.common import activation_mesh
from repro.training.optimizer import AdamWState

# ---------------------------------------------------------------------------
# Single-combo dry run
# ---------------------------------------------------------------------------
def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            block_q: int = 0, verbose: bool = True) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    base_cfg = get_config(arch)
    ok, reason = shape_applicable(base_cfg, shape)
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.mode,
    }
    if not ok:
        result["status"] = "skipped"
        result["reason"] = reason
        return result

    cfg = S.resolved_config(base_cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = shd.activation_rules(mesh)

    t0 = time.time()
    params_sh = S.abstract_params(cfg)
    pspecs = shd.param_specs(mesh, params_sh)
    ns = lambda spec: NamedSharding(mesh, spec)  # noqa: E731
    p_shardings = jax.tree.map(ns, pspecs,
                               is_leaf=lambda x: isinstance(x, P))

    inputs = S.input_specs(cfg, shape)
    HBM_BUDGET = 15.5e9                  # 16 GB/chip minus headroom
    microbatches = 1
    with activation_mesh(mesh, rules):
        if shape.mode == "train":
            opt_sh = S.abstract_opt_state(params_sh)
            ospecs = AdamWState(step=P(), mu=pspecs, nu=pspecs)
            o_shardings = jax.tree.map(ns, ospecs,
                                       is_leaf=lambda x: isinstance(x, P))
            bspecs = shd.batch_specs(mesh, inputs["batch"])
            b_shardings = {k: ns(v) for k, v in bspecs.items()}
            # auto-fit: double gradient-accumulation microbatches until the
            # compiled step fits the per-chip HBM budget
            while True:
                step = S.make_train_step(cfg, microbatches=microbatches)
                # scopelint: allow[recompile-hazard] -- AOT auto-fit: each pass compiles a different microbatch count on purpose
                jitted = jax.jit(step,
                                 in_shardings=(p_shardings, o_shardings,
                                               b_shardings),
                                 out_shardings=(p_shardings, o_shardings,
                                                None))
                lowered = jitted.lower(params_sh, opt_sh, inputs["batch"])
                compiled_try = lowered.compile()
                ma_try = compiled_try.memory_analysis()
                temp = getattr(ma_try, "temp_size_in_bytes", 0) if ma_try else 0
                if temp <= HBM_BUDGET or microbatches >= 16:
                    break
                microbatches *= 2
        elif shape.mode == "prefill":
            bspecs = shd.batch_specs(mesh, inputs["batch"])
            b_shardings = {k: ns(v) for k, v in bspecs.items()}
            while True:
                step = S.make_prefill_step(cfg, microbatches=microbatches)
                # scopelint: allow[recompile-hazard] -- AOT auto-fit: each pass compiles a different microbatch count on purpose
                jitted = jax.jit(step,
                                 in_shardings=(p_shardings, b_shardings))
                lowered = jitted.lower(params_sh, inputs["batch"])
                compiled_try = lowered.compile()
                ma_try = compiled_try.memory_analysis()
                temp = getattr(ma_try, "temp_size_in_bytes", 0) if ma_try else 0
                if temp <= HBM_BUDGET or microbatches >= 16:
                    break
                microbatches *= 2
        else:  # decode
            cspecs = shd.cache_specs(mesh, inputs["caches"])
            c_shardings = jax.tree.map(ns, cspecs,
                                       is_leaf=lambda x: isinstance(x, P))
            da = shd.data_axes(mesh)
            tok_spec = ns(P(shd._fit(mesh, shape.global_batch, da), None))
            step = S.make_serve_step(cfg)
            jitted = jax.jit(step,
                             in_shardings=(p_shardings, tok_spec,
                                           c_shardings),
                             out_shardings=(None, c_shardings),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_sh, inputs["token"],
                                   inputs["caches"])
        t_lower = time.time() - t0

        t1 = time.time()
        compiled = (compiled_try if shape.mode in ("train", "prefill")
                    else lowered.compile())
        t_compile = time.time() - t1

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    mem: Dict[str, Any] = {}
    if ma is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = int(v)

    hlo = compiled.as_text()
    ana = hlo_analysis.analyze(hlo)

    # analytic per-device parameter bytes (sanity reference)
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(params_sh))
    # roofline terms (per device; TPU v5e constants)
    PEAK_FLOPS = 197e12          # bf16 / chip
    HBM_BW = 819e9               # B/s
    LINK_BW = 50e9               # B/s per ICI link
    terms = {
        "compute_s": ana["flops"] / PEAK_FLOPS,
        "memory_s": ana["hbm_bytes"] / HBM_BW,
        "collective_s": ana["collective_total_bytes"] / LINK_BW,
    }
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]
                              if k.endswith("_s") else -1)

    result.update({
        "status": "ok",
        "microbatches": microbatches,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "num_params": int(n_params),
        "cost_analysis_flops_unscaled": float(ca.get("flops", 0.0)),
        "hlo_flops_per_device": ana["flops"],
        "hlo_hbm_bytes_per_device": ana["hbm_bytes"],
        "collectives": {
            "bytes_per_op": ana["collective_bytes"],
            "total_bytes": ana["collective_total_bytes"],
            "op_counts": ana["collective_op_counts"],
        },
        "memory_analysis": mem,
        "roofline": terms,
        "num_devices": int(np.prod(mesh.devices.shape)),
    })
    if verbose:
        print(f"[{arch} x {shape_name} x {result['mesh']}] OK "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"flops/dev={ana['flops']:.3e} "
              f"hbm/dev={ana['hbm_bytes']:.3e}B "
              f"coll/dev={ana['collective_total_bytes']:.3e}B "
              f"temp={mem.get('temp_size_in_bytes', 0)/1e9:.1f}GB "
              f"bottleneck={terms['bottleneck']}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    results = []
    if args.all:
        combos = [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape
        combos = [(args.arch, args.shape)]
    for arch, shape in combos:
        try:
            results.append(run_one(arch, shape, multi_pod=args.multi_pod))
        except Exception as e:  # noqa: BLE001
            print(f"[{arch} x {shape}] FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
            results.append({"arch": arch, "shape": shape, "status": "failed",
                            "error": f"{type(e).__name__}: {str(e)[:500]}"})
    if args.out:
        import os as _os
        _os.makedirs(_os.path.dirname(_os.path.abspath(args.out)),
                     exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
    bad = [r for r in results if r["status"] == "failed"]
    print(f"dry-run: {len(results)} combos, {len(bad)} failed")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
