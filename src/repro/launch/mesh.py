"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the pod axis
composes with data for batch/FSDP sharding (pure DP across the inter-pod
links, TP kept inside a pod).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the real local device (tests/examples)."""
    n = jax.local_device_count()
    return jax.make_mesh((n, 1), ("data", "model"))


def make_serve_mesh(n_data: int = 0, n_model: int = 1):
    """Serve-time mesh: data-parallel by default, TP optional.

    The streaming serve path shards request microbatches (and FSDP-shards
    estimator params) across ``data``; the estimator is small enough that
    ``model`` usually stays 1.  ``n_data=0`` takes every local device —
    on CPU, tests and benchmarks multiply devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before
    the first jax import).
    """
    n = n_data or max(1, jax.local_device_count() // n_model)
    return jax.make_mesh((n, n_model), ("data", "model"))
