"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the pod axis
composes with data for batch/FSDP sharding (pure DP across the inter-pod
links, TP kept inside a pod).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the real local device (tests/examples)."""
    n = jax.local_device_count()
    return jax.make_mesh((n, 1), ("data", "model"))
