"""SCOPE routing service driver.

Loads (or quickly trains) an estimator, fingerprints the pool — including
the unseen OOD models, which need NO retraining — and serves a batch of
queries at a chosen alpha or under a set-level budget.

  PYTHONPATH=src python -m repro.launch.serve --alpha 0.6
  PYTHONPATH=src python -m repro.launch.serve --budget 0.5 --ood
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.core.estimator import ReasoningEstimator
from repro.core.router import ScopeRouter
from repro.data.datasets import build_scope_data
from repro.launch.train import build_world, estimator_config
from repro.models import model as M
from repro.serving.router_service import RouterService
from repro.training import checkpoint
from repro.training.sft import build_sft_dataset, train_sft


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--alpha", type=float, default=None)
    ap.add_argument("--budget", type=float, default=None)
    ap.add_argument("--ood", action="store_true",
                    help="route over the unseen (OOD) model pool")
    ap.add_argument("--queries", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.alpha is None and args.budget is None:
        args.alpha = 0.6

    cfg = estimator_config(args.size)
    world, data, lib, retr = build_world(600, 250, args.seed)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.checkpoint:
        params = checkpoint.load(args.checkpoint, params)
    else:
        print("no checkpoint given - quick SFT bootstrap...")
        ds = build_sft_dataset(data, lib, retr, max_examples=3000,
                               seed=args.seed)
        params, _ = train_sft(params, cfg, ds, steps=250, batch_size=64)

    if args.ood:
        pool = [m.name for m in world.pool if not m.seen]
        # training-free onboarding: fingerprints only, no weight updates
        for m in pool:
            if m not in lib:
                lib.onboard(world, m, seed=args.seed + 99)
        data = build_scope_data(world, n_queries=300, models=pool,
                                seed=args.seed + 1, difficulty_shift=0.9)
    else:
        pool = data.models

    est = ReasoningEstimator(cfg, params)
    router = ScopeRouter(est, retr, lib, world.models,
                         {m: i for i, m in enumerate(pool)})
    service = RouterService(router, data, pool)
    qids = data.test_qids[: args.queries]
    report = service.serve(qids, alpha=args.alpha, budget=args.budget)
    print(json.dumps({
        "alpha": report.alpha,
        "accuracy": report.accuracy,
        "total_cost_usd": round(report.total_cost, 4),
        "exec_tokens": report.exec_tokens,
        "prediction_overhead_tokens": report.overhead_tokens,
        "portfolio": {k: round(v, 3) for k, v in
                      report.per_model_share.items() if v > 0},
    }, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
