"""SCOPE routing service driver on the ``repro.api`` surface.

Loads (or quickly trains) an estimator, assembles a ``ScopeEngine``,
fingerprints the pool — including the unseen OOD models, which need NO
retraining — and serves a batch of queries under a chosen routing policy.

  PYTHONPATH=src python -m repro.launch.serve --alpha 0.6
  PYTHONPATH=src python -m repro.launch.serve --budget 0.5 --ood
  PYTHONPATH=src python -m repro.launch.serve --accuracy-floor 0.7
  PYTHONPATH=src python -m repro.launch.serve --cost-ceiling 0.002
  PYTHONPATH=src python -m repro.launch.serve --stream-ticks 6 --mesh
  PYTHONPATH=src python -m repro.launch.serve --stream-ticks 12 \
      --max-queue-ms 5 --min-fill 0.5
  PYTHONPATH=src python -m repro.launch.serve --stream-ticks 12 \
      --refill --segment-len 4
  PYTHONPATH=src python -m repro.launch.serve --stream-ticks 12 \
      --refill --kv-paged --kv-page-size 16
  PYTHONPATH=src python -m repro.launch.serve --stream-ticks 12 \
      --max-pending 2
  PYTHONPATH=src python -m repro.launch.serve --stream-ticks 12 \
      --chaos 0 --max-retries 2 --deadline-ms 500
  PYTHONPATH=src python -m repro.launch.serve --stream-ticks 12 \
      --tier0 --escalation-threshold 0.9
  PYTHONPATH=src python -m repro.launch.serve --stream-ticks 12 \
      --drift-detect --drift-threshold 5.0
  PYTHONPATH=src python -m repro.launch.serve --stream-ticks 12 \
      --refill --hot-swap
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.api import (
    AccuracyFloorPolicy, CostCeilingPolicy, EngineConfig, FixedAlphaPolicy,
    ScopeEngine, SetBudgetPolicy)
from repro.core.estimator import ReasoningEstimator
from repro.data.datasets import build_scope_data
from repro.launch.train import build_world, estimator_config
from repro.models import model as M
from repro.training import checkpoint
from repro.training.sft import build_sft_dataset, train_sft


def pick_policy(args):
    if args.budget is not None:
        return SetBudgetPolicy(args.budget)
    if args.accuracy_floor is not None:
        return AccuracyFloorPolicy(args.accuracy_floor)
    if args.cost_ceiling is not None:
        return CostCeilingPolicy(
            args.cost_ceiling,
            alpha=args.alpha if args.alpha is not None else 0.6)
    return FixedAlphaPolicy(args.alpha if args.alpha is not None else 0.6)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="tiny")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--alpha", type=float, default=None)
    ap.add_argument("--budget", type=float, default=None,
                    help="set-level $ budget (SetBudgetPolicy)")
    ap.add_argument("--accuracy-floor", type=float, default=None,
                    help="expected-accuracy floor (AccuracyFloorPolicy)")
    ap.add_argument("--cost-ceiling", type=float, default=None,
                    help="per-query $ cap (CostCeilingPolicy)")
    ap.add_argument("--ood", action="store_true",
                    help="route over the unseen (OOD) model pool")
    ap.add_argument("--queries", type=int, default=48)
    ap.add_argument("--stream-ticks", type=int, default=0,
                    help="serve as N streaming traffic ticks through the "
                         "bucketed microbatch scheduler (0 = one batch)")
    ap.add_argument("--max-queue-ms", type=float, default=None,
                    help="deadline flush: emit a partially-filled bucket "
                         "rather than queue a prompt longer than this")
    ap.add_argument("--min-fill", type=float, default=0.0,
                    help="occupancy flush: emit once a length queue covers "
                         "this fraction of the largest batch bucket")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable double-buffered dispatch (synchronous "
                         "microbatch execution)")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="pipelining depth: microbatches in flight before "
                         "the oldest is block-parsed (default 1 with "
                         "overlap, 0 without; 2 interleaves prefill of "
                         "N+1 with decode of N on real accelerators)")
    ap.add_argument("--refill", action="store_true",
                    help="segment-chunked continuous batching: refill "
                         "drained-at-EOS decode slots from the queue "
                         "between scan segments instead of retiring "
                         "microbatches whole")
    ap.add_argument("--segment-len", type=int, default=None,
                    help="decode steps per scan segment in --refill mode "
                         "(default 4; drained slots admit new prompts at "
                         "segment boundaries)")
    ap.add_argument("--kv-paged", action="store_true",
                    help="block-paged decode KV cache (--refill only): "
                         "pool-backed pages instead of a dense per-slot "
                         "horizon — KV memory scales with live tokens and "
                         "admission gates on free pages")
    ap.add_argument("--kv-page-size", type=int, default=16,
                    help="token positions per KV page in --kv-paged mode "
                         "(default 16; smaller pages = less last-page "
                         "waste, bigger page tables)")
    ap.add_argument("--kv-pool-pages", type=int, default=None,
                    help="KV pool size in pages in --kv-paged mode "
                         "(default: auto-size each slot state to its "
                         "bucket's dense worst case)")
    ap.add_argument("--mesh", action="store_true",
                    help="shard the estimator over the local serve mesh "
                         "(multiply CPU devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="failed microbatch/segment rows are requeued and "
                         "retried up to this many times before quarantine")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLO: a prompt older than this "
                         "(queued + in flight) is answered immediately in "
                         "degraded mode from retrieval priors")
    ap.add_argument("--no-degrade", action="store_true",
                    help="mark quarantined/expired pairs FAILED instead of "
                         "answering them from retrieval priors")
    ap.add_argument("--chaos", type=int, default=None,
                    help="inject a deterministic fault plan seeded with "
                         "this value (FaultPlan.seeded: dispatch/segment/"
                         "parse/pool failures at ~10%% rates) into the "
                         "stream — requires --stream-ticks")
    ap.add_argument("--tier0", action="store_true",
                    help="two-tier routing: distill a tier-0 pre-router "
                         "head from the estimator and answer high-"
                         "confidence (query, model) pairs in one jitted "
                         "forward; only the rest pay the reasoning decode")
    ap.add_argument("--escalation-threshold", type=float, default=0.9,
                    help="tier-0 confidence max(p, 1-p) below which a pair "
                         "escalates to the reasoning decode (<= 0.5 "
                         "escalates nothing, > 1.0 escalates everything)")
    ap.add_argument("--tier0-steps", type=int, default=300,
                    help="distillation steps for the --tier0 head")
    ap.add_argument("--drift-detect", action="store_true",
                    help="self-healing serving: record every executed "
                         "(predicted, observed) outcome in a replay buffer, "
                         "run a per-model Page-Hinkley drift detector over "
                         "the calibration residuals, quarantine alarmed "
                         "models (DriftAwarePolicy routes around them) "
                         "until onboard(refresh=True) heals them")
    ap.add_argument("--drift-threshold", type=float, default=5.0,
                    help="Page-Hinkley alarm mass for --drift-detect "
                         "(residual mass a model must accumulate above its "
                         "running mean before the alarm fires; default 5.0)")
    ap.add_argument("--hot-swap", action="store_true",
                    help="demo a live estimator hot-swap halfway through "
                         "the stream: donate the params under a bumped "
                         "estimator_version at a tick boundary — in-flight "
                         "rows finish on the old params, queued rows "
                         "dispatch on the new, the prediction cache and "
                         "stale tier-0 stashes invalidate for free — "
                         "requires --stream-ticks")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = estimator_config(args.size)
    world, data, lib, retr = build_world(600, 250, args.seed)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.checkpoint:
        params = checkpoint.load(args.checkpoint, params)
    else:
        print("no checkpoint given - quick SFT bootstrap...")
        ds = build_sft_dataset(data, lib, retr, max_examples=3000,
                               seed=args.seed)
        params, _ = train_sft(params, cfg, ds, steps=250, batch_size=64)

    if args.kv_paged and not args.refill:
        ap.error("--kv-paged requires --refill (the whole-retire runtime "
                 "keeps dense per-microbatch caches)")
    if args.kv_page_size < 1:
        ap.error(f"--kv-page-size must be >= 1, got {args.kv_page_size}")

    if args.hot_swap and args.stream_ticks <= 0:
        ap.error("--hot-swap requires --stream-ticks (the swap lands at a "
                 "live tick boundary)")

    fault_plan = None
    if args.chaos is not None:
        if args.stream_ticks <= 0:
            ap.error("--chaos requires --stream-ticks (faults are injected "
                     "at the streaming serve boundaries)")
        from repro.serving.faults import FaultPlan
        fault_plan = FaultPlan.seeded(
            args.chaos, rates={"dispatch": 0.1, "segment": 0.1,
                               "parse": 0.1, "pool": 0.1})

    estimator = ReasoningEstimator(cfg, params)
    tier0_head = None
    if args.tier0:
        from repro.training.tier0 import distill_tier0
        print("distilling tier-0 pre-router from the estimator...")
        tier0_head = distill_tier0(data, lib, retr, estimator,
                                   max_pairs=3000, steps=args.tier0_steps,
                                   seed=args.seed)
        print(f"# tier-0 calibration temperature "
              f"{tier0_head.temperature:.3f}")

    engine = ScopeEngine.build(EngineConfig(
        estimator=estimator, retriever=retr,
        library=lib, models_meta={m: world.models[m] for m in data.models},
        kv_paged=args.kv_paged, kv_page_size=args.kv_page_size,
        kv_pool_pages=args.kv_pool_pages,
        max_retries=args.max_retries, deadline_ms=args.deadline_ms,
        degrade=not args.no_degrade, fault_plan=fault_plan,
        tier0=tier0_head,
        escalation_threshold=args.escalation_threshold,
        drift_detect=args.drift_detect,
        drift_threshold=args.drift_threshold))

    if args.kv_paged and args.kv_pool_pages is not None:
        # a request admitted at a boundary may decode its whole budget:
        # a pool that cannot page even a minimal such row can never admit
        seg = args.segment_len or 4
        budget = int(engine.estimator.max_new_tokens)
        budget_steps = -(-budget // seg) * seg
        min_pages = -(-(1 + budget_steps) // args.kv_page_size)
        if args.kv_pool_pages < min_pages:
            raise ValueError(
                f"--kv-pool-pages {args.kv_pool_pages} is too small to "
                f"admit a single full-budget row: a 1-token prompt "
                f"decoding {budget_steps} budget steps needs "
                f"{min_pages} pages of {args.kv_page_size} tokens")

    if args.ood:
        pool = [m.name for m in world.pool if not m.seen]
        # training-free onboarding: fingerprints only, no weight updates
        for m in pool:
            engine.onboard(world, m, seed=args.seed + 99)
        data = build_scope_data(world, n_queries=300, models=pool,
                                seed=args.seed + 1, difficulty_shift=0.9)
    else:
        pool = data.models

    if args.mesh:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh()
        engine.estimator.shard(mesh)
        print(f"# estimator sharded over "
              f"{dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))}")

    policy = pick_policy(args)
    if args.drift_detect:
        from repro.api import DriftAwarePolicy
        policy = DriftAwarePolicy(policy)
    qids = [int(q) for q in data.test_qids[: args.queries]]

    if args.stream_ticks > 0:
        from repro.serving.scheduler import MicrobatchScheduler
        sched = MicrobatchScheduler(
            max_queue_age=(None if args.max_queue_ms is None
                           else args.max_queue_ms / 1e3),
            min_fill=args.min_fill)
        chunks = [[int(q) for q in c]
                  for c in np.array_split(qids, args.stream_ticks)]
        swap_at = len(chunks) // 2 if args.hot_swap else None
        reports = []
        for i, r in enumerate(engine.serve_stream(
                data, chunks, policy, models=pool, scheduler=sched,
                overlap=not args.no_overlap, refill=args.refill,
                segment_len=args.segment_len,
                max_pending=args.max_pending)):
            reports.append(r)
            if swap_at is not None and i + 1 == swap_at:
                # live swap between ticks: same params pytree donated
                # under a bumped version — the point is the serve-path
                # machinery (cache space, dedup keys, tier-0 stashes all
                # roll over), not new weights.  A tier-0 head rides along
                # re-tempered on the replay buffer's observed outcomes.
                t0 = engine.config.tier0
                if (t0 is not None and engine.monitor is not None
                        and len(engine.monitor.buffer)):
                    from repro.training.tier0 import recalibrate_tier0
                    rows = engine.monitor.buffer.rows()
                    t0 = recalibrate_tier0(
                        t0,
                        np.asarray([o.predicted_p for o in rows]),
                        np.asarray([o.observed_y for o in rows]))
                version = engine.config.estimator_version + "+swap"
                engine.hot_swap(engine.estimator, version, tier0=t0)
                swap_at = None
                print(f"# hot-swapped estimator to {version!r} "
                      f"after tick {i + 1}")
        n = sum(r.n_queries for r in reports)
        print(json.dumps({
            "policy": policy.name,
            "ticks": [{"queries": r.n_queries,
                       "accuracy": round(r.accuracy, 3),
                       "cost_usd": round(r.total_cost, 4)}
                      for r in reports],
            "accuracy": sum(r.accuracy * r.n_queries
                            for r in reports) / max(n, 1),
            "total_cost_usd": round(sum(r.total_cost for r in reports), 4),
            "scheduler": sched.stats.as_dict(),
        }, indent=2))
        return 0

    report = engine.serve(data, qids, policy, models=pool)
    print(json.dumps({
        "policy": report.policy,
        "alpha": report.alpha,
        "accuracy": report.accuracy,
        "total_cost_usd": round(report.total_cost, 4),
        "exec_tokens": report.exec_tokens,
        "prediction_overhead_tokens": report.overhead_tokens,
        "cache": {"hits": report.cache_hits, "misses": report.cache_misses},
        "portfolio": {k: round(v, 3) for k, v in
                      report.per_model_share.items() if v > 0},
    }, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
