"""scopelint driver: scan files, apply suppressions, run the jaxpr pass."""
from __future__ import annotations

import pathlib
from typing import Iterable, List, Optional, Sequence

from repro.analysis.astpass import ModuleContext, Rule
from repro.analysis.findings import Finding

_SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules", "build",
              "dist", ".mypy_cache", ".pytest_cache"}


def all_rules() -> List[Rule]:
    from repro.analysis.rules_determinism import NondeterminismRule
    from repro.analysis.rules_host import HostSyncRule
    from repro.analysis.rules_pallas import PallasContractRule
    from repro.analysis.rules_recompile import RecompileHazardRule
    from repro.analysis.rules_sideeffect import TracedSideEffectRule
    return [HostSyncRule(), NondeterminismRule(), RecompileHazardRule(),
            TracedSideEffectRule(), PallasContractRule()]


def scan_source(source: str, path: str,
                hot_path: Optional[bool] = None) -> List[Finding]:
    """Run every applicable rule over one module's source."""
    try:
        ctx = ModuleContext(source, path, hot_path=hot_path)
    except SyntaxError as exc:
        return [Finding("parse-error", path, exc.lineno or 0, str(exc))]
    findings: List[Finding] = []
    for rule in all_rules():
        if rule.applies(ctx):
            findings.extend(rule.check(ctx))
    findings = ctx.suppressions.apply(findings)
    findings.extend(ctx.suppressions.meta_findings(path))
    return findings


def iter_py_files(paths: Sequence[str]) -> Iterable[pathlib.Path]:
    for raw in paths:
        p = pathlib.Path(raw)
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part in _SKIP_DIRS for part in f.parts):
                    yield f


def scan_paths(paths: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(scan_source(f.read_text(), str(f)))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="scopelint: static serve-path invariant checks "
                    "(AST rules + jaxpr pass)")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files/directories to scan (default: src)")
    ap.add_argument("--self-test", action="store_true",
                    help="run the rule corpus + jaxpr poison checks")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip tracing the registered hot-path executables")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            scope = "hot-path" if rule.hot_path_only else "all files"
            print(f"{rule.id:28s} [{scope}] {rule.description}")
        return 0

    failed = False

    if args.self_test:
        from repro.analysis.selftest import run_self_test
        failures = run_self_test()
        for msg in failures:
            print(f"self-test FAILED: {msg}")
        n_rules = len(all_rules())
        if failures:
            failed = True
        else:
            print(f"self-test: {n_rules} rules fire/stay-silent on their "
                  "corpus twins; jaxpr poison checks pass")

    findings = scan_paths(args.paths or ["src"])
    if not args.no_jaxpr:
        from repro.analysis import jaxpr_pass
        findings.extend(jaxpr_pass.run_jaxpr_pass())
        n_exec = len(jaxpr_pass.registered())
    else:
        n_exec = 0

    hard = [f for f in findings if not f.suppressed]
    soft = [f for f in findings if f.suppressed]
    for f in hard + soft:
        print(f.render())
    print(f"scopelint: {len(hard)} findings ({len(soft)} suppressed)"
          + (f"; jaxpr pass: {n_exec} executables traced"
             if n_exec else ""))
    if hard:
        failed = True
    return 1 if failed else 0
