"""Hot-path manifest: which modules carry serve-path invariants.

The serve hot path is everything a request touches between admission and
its routing decision: the serving package (scheduler, runtime, sampler,
KV pool, faults), the Pallas/XLA kernels, and the engine facade that
dispatches them.  Rules marked ``hot_path_only`` fire only in these
modules — a wall-clock read in ``training/`` is fine, in ``serving/`` it
is a determinism bug.

Paths are matched structurally (posix suffix under ``repro/``) so the
manifest works for both ``src/repro/...`` checkouts and installed trees.
"""
from __future__ import annotations

import pathlib

HOT_PATH_PREFIXES = (
    "repro/serving/",
    "repro/kernels/",
)
HOT_PATH_FILES = (
    "repro/api/engine.py",
    "repro/models/tier0.py",    # tier-0 pre-router: gates every request
)


def is_hot_path(path: str) -> bool:
    p = pathlib.PurePath(path).as_posix()
    # normalise to the part under the package root
    idx = p.rfind("repro/")
    if idx < 0:
        return False
    rel = p[idx:]
    if rel in HOT_PATH_FILES:
        return True
    return any(rel.startswith(pfx) for pfx in HOT_PATH_PREFIXES)
