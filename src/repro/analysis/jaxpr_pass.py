"""Layer 2: trace the registered hot-path executables and walk the jaxprs.

The AST rules see what the *source* says; this pass sees what *XLA* sees.
Each registered executable is traced with abstract inputs
(``jax.ShapeDtypeStruct`` leaves via ``jax.eval_shape`` /
``jax.make_jaxpr`` — no FLOPs, no device memory) and its closed jaxpr is
walked recursively (scan bodies, pjit sub-jaxprs, pallas kernels) for:

- **host callbacks** (``pure_callback`` / ``io_callback`` / debug
  callbacks / outfeed): a callback inside the fused decode scan would
  serialise every step on the host;
- **f64 promotions**: a ``convert_element_type`` to float64 (or any
  float64/complex128 intermediate) doubles KV bandwidth and silently
  disables TPU-native matmuls;
- **device-to-host transfers** staged into the computation
  (``device_put`` to a host memory kind).

Registry: ``register("name")(builder)`` where ``builder() -> ClosedJaxpr``.
The default registry covers the serve path's six jitted executables —
fused decode (``_scan_decode``), fused refill (``_refill_scan_decode``),
the paged segment scan (``_paged_scan_decode``, XLA and Pallas kernels),
the paged fused refill, and the tier-0 pre-router forward
(``tier0_forward``) — built over the TINY estimator config.  A
builder that *fails to trace* is itself a finding: the hot path no longer
compiles, which is worse than any primitive it might contain.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List

from repro.analysis.findings import Finding

RULE_ID = "jaxpr-forbidden-primitive"

_CALLBACK_SUBSTR = ("callback", "outside_call", "infeed", "outfeed",
                    "host_local_array")
_WIDE_DTYPES = ("float64", "complex128")

_REGISTRY: Dict[str, Callable[[], Any]] = {}


def register(name: str):
    """Register a hot-path executable builder for the jaxpr pass."""
    def deco(builder: Callable[[], Any]):
        _REGISTRY[name] = builder
        return builder
    return deco


def registered() -> Dict[str, Callable[[], Any]]:
    _ensure_defaults()
    return dict(_REGISTRY)


def iter_eqns(jaxpr):
    """All equations of a jaxpr, recursing into sub-jaxprs in params."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            yield from _iter_sub(val)


def _iter_sub(val):
    # sub-jaxprs appear as Jaxpr/ClosedJaxpr params, possibly nested in
    # containers (branches of cond/switch, pallas grid mappings)
    if hasattr(val, "eqns"):
        yield from iter_eqns(val)
    elif hasattr(val, "jaxpr") and hasattr(val.jaxpr, "eqns"):
        yield from iter_eqns(val.jaxpr)
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _iter_sub(v)


def check_closed_jaxpr(name: str, closed) -> List[Finding]:
    """Walk one executable's jaxpr for forbidden primitives/dtypes."""
    path = f"<jaxpr:{name}>"
    messages: List[str] = []
    seen = set()

    def emit(msg: str) -> None:
        if msg not in seen:
            seen.add(msg)
            messages.append(msg)

    for eqn in iter_eqns(closed.jaxpr):
        pname = eqn.primitive.name
        if any(s in pname for s in _CALLBACK_SUBSTR):
            emit(f"host callback primitive '{pname}' staged into the "
                 "executable — every step would round-trip the host")
        if pname == "convert_element_type":
            new = str(eqn.params.get("new_dtype", ""))
            if new in _WIDE_DTYPES:
                emit(f"convert_element_type to {new} — f64 promotion in "
                     "the hot path (check jax_enable_x64 leaks and numpy "
                     "scalar mixing)")
        if pname == "device_put":
            devs = eqn.params.get("devices", ()) or ()
            srcs = eqn.params.get("srcs", ()) or ()
            blob = f"{devs}{srcs}".lower()
            if "host" in blob or "pinned" in blob:
                emit(f"device_put with host memory kind ({pname}) — "
                     "transfer staged into the executable")
        for v in getattr(eqn, "outvars", ()):
            aval = getattr(v, "aval", None)
            dt = str(getattr(aval, "dtype", ""))
            if dt in _WIDE_DTYPES:
                emit(f"{dt} intermediate produced by '{pname}'")
    return [Finding(RULE_ID, path, 0, m) for m in messages]


def run_jaxpr_pass() -> List[Finding]:
    """Trace every registered executable and collect findings."""
    _ensure_defaults()
    out: List[Finding] = []
    for name, builder in sorted(_REGISTRY.items()):
        try:
            closed = builder()
        except Exception as exc:            # noqa: BLE001 - report, not die
            out.append(Finding(
                RULE_ID, f"<jaxpr:{name}>", 0,
                f"hot-path executable failed to trace: {exc!r}"))
            continue
        out.extend(check_closed_jaxpr(name, closed))
    return out


# ---------------------------------------------------------------------------
# Default registry: the serve path's jitted executables over TINY
# ---------------------------------------------------------------------------
_DEFAULTS_DONE = False


def _ensure_defaults() -> None:
    global _DEFAULTS_DONE
    if _DEFAULTS_DONE:
        return
    _DEFAULTS_DONE = True
    _register_defaults()


@functools.lru_cache(maxsize=1)
def _abstract_serve_state():
    """Abstract (shape-only) params/caches/logits for a TINY decode batch."""
    import jax
    import jax.numpy as jnp

    from repro.configs.scope_estimator import TINY
    from repro.models import model as M
    from repro.serving import sampler

    cfg = TINY
    B, L, T = 2, 8, 4
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params = jax.eval_shape(functools.partial(M.init_params, cfg=cfg), key)
    tokens = jax.ShapeDtypeStruct((B, L), jnp.int32)
    logits, caches = jax.eval_shape(
        lambda p, t: M.prefill(p, cfg, {"tokens": t}), params, tokens)
    padded = jax.eval_shape(
        lambda c: sampler._pad_caches(c, L + T, L), caches)
    last = jax.ShapeDtypeStruct((B, cfg.vocab_size), jnp.float32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    done = jax.ShapeDtypeStruct((B,), jnp.bool_)
    return {"cfg": cfg, "B": B, "L": L, "T": T, "key": key,
            "params": params, "tokens": tokens, "padded": padded,
            "last": last, "pos": pos, "done": done}


def _register_defaults() -> None:
    try:
        import jax
        import jax.numpy as jnp
    except Exception:                       # pragma: no cover - no jax
        return

    from repro.serving import sampler

    @register("fused_decode")
    def _fused_decode():
        s = _abstract_serve_state()
        cfg, T = s["cfg"], s["T"]
        fn = lambda p, lg, c, k, pos, dn: sampler._scan_decode(
            p, cfg, lg, c, k, T, 0.0, True, pos, dn)
        return jax.make_jaxpr(fn)(s["params"], s["last"], s["padded"],
                                  s["key"], s["pos"], s["done"])

    @register("fused_refill")
    def _fused_refill():
        s = _abstract_serve_state()
        cfg, B, L, T = s["cfg"], s["B"], s["L"], s["T"]
        mask = jax.ShapeDtypeStruct((B,), jnp.bool_)
        rlens = jax.ShapeDtypeStruct((B,), jnp.int32)
        fn = lambda p, lg, c, k, pos, dn, m, rp, rl: \
            sampler._refill_scan_decode(p, cfg, lg, c, k, T, 0.0, True,
                                        pos, dn, m, rp, rl)
        return jax.make_jaxpr(fn)(s["params"], s["last"], s["padded"],
                                  s["key"], s["pos"], s["done"], mask,
                                  s["tokens"], rlens)

    def _paged_state(kernel):
        from repro.serving.kv_pool import PagedSpec, _ceil_div
        s = _abstract_serve_state()
        cfg, B, L, T = s["cfg"], s["B"], s["L"], s["T"]
        page_size = 4
        kv_cap = L + T
        width = _ceil_div(kv_cap, page_size)
        n_pages_total = B * width + 1       # + trash page
        npg = _ceil_div(L, page_size)
        ids = jax.ShapeDtypeStruct((B * npg,), jnp.int32)
        _, pcaches = jax.eval_shape(
            lambda p, t, i: sampler._paged_prefill(
                p, cfg, t, n_pages_total, page_size, i),
            s["params"], s["tokens"], ids)
        spec = PagedSpec(page_size=page_size, kv_cap=kv_cap, kernel=kernel)
        table = jax.ShapeDtypeStruct((B, width), jnp.int32)
        return s, pcaches, spec, table, ids

    def _paged_builder(kernel):
        def build():
            s, pcaches, spec, table, _ = _paged_state(kernel)
            cfg, T = s["cfg"], s["T"]
            fn = lambda p, lg, c, k, tbl, pos, dn: \
                sampler._paged_scan_decode(p, cfg, lg, c, k, T, 0.0, True,
                                           spec, tbl, pos, dn)
            return jax.make_jaxpr(fn)(s["params"], s["last"], pcaches,
                                      s["key"], table, s["pos"], s["done"])
        return build

    from repro.kernels.decode_attention import KernelType
    register("paged_segment_scan")(_paged_builder(KernelType.XLA))
    register("paged_segment_scan_pallas")(_paged_builder(KernelType.PALLAS))

    @register("paged_fused_refill")
    def _paged_fused_refill():
        from repro.kernels.decode_attention import KernelType
        s, pcaches, spec, table, ids = _paged_state(KernelType.XLA)
        cfg, B, T = s["cfg"], s["B"], s["T"]
        mask = jax.ShapeDtypeStruct((B,), jnp.bool_)
        rlens = jax.ShapeDtypeStruct((B,), jnp.int32)
        fn = lambda p, lg, c, k, tbl, pos, dn, m, rp, rl, ri: \
            sampler._paged_refill_scan_decode(
                p, cfg, lg, c, k, T, 0.0, True, spec, tbl, pos, dn,
                m, rp, rl, ri)
        return jax.make_jaxpr(fn)(s["params"], s["last"], pcaches,
                                  s["key"], table, s["pos"], s["done"],
                                  mask, s["tokens"], rlens, ids)

    @register("tier0_forward")
    def _tier0_forward():
        from repro.models import tier0 as T0
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        cfg = T0.Tier0Config()
        params = jax.eval_shape(
            functools.partial(T0.init_tier0, cfg=cfg), key)
        n, K = T0.PAIR_BUCKETS[0], 5
        qf = jax.ShapeDtypeStruct((n, T0.QUERY_FEATS), jnp.float32)
        af = jax.ShapeDtypeStruct((n, K, T0.ANCHOR_FEATS), jnp.float32)
        mf = jax.ShapeDtypeStruct((n, T0.MODEL_FEATS), jnp.float32)
        mid = jax.ShapeDtypeStruct((n,), jnp.int32)
        return jax.make_jaxpr(T0.tier0_forward)(params, qf, af, mf, mid)
