"""scopelint self-test: prove every rule alive before trusting a clean run.

A static checker's worst failure mode is silence — a refactor that makes a
rule stop matching produces the same output as a healthy codebase.  So
every rule ships a corpus: ``triggers`` it must flag and ``non_triggers``
(near-identical twins) it must not.  The suppression machinery and the
jaxpr walker get the same treatment: a deliberately-poisoned toy jit
(host callback + f64 promotion) must be flagged, a clean one must not.

``run_self_test()`` returns failure messages; empty means healthy.
"""
from __future__ import annotations

from typing import List

_SELFTEST_PATH = "repro/serving/_scopelint_selftest.py"   # forces hot-path

_SUPPRESSED_SNIPPET = """\
import jax


@jax.jit
def f(x):
    return float(x)  # scopelint: allow[host-sync-in-hot-path] -- corpus
"""

_UNSUPPRESSED_TWIN = _SUPPRESSED_SNIPPET.replace(
    "  # scopelint: allow[host-sync-in-hot-path] -- corpus", "")


def run_self_test() -> List[str]:
    from repro.analysis.astpass import ModuleContext
    from repro.analysis.runner import all_rules, scan_source

    failures: List[str] = []
    for rule in all_rules():
        for i, snip in enumerate(rule.triggers):
            ctx = ModuleContext(snip, _SELFTEST_PATH, hot_path=True)
            hits = list(rule.check(ctx))
            if not hits:
                failures.append(
                    f"{rule.id}: trigger snippet #{i} produced no finding")
        for i, snip in enumerate(rule.non_triggers):
            ctx = ModuleContext(snip, _SELFTEST_PATH, hot_path=True)
            hits = list(rule.check(ctx))
            if hits:
                failures.append(
                    f"{rule.id}: non-trigger snippet #{i} false-positived: "
                    f"{hits[0].message!r}")

    # suppression machinery: the allow comment must absorb the finding...
    sup = scan_source(_SUPPRESSED_SNIPPET, _SELFTEST_PATH, hot_path=True)
    if [f for f in sup if not f.suppressed]:
        failures.append("suppression: allow[...] comment did not suppress")
    if not [f for f in sup if f.suppressed]:
        failures.append("suppression: suppressed finding not reported")
    # ...and the twin without it must fail
    raw = scan_source(_UNSUPPRESSED_TWIN, _SELFTEST_PATH, hot_path=True)
    if not [f for f in raw if not f.suppressed]:
        failures.append("suppression: unsuppressed twin produced no finding")

    failures.extend(_jaxpr_self_test())
    return failures


def _jaxpr_self_test() -> List[str]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.jaxpr_pass import check_closed_jaxpr

    failures: List[str] = []
    x = jax.ShapeDtypeStruct((4,), jnp.float32)

    def poisoned(v):
        y = jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct(v.shape, v.dtype), v)
        return y.astype(jnp.float64)

    # enable_x64 scoped to the trace so the f64 survives canonicalisation
    with jax.experimental.enable_x64():
        bad = jax.make_jaxpr(poisoned)(x)
    msgs = " ".join(f.message for f in check_closed_jaxpr("poisoned", bad))
    if "pure_callback" not in msgs:
        failures.append("jaxpr: poisoned toy jit's host callback missed")
    if "float64" not in msgs:
        failures.append("jaxpr: poisoned toy jit's f64 promotion missed")

    clean = jax.make_jaxpr(lambda v: (v * 2.0).sum())(x)
    leftover = check_closed_jaxpr("clean", clean)
    if leftover:
        failures.append(
            f"jaxpr: clean toy jit false-positived: {leftover[0].message!r}")
    return failures
