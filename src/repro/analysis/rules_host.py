"""Rule: host-sync-in-hot-path.

The serve path's latency claims (PR 2's 171x host-transfer cut, PR 5's
overlapped dispatch) rest on nothing blocking on device values mid-path.
This rule flags the classic sync idioms in hot-path modules:

- ``.item()`` / ``jax.device_get`` anywhere in a hot-path module (both
  exist only to move device values to the host);
- ``float()`` / ``int()`` / ``bool()`` / ``.tolist()`` applied to a
  *tainted* (traced) value inside a traced body — under jit these raise
  ``ConcretizationError`` at trace time, but in transitively-traced
  helpers they are latent syncs;
- ``np.asarray`` / ``np.array`` inside a traced body (numpy pulls the
  operand to the host; use ``jnp``);
- implicit ``__bool__`` of a traced value: ``if x:`` / ``while x:`` /
  ``assert x`` / ``not x`` where ``x`` is a bare tainted name.

Shape/dtype reads (``x.shape[0]``, ``len(x)``) are static under tracing
and never flagged.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astpass import ModuleContext, Rule, dotted, expr_tainted
from repro.analysis.findings import Finding

_SYNC_METHODS = frozenset({"item"})
_TRACED_SYNC_METHODS = frozenset({"item", "tolist", "to_py"})
_CONVERSIONS = frozenset({"float", "int", "bool", "complex"})
_NP_PULLS = frozenset({"np.asarray", "np.array", "numpy.asarray",
                       "numpy.array", "onp.asarray", "onp.array"})
_DEVICE_GET = frozenset({"jax.device_get", "device_get"})


class HostSyncRule(Rule):
    id = "host-sync-in-hot-path"
    description = ("device->host synchronisation (.item(), jax.device_get, "
                   "float()/np.asarray on traced values, implicit __bool__) "
                   "in hot-path modules")
    hot_path_only = True

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                yield from self._check_call(ctx, node)
            elif isinstance(node, (ast.If, ast.While)):
                yield from self._check_bool(ctx, node, node.test)
            elif isinstance(node, ast.Assert):
                yield from self._check_bool(ctx, node, node.test)
            elif isinstance(node, ast.IfExp):
                yield from self._check_bool(ctx, node, node.test)
            elif isinstance(node, ast.BoolOp):
                for v in node.values:
                    yield from self._check_bool(ctx, node, v)
            elif isinstance(node, ast.UnaryOp) and \
                    isinstance(node.op, ast.Not):
                yield from self._check_bool(ctx, node, node.operand)

    def _check_call(self, ctx: ModuleContext,
                    node: ast.Call) -> Iterator[Finding]:
        fname = dotted(node.func)
        in_trace = ctx.in_traced_body(node)
        if fname in _DEVICE_GET:
            yield ctx.finding(self.id, node,
                              "jax.device_get blocks on the device — keep "
                              "values on device or sync at parse time only")
            return
        if isinstance(node.func, ast.Attribute):
            meth = node.func.attr
            if meth in _SYNC_METHODS or \
                    (in_trace and meth in _TRACED_SYNC_METHODS):
                yield ctx.finding(
                    self.id, node,
                    f".{meth}() synchronises device->host — slice on "
                    "device and convert whole arrays at parse time")
                return
        if not in_trace:
            return
        if fname in _NP_PULLS:
            yield ctx.finding(self.id, node,
                              f"{fname} inside a traced body pulls the "
                              "operand to the host — use jnp")
            return
        if fname in _CONVERSIONS and node.args:
            fn = ctx.traced_fn(node)
            taint = ctx.tainted_names(fn.node) if fn else frozenset()
            if not isinstance(node.args[0], ast.Constant) and \
                    expr_tainted(node.args[0], taint):
                yield ctx.finding(
                    self.id, node,
                    f"{fname}() of a traced value forces a host sync "
                    "(ConcretizationError under jit)")

    def _check_bool(self, ctx: ModuleContext, node: ast.AST,
                    test: ast.AST) -> Iterator[Finding]:
        # bare tainted name (or `not name`): implicit __bool__ of a traced
        # array; comparisons on traced values are recompile-hazard's beat
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            test = test.operand
        if not isinstance(test, ast.Name):
            return
        fn = ctx.traced_fn(node)
        if fn is None:
            return
        if test.id in ctx.tainted_names(fn.node):
            yield ctx.finding(
                self.id, node,
                f"truthiness of traced value '{test.id}' calls __bool__ "
                "on an abstract array — use jnp.where / lax.cond")

    triggers = (
        """\
import jax

@jax.jit
def f(x):
    if x:
        x = x + 1
    return float(x)

def g(y):
    return y.item()
""",
        """\
import jax
import numpy as np

@jax.jit
def f(x):
    y = np.asarray(x)
    return jax.device_get(y)
""",
    )
    non_triggers = (
        """\
import functools
import jax
import jax.numpy as jnp

@functools.partial(jax.jit, static_argnums=(1,))
def f(x, n):
    if n > 0:
        x = x + int(n)
    b = x.shape[0]
    return x * b

def g(y):
    return jnp.asarray(y)
""",
        """\
import numpy as np

def host_side_parse(rows):
    lens = np.asarray(rows)
    return lens.tolist()
""",
    )
