"""Rule: serve-time-nondeterminism.

Replayability is the fault-tolerance contract from PR 7: a serve stream
re-run with the same FaultPlan must be bit-identical, which is only true
if serving code never reads a wall clock or draws fresh entropy.  Clocks
are *injected* (``MicrobatchScheduler(clock=...)``), sampling keys are
*carried* through ``DecodeState``, and ``FaultPlan.seeded`` draws its plan
once at build time.

Flags **calls** (never bare references — ``clock: Callable =
time.monotonic`` as an injectable default is the approved idiom) to:

- ``time.time/monotonic/perf_counter/...`` and ``datetime.now/utcnow``;
- stdlib ``random.*`` and ``np.random.*``;
- fresh key construction ``jax.random.PRNGKey`` / ``jax.random.key``
  (``split``/``fold_in`` on a carried key are fine).
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astpass import ModuleContext, Rule, dotted
from repro.analysis.findings import Finding

_CLOCKS = frozenset({
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "time.clock_gettime",
})
_DATETIME = frozenset({
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "date.today", "datetime.date.today",
})
_FRESH_KEYS = frozenset({
    "jax.random.PRNGKey", "jax.random.key", "jrandom.PRNGKey",
    "jrandom.key", "random.PRNGKey",
})
# stdlib random API (so `from jax import random; random.split(key)` is not
# mistaken for the stdlib module)
_STDLIB_RANDOM = frozenset({
    "seed", "random", "randint", "randrange", "choice", "choices",
    "shuffle", "sample", "uniform", "gauss", "normalvariate",
    "expovariate", "betavariate", "getrandbits", "randbytes", "triangular",
})


class NondeterminismRule(Rule):
    id = "serve-time-nondeterminism"
    description = ("wall-clock reads, RNG draws, or fresh PRNGKeys in "
                   "serving modules — clocks must be injected, keys carried")
    hot_path_only = True

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fname = dotted(node.func)
            if fname is None:
                continue
            if fname in _CLOCKS or fname in _DATETIME:
                yield ctx.finding(
                    self.id, node,
                    f"{fname}() reads the wall clock in a serving module — "
                    "inject it (clock=... parameter) so replays and tests "
                    "can control time")
            elif fname.split(".", 1)[0] == "random" and \
                    fname.split(".")[-1] in _STDLIB_RANDOM:
                yield ctx.finding(
                    self.id, node,
                    f"{fname}() draws serve-time entropy — carry explicit "
                    "seeded state instead")
            elif fname.startswith(("np.random.", "numpy.random.")):
                yield ctx.finding(
                    self.id, node,
                    f"{fname}() draws serve-time entropy — draw plans at "
                    "build time (FaultPlan.seeded) and replay them")
            elif fname in _FRESH_KEYS:
                yield ctx.finding(
                    self.id, node,
                    f"{fname}() mints a fresh key in serving code — keys "
                    "are carried through DecodeState and split, never "
                    "re-seeded mid-stream")

    triggers = (
        """\
import time
import numpy as np
import jax

def serve_tick(reqs):
    t0 = time.monotonic()
    noise = np.random.rand()
    key = jax.random.PRNGKey(0)
    return t0, noise, key
""",
        """\
import random

def pick_slot(slots):
    return random.choice(slots)
""",
    )
    non_triggers = (
        """\
import time
from typing import Callable

def make_scheduler(clock: Callable[[], float] = time.monotonic):
    return clock

def split_key(key):
    import jax
    return jax.random.split(key)
""",
    )
