"""Inline suppression comments: ``# scopelint: allow[rule-id] -- reason``.

A suppression applies to findings on the same physical line, or — when the
comment stands alone on its line — to the line directly below it.  Several
rule ids may be listed comma-separated; ``allow[*]`` matches any rule.

The suppression machinery polices itself: a suppression without a
``-- reason`` justification and a suppression that matched nothing are both
findings (``suppression-missing-reason`` / ``unused-suppression``), so dead
or unexplained waivers cannot accumulate silently.  Those two meta-rules
cannot themselves be suppressed.
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize
from typing import List, Optional

from repro.analysis.findings import Finding

_ALLOW_RX = re.compile(
    r"#\s*scopelint:\s*allow\[([A-Za-z0-9_*\-, ]+)\]"
    r"(?:\s*--\s*(.*\S))?\s*$")

# meta-findings emitted by the suppression layer itself; never suppressible
MISSING_REASON = "suppression-missing-reason"
UNUSED = "unused-suppression"
_META = frozenset({MISSING_REASON, UNUSED})


@dataclasses.dataclass
class _Entry:
    comment_line: int       # line the comment sits on (1-based)
    target_line: int        # line whose findings it suppresses
    rules: List[str]
    reason: Optional[str]
    used: bool = False


class Suppressions:
    """Parsed ``allow[...]`` comments of one module."""

    def __init__(self, entries: List[_Entry]):
        self._entries = entries

    @classmethod
    def parse(cls, source: str) -> "Suppressions":
        # real COMMENT tokens only — the syntax quoted in a docstring or
        # string literal is documentation, not a waiver
        entries: List[_Entry] = []
        try:
            tokens = list(tokenize.generate_tokens(
                io.StringIO(source).readline))
        except (tokenize.TokenError, SyntaxError, IndentationError):
            tokens = []
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _ALLOW_RX.search(tok.string)
            if not m:
                continue
            rules = [r.strip() for r in m.group(1).split(",") if r.strip()]
            line = tok.start[0]
            standalone = tok.line[: tok.start[1]].strip() == ""
            entries.append(_Entry(
                comment_line=line,
                target_line=line + 1 if standalone else line,
                rules=rules,
                reason=m.group(2)))
        return cls(entries)

    def match(self, rule: str, line: int) -> Optional[_Entry]:
        """Return (and mark used) the entry covering ``rule`` at ``line``."""
        if rule in _META:
            return None
        for e in self._entries:
            if e.target_line == line and (rule in e.rules or "*" in e.rules):
                e.used = True
                return e
        return None

    def meta_findings(self, path: str) -> List[Finding]:
        """Findings about the suppressions themselves (run after matching)."""
        out: List[Finding] = []
        for e in self._entries:
            if e.reason is None:
                out.append(Finding(
                    MISSING_REASON, path, e.comment_line,
                    "suppression lacks a '-- reason' justification"))
            if not e.used:
                out.append(Finding(
                    UNUSED, path, e.comment_line,
                    f"suppression allow[{', '.join(e.rules)}] matched "
                    "no finding — remove it or fix the target line"))
        return out

    def apply(self, findings: List[Finding]) -> List[Finding]:
        """Mark findings covered by an entry as suppressed."""
        out: List[Finding] = []
        for f in findings:
            e = self.match(f.rule, f.line)
            if e is not None:
                f = dataclasses.replace(
                    f, suppressed=True, suppress_reason=e.reason or "")
            out.append(f)
        return out
