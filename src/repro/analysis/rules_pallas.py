"""Rule: pallas-kernel-contract.

Statically-checkable half of the Pallas kernel contract the paged decode
path relies on (``kernels/decode_attention.py``):

- every ``pl.pallas_call`` declares a ``grid`` or ``grid_spec`` (an
  implicit single-program grid hides indexing bugs);
- each ``BlockSpec`` index-map lambda takes exactly ``len(grid)`` program
  indices — plus ``num_scalar_prefetch`` leading refs under a
  ``PrefetchScalarGridSpec`` (the page table / lengths the paged kernel
  prefetches);
- index maps are pure address arithmetic: no calls inside the lambda;
- rank-1 block shapes (per-row scalars like lengths) carry an explicit
  ``memory_space`` annotation (SMEM) — the default vector-memory layout
  traps on TPU for sub-tile scalars;
- ``interpret=True`` is never hardcoded (pass it through so TPU runs
  compile; see the ``_interpret()`` backend probe in ``kernels/ops.py``).

Grid/block divisibility and index-map *bounds* against ``PagedSpec``
depend on runtime shapes, so they are enforced by layer 2: the jaxpr pass
traces the registered paged executables, and pallas validates block
shapes against array shapes at trace time — a violation fails the trace
and surfaces as a finding there.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional

from repro.analysis.astpass import ModuleContext, Rule, dotted
from repro.analysis.findings import Finding

_PALLAS_CALL = frozenset({"pl.pallas_call", "pallas_call",
                          "pltpu.pallas_call"})
_GRID_SPECS = frozenset({"pltpu.PrefetchScalarGridSpec",
                         "PrefetchScalarGridSpec", "pl.GridSpec",
                         "GridSpec"})
_BLOCK_SPECS = frozenset({"pl.BlockSpec", "BlockSpec"})


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _tuple_len(node: Optional[ast.AST]) -> Optional[int]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return 1
    return None


def _block_specs(node: Optional[ast.AST]) -> List[ast.Call]:
    """BlockSpec constructor calls in an in_specs/out_specs expression."""
    if node is None:
        return []
    out: List[ast.Call] = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and dotted(sub.func) in _BLOCK_SPECS:
            out.append(sub)
    return out


class PallasContractRule(Rule):
    id = "pallas-kernel-contract"
    description = ("pallas_call grid/BlockSpec contract: index-map arity, "
                   "pure index maps, SMEM annotations on rank-1 blocks, "
                   "no hardcoded interpret mode")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and \
                    dotted(node.func) in _PALLAS_CALL:
                yield from self._check_call(ctx, node)

    def _check_call(self, ctx: ModuleContext,
                    call: ast.Call) -> Iterator[Finding]:
        grid = _kw(call, "grid")
        grid_spec = _kw(call, "grid_spec")
        if grid is None and grid_spec is None:
            yield ctx.finding(
                self.id, call,
                "pallas_call without grid= or grid_spec= — declare the "
                "program grid explicitly")
            return
        n_prefetch = 0
        specs_holder = call
        if grid_spec is not None and isinstance(grid_spec, ast.Call) and \
                dotted(grid_spec.func) in _GRID_SPECS:
            grid = _kw(grid_spec, "grid") or grid
            pf = _kw(grid_spec, "num_scalar_prefetch")
            if isinstance(pf, ast.Constant) and isinstance(pf.value, int):
                n_prefetch = pf.value
            specs_holder = grid_spec
        ndims = _tuple_len(grid)

        interp = _kw(call, "interpret")
        if isinstance(interp, ast.Constant) and interp.value is True:
            yield ctx.finding(
                self.id, interp,
                "interpret=True hardcoded — thread it through (backend "
                "probe) so the kernel compiles on TPU")

        for spec in (_block_specs(_kw(specs_holder, "in_specs")) +
                     _block_specs(_kw(specs_holder, "out_specs"))):
            yield from self._check_block_spec(ctx, spec, ndims, n_prefetch)

    def _check_block_spec(self, ctx: ModuleContext, spec: ast.Call,
                          ndims: Optional[int],
                          n_prefetch: int) -> Iterator[Finding]:
        shape = spec.args[0] if spec.args else _kw(spec, "block_shape")
        index_map = spec.args[1] if len(spec.args) > 1 \
            else _kw(spec, "index_map")
        if isinstance(index_map, ast.Lambda):
            arity = len(index_map.args.args)
            if ndims is not None and arity != ndims + n_prefetch:
                want = f"{ndims} grid indices" + (
                    f" + {n_prefetch} scalar-prefetch refs"
                    if n_prefetch else "")
                yield ctx.finding(
                    self.id, index_map,
                    f"index map takes {arity} args but the grid implies "
                    f"{want} — each program axis must be addressed")
            for sub in ast.walk(index_map.body):
                if isinstance(sub, ast.Call):
                    yield ctx.finding(
                        self.id, sub,
                        "call inside a BlockSpec index map — index maps "
                        "must be pure address arithmetic")
                    break
        rank = _tuple_len(shape)
        if rank == 1 and _kw(spec, "memory_space") is None:
            yield ctx.finding(
                self.id, spec,
                "rank-1 BlockSpec without memory_space= — per-row scalars "
                "belong in SMEM (pltpu.SMEM), the default vector layout "
                "traps on sub-tile blocks")

    triggers = (
        """\
import jax
from jax.experimental import pallas as pl

def bad(x, kernel):
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)

def bad2(x, kernel, table):
    return pl.pallas_call(
        kernel,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, lookup(j))),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
""",
    )
    non_triggers = (
        """\
import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

def good(x, kernel, interpret):
    return pl.pallas_call(
        kernel,
        grid=(4, 2, 8),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, 8, 16), lambda b, h, i: (b, h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 16), lambda b, h, i: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
""",
    )
