"""scopelint: static analysis enforcing the serve-path invariants.

Two layers:

- **AST rules** (``astpass`` + ``rules_*``): host syncs, serve-time
  nondeterminism, recompile hazards, traced-body side effects, and the
  Pallas kernel contract, checked over the source with a traced-body
  index and value taint so static-config idioms don't false-positive.
- **jaxpr pass** (``jaxpr_pass``): the registered hot-path executables
  are traced with abstract inputs and their jaxprs walked for host
  callbacks, f64 promotions, and staged host transfers — what XLA sees,
  not what the source says.

CLI: ``python -m repro.analysis [--self-test] [--list-rules] [paths]``.
Suppress a finding with ``# scopelint: allow[rule-id] -- reason``.
"""
from repro.analysis.findings import Finding
from repro.analysis.astpass import ModuleContext, Rule
from repro.analysis.runner import all_rules, main, scan_paths, scan_source

__all__ = ["Finding", "ModuleContext", "Rule", "all_rules", "main",
           "scan_paths", "scan_source"]
