"""Rule: recompile-hazard.

The "0 recompiles after warmup" gate (read from ``COMPILE_COUNTS``) is a
throughput invariant: one silent recompile per microbatch erases the
fused-decode win.  Three statically-visible hazards:

- **jit-in-loop**: ``jax.jit(...)`` constructed inside a ``for``/``while``
  body builds a fresh cache entry per iteration — hoist it;
- **Python branch on a traced value**: ``if x.sum() > 0:`` inside a traced
  body either fails to trace or, via shape polymorphism workarounds,
  triggers per-value retraces — use ``lax.cond``/``jnp.where``;
- **unhashable static argument**: a list/dict/set (or fresh ndarray)
  passed at a ``static_argnums`` position of a same-module jitted
  function raises at best and retraces per call at worst.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Tuple

from repro.analysis.astpass import (ModuleContext, Rule, _FunctionNode,
                                    dotted, expr_tainted, jit_statics)
from repro.analysis.findings import Finding

_JIT_CALLS = frozenset({"jax.jit", "jit", "jax.pmap", "pmap"})
_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
               ast.SetComp, ast.GeneratorExp)
_ARRAY_MAKERS = frozenset({"np.array", "np.asarray", "numpy.array",
                           "numpy.asarray", "jnp.array", "jnp.asarray",
                           "jax.numpy.array", "jax.numpy.asarray"})


class RecompileHazardRule(Rule):
    id = "recompile-hazard"
    description = ("jit built inside a loop, Python branches on traced "
                   "values, or unhashable static-argnum arguments")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        jitted = self._jitted_statics(ctx)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                yield from self._check_jit_in_loop(ctx, node)
                yield from self._check_static_args(ctx, node, jitted)
            elif isinstance(node, (ast.If, ast.While)):
                yield from self._check_branch(ctx, node)

    def _jitted_statics(self, ctx: ModuleContext
                        ) -> Dict[str, Tuple[int, ...]]:
        out: Dict[str, Tuple[int, ...]] = {}
        for fn in ctx.tree.body:
            if not isinstance(fn, _FunctionNode):
                continue
            for dec in fn.decorator_list:
                st = jit_statics(dec)
                if st is not None and st[0]:
                    out[fn.name] = tuple(sorted(st[0]))
        return out

    def _check_jit_in_loop(self, ctx: ModuleContext,
                           node: ast.Call) -> Iterator[Finding]:
        if dotted(node.func) not in _JIT_CALLS:
            return
        cur = ctx.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.For, ast.While, ast.AsyncFor)):
                yield ctx.finding(
                    self.id, node,
                    "jax.jit constructed inside a loop compiles a fresh "
                    "executable per iteration — hoist it out")
                return
            if isinstance(cur, _FunctionNode):
                # a loop *outside* this def doesn't re-run the jit call
                return
            cur = ctx.parents.get(cur)

    def _check_branch(self, ctx: ModuleContext,
                      node: ast.AST) -> Iterator[Finding]:
        fn = ctx.traced_fn(node)
        if fn is None:
            return
        test = node.test
        # bare-name truthiness belongs to host-sync-in-hot-path
        bare = test
        if isinstance(bare, ast.UnaryOp) and isinstance(bare.op, ast.Not):
            bare = bare.operand
        if isinstance(bare, ast.Name):
            return
        if expr_tainted(test, ctx.tainted_names(fn.node)):
            yield ctx.finding(
                self.id, node,
                "Python branch on a traced value cannot be staged — use "
                "jnp.where or lax.cond (static config branches are fine)")

    def _check_static_args(self, ctx: ModuleContext, node: ast.Call,
                           jitted: Dict[str, Tuple[int, ...]]
                           ) -> Iterator[Finding]:
        if not isinstance(node.func, ast.Name):
            return
        statics = jitted.get(node.func.id)
        if not statics:
            return
        for ix in statics:
            if ix >= len(node.args):
                continue
            arg = node.args[ix]
            if isinstance(arg, _UNHASHABLE):
                yield ctx.finding(
                    self.id, arg,
                    f"unhashable literal at static position {ix} of "
                    f"{node.func.id}() — statics must be hashable "
                    "(tuple, int, NamedTuple)")
            elif isinstance(arg, ast.Call) and \
                    dotted(arg.func) in _ARRAY_MAKERS:
                yield ctx.finding(
                    self.id, arg,
                    f"fresh array at static position {ix} of "
                    f"{node.func.id}() — arrays are unhashable and every "
                    "call would retrace")

    triggers = (
        """\
import functools
import jax

@functools.partial(jax.jit, static_argnums=(1,))
def f(x, cfg):
    return x * 2

def caller(x):
    for lr in (0.1, 0.2):
        step = jax.jit(lambda y: y * lr)
        x = step(x)
    return f(x, [1, 2, 3])

@jax.jit
def g(x):
    if x.sum() > 0:
        return x
    return -x
""",
    )
    non_triggers = (
        """\
import functools
import jax

@functools.partial(jax.jit, static_argnums=(1,))
def f(x, n):
    if n > 2:
        return x * 2.0
    return x

_step = jax.jit(lambda y: y * 2.0)

def caller(x):
    for _ in range(3):
        x = _step(x)
    return f(x, 3)
""",
        """\
import functools
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _kernel(q_ref, o_ref, *, softcap: float, window: int):
    s = q_ref[...]
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    if window > 0:
        s = s * 2.0
    o_ref[...] = s

def launch(q, interpret):
    return pl.pallas_call(
        functools.partial(_kernel, softcap=20.0, window=0),
        grid=(4,),
        out_shape=q,
        interpret=interpret,
    )(q)
""",
    )
