"""Layer-1 infrastructure: module parsing, traced-body index, taint, rule ABC.

The AST rules need to answer two questions precisely, or they drown the
repo in false positives:

1. **Which function bodies run under a JAX trace?**  Jitted functions
   (``@jax.jit`` / ``@functools.partial(jax.jit, static_argnums=...)``),
   bodies handed to traced control flow (``lax.scan`` / ``while_loop`` /
   ``cond`` / ``fori_loop`` / ``switch`` / ``map``), Pallas kernels handed
   to ``pl.pallas_call`` (possibly through a ``functools.partial``
   assignment), functions nested inside any of those, and — transitively —
   module-level functions *called* from a traced body (``_run_scan`` called
   from the jitted ``_scan_decode``).

2. **Which names hold traced values?**  Non-static jit parameters and
   traced-control-flow body parameters seed the taint set; assignment
   propagates it; ``.shape`` / ``.ndim`` / ``.dtype`` / ``.size`` access
   and ``len()`` stop it (static under tracing).  Parameters of functions
   that are only *transitively* traced are deliberately left untainted:
   their call sites may pass static values (``_run_scan``'s ``temperature``
   is a closed-over static), so branching on them is legitimate.

Each rule carries its own self-test corpus (``triggers`` must fire,
``non_triggers`` must stay silent) so ``--self-test`` proves every rule
alive without fixtures.
"""
from __future__ import annotations

import abc
import ast
import dataclasses
from typing import (ClassVar, Dict, FrozenSet, Iterator, List, Optional,
                    Set, Tuple)

from repro.analysis.findings import Finding
from repro.analysis.manifest import is_hot_path
from repro.analysis.suppress import Suppressions

# dotted names of jit entry points
_JIT_NAMES = frozenset({"jax.jit", "jit"})
_PARTIAL_NAMES = frozenset({"functools.partial", "partial"})
# traced higher-order control flow: dotted name -> indices of function args
_TRACED_HOF: Dict[str, Tuple[int, ...]] = {
    "jax.lax.scan": (0,), "lax.scan": (0,),
    "jax.lax.while_loop": (0, 1), "lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,), "lax.fori_loop": (2,),
    "jax.lax.cond": (1, 2), "lax.cond": (1, 2),
    "jax.lax.switch": (1, 2, 3, 4), "lax.switch": (1, 2, 3, 4),
    "jax.lax.map": (0,), "lax.map": (0,),
    "jax.lax.associative_scan": (0,), "lax.associative_scan": (0,),
    "jax.checkpoint": (0,), "jax.remat": (0,),
}
_PALLAS_CALL = frozenset({"pl.pallas_call", "pallas_call",
                          "pltpu.pallas_call"})
# attribute reads that are static under tracing and stop taint propagation
STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "aval",
                          "sharding", "itemsize"})
_STATIC_CALLS = frozenset({"len", "isinstance", "type", "id", "repr",
                           "str", "hasattr", "getattr"})


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def _literal(node: ast.AST):
    try:
        return ast.literal_eval(node)
    except (ValueError, TypeError, SyntaxError, MemoryError):
        return None


def jit_statics(dec: ast.AST) -> Optional[Tuple[Set[int], Set[str]]]:
    """If ``dec`` is a jit decorator, return (static positions, names)."""
    if dotted(dec) in _JIT_NAMES:
        return set(), set()
    if not isinstance(dec, ast.Call):
        return None
    fn = dotted(dec.func)
    kws = dec.keywords
    if fn in _PARTIAL_NAMES:
        if not (dec.args and dotted(dec.args[0]) in _JIT_NAMES):
            return None
    elif fn not in _JIT_NAMES:
        return None
    nums: Set[int] = set()
    names: Set[str] = set()
    for kw in kws:
        if kw.arg == "static_argnums":
            val = _literal(kw.value)
            if isinstance(val, int):
                nums.add(val)
            elif isinstance(val, (tuple, list)):
                nums.update(v for v in val if isinstance(v, int))
        elif kw.arg == "static_argnames":
            val = _literal(kw.value)
            if isinstance(val, str):
                names.add(val)
            elif isinstance(val, (tuple, list)):
                names.update(v for v in val if isinstance(v, str))
    return nums, names


_FunctionNode = (ast.FunctionDef, ast.AsyncFunctionDef)
_NO_STATICS: FrozenSet[int] = frozenset()


@dataclasses.dataclass
class TracedFn:
    node: ast.FunctionDef
    kind: str                       # jit | scan-body | pallas-kernel |
    #                                 nested | transitive
    traced_params: FrozenSet[str]
    statics: FrozenSet[int] = frozenset()   # positional static indices (jit)


class ModuleContext:
    """One parsed module plus the derived indices the rules consume."""

    def __init__(self, source: str, path: str,
                 hot_path: Optional[bool] = None):
        self.source = source
        self.path = path
        self.tree = ast.parse(source)
        self.hot_path = is_hot_path(path) if hot_path is None else hot_path
        self.suppressions = Suppressions.parse(source)
        self.parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        self.traced: Dict[ast.AST, TracedFn] = {}
        self._taint_cache: Dict[ast.AST, FrozenSet[str]] = {}
        self._build_traced_index()

    # -- traced-body index -------------------------------------------------
    def _functions(self) -> List[ast.FunctionDef]:
        return [n for n in ast.walk(self.tree)
                if isinstance(n, _FunctionNode)]

    def _positional_params(self, fn: ast.FunctionDef) -> List[str]:
        a = fn.args
        return [p.arg for p in (a.posonlyargs + a.args)]

    def _kwonly_params(self, fn: ast.FunctionDef) -> List[str]:
        return [p.arg for p in fn.args.kwonlyargs]

    def _resolve_fn_arg(self, arg: ast.AST,
                        scope: ast.AST) -> Optional[ast.FunctionDef]:
        """Resolve a function-valued call argument to its local def.

        Handles a bare Name, ``functools.partial(name, ...)`` inline, and a
        Name previously assigned from ``functools.partial(name, ...)``.
        """
        if isinstance(arg, ast.Call) and dotted(arg.func) in _PARTIAL_NAMES:
            return self._resolve_fn_arg(arg.args[0], scope) if arg.args \
                else None
        name = dotted(arg)
        if name is None or "." in name:
            return None
        # nearest definition: walk enclosing function scopes, then module
        node: Optional[ast.AST] = scope
        while node is not None:
            if isinstance(node, _FunctionNode + (ast.Module,)):
                for stmt in ast.walk(node):
                    if isinstance(stmt, _FunctionNode) and \
                            stmt.name == name:
                        return stmt
                    if isinstance(stmt, ast.Assign):
                        for tgt in stmt.targets:
                            if isinstance(tgt, ast.Name) and \
                                    tgt.id == name and \
                                    isinstance(stmt.value, ast.Call) and \
                                    dotted(stmt.value.func) in \
                                    _PARTIAL_NAMES and stmt.value.args:
                                return self._resolve_fn_arg(
                                    stmt.value.args[0], node)
            node = self.parents.get(node)
        return None

    def _mark(self, fn: ast.FunctionDef, kind: str,
              traced_params: Set[str],
              statics: FrozenSet[int] = _NO_STATICS) -> None:
        if fn in self.traced:
            return
        self.traced[fn] = TracedFn(fn, kind, frozenset(traced_params),
                                   statics)

    def _build_traced_index(self) -> None:
        fns = self._functions()
        # 1) jit roots
        for fn in fns:
            for dec in fn.decorator_list:
                st = jit_statics(dec)
                if st is None:
                    continue
                nums, names = st
                params = self._positional_params(fn)
                traced = {p for i, p in enumerate(params)
                          if i not in nums and p not in names}
                traced |= {p for p in self._kwonly_params(fn)
                           if p not in names}
                self._mark(fn, "jit", traced, frozenset(nums))
                break
        # 2) traced-control-flow bodies and pallas kernels (traced
        #    regardless of jit context: lax.scan/pallas_call always trace)
        for call in ast.walk(self.tree):
            if not isinstance(call, ast.Call):
                continue
            fname = dotted(call.func)
            scope = self.enclosing_function(call) or self.tree
            if fname in _TRACED_HOF:
                for ix in _TRACED_HOF[fname]:
                    if ix < len(call.args):
                        tgt = self._resolve_fn_arg(call.args[ix], scope)
                        if tgt is not None:
                            # kwonly params are bound by functools.partial
                            # at trace time — static config, not tracers
                            self._mark(tgt, "scan-body",
                                       set(self._positional_params(tgt)))
            elif fname in _PALLAS_CALL and call.args:
                tgt = self._resolve_fn_arg(call.args[0], scope)
                if tgt is not None:
                    self._mark(tgt, "pallas-kernel",
                               set(self._positional_params(tgt)))
        # 3) fixpoint: nested defs + same-module transitive callees
        changed = True
        while changed:
            changed = False
            for fn in fns:
                if fn in self.traced:
                    continue
                enc = self.enclosing_function(fn)
                if enc is not None and enc in self.traced:
                    self._mark(fn, "nested", set())
                    changed = True
            module_fns = {f.name: f for f in self.tree.body
                          if isinstance(f, _FunctionNode)}
            for fn in list(self.traced):
                for call in ast.walk(fn):
                    if isinstance(call, ast.Call) and \
                            isinstance(call.func, ast.Name):
                        tgt = module_fns.get(call.func.id)
                        if tgt is not None and tgt not in self.traced:
                            # params stay untainted: call sites may pass
                            # static values (closed-over temperature etc.)
                            self._mark(tgt, "transitive", set())
                            changed = True

    # -- queries -----------------------------------------------------------
    def enclosing_function(self, node: ast.AST) -> Optional[ast.FunctionDef]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, _FunctionNode):
                return cur
            cur = self.parents.get(cur)
        return None

    def traced_fn(self, node: ast.AST) -> Optional[TracedFn]:
        """Innermost traced function whose body contains ``node``."""
        fn = node if isinstance(node, _FunctionNode) else \
            self.enclosing_function(node)
        while fn is not None:
            if fn in self.traced:
                return self.traced[fn]
            fn = self.enclosing_function(fn)
        return None

    def traced_root(self, node: ast.AST) -> Optional[ast.FunctionDef]:
        """Outermost traced function containing ``node`` (trace boundary)."""
        root = None
        fn = node if isinstance(node, _FunctionNode) else \
            self.enclosing_function(node)
        while fn is not None:
            if fn in self.traced:
                root = fn
            fn = self.enclosing_function(fn)
        return root

    def in_traced_body(self, node: ast.AST) -> bool:
        return self.traced_fn(node) is not None

    # -- taint -------------------------------------------------------------
    def tainted_names(self, fn: ast.FunctionDef) -> FrozenSet[str]:
        """Names (likely) bound to traced values inside ``fn``'s own body.

        Seeded with the function's traced params plus taint inherited from
        the enclosing traced scope (closures see traced outer locals), then
        propagated through assignments to a fixpoint.
        """
        cached = self._taint_cache.get(fn)
        if cached is not None:
            return cached
        info = self.traced.get(fn)
        taint: Set[str] = set(info.traced_params) if info else set()
        enc = self.enclosing_function(fn)
        if enc is not None and enc in self.traced:
            taint |= self.tainted_names(enc)
        own = [n for n in ast.walk(fn)
               if self.enclosing_function(n) is fn]
        changed = True
        while changed:
            changed = False
            for node in own:
                targets: List[ast.AST] = []
                value: Optional[ast.AST] = None
                if isinstance(node, ast.Assign):
                    targets, value = node.targets, node.value
                elif isinstance(node, ast.AnnAssign) and node.value:
                    targets, value = [node.target], node.value
                elif isinstance(node, ast.AugAssign):
                    targets, value = [node.target], node.value
                elif isinstance(node, ast.NamedExpr):
                    targets, value = [node.target], node.value
                if value is None or not expr_tainted(value, taint):
                    continue
                for tgt in targets:
                    for name in ast.walk(tgt):
                        if isinstance(name, ast.Name) and \
                                name.id not in taint:
                            taint.add(name.id)
                            changed = True
        out = frozenset(taint)
        self._taint_cache[fn] = out
        return out

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule, self.path, getattr(node, "lineno", 0), message)


def expr_tainted(node: ast.AST, taint: FrozenSet[str]) -> bool:
    """Does evaluating ``node`` read a tainted name as a (device) value?

    ``x.shape[0]``, ``len(x)``, ``isinstance(x, T)`` read only static
    metadata and do not count.
    """
    if isinstance(node, ast.Name):
        return node.id in taint
    if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
        return False
    if isinstance(node, ast.Call):
        fname = dotted(node.func)
        if fname in _STATIC_CALLS:
            return False
        kids: List[ast.AST] = list(node.args) + \
            [kw.value for kw in node.keywords]
        # a method call on a tainted receiver yields a tainted value
        if isinstance(node.func, ast.Attribute):
            kids.append(node.func.value)
        return any(expr_tainted(k, taint) for k in kids)
    return any(expr_tainted(c, taint) for c in ast.iter_child_nodes(node))


class Rule(abc.ABC):
    """One scopelint rule: a checker plus its self-test corpus.

    ``triggers`` are minimal snippets the rule MUST flag; ``non_triggers``
    are near-identical twins it MUST leave alone.  ``--self-test`` runs
    both sets for every registered rule, so a refactor that silently
    lobotomises a rule fails CI even with a clean tree.
    """
    id: ClassVar[str]
    description: ClassVar[str]
    hot_path_only: ClassVar[bool] = False
    triggers: ClassVar[Tuple[str, ...]] = ()
    non_triggers: ClassVar[Tuple[str, ...]] = ()

    @abc.abstractmethod
    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        ...

    def applies(self, ctx: ModuleContext) -> bool:
        return ctx.hot_path or not self.hot_path_only
