"""Rule: traced-body-side-effect.

A traced body runs at *trace* time, once per compilation — not once per
call.  Mutating state that outlives the trace (module globals, closed-over
mutables, ``self``) from inside a jitted/scanned body therefore records
trace-time artifacts, breaks replay, and silently diverges between the
first call and every cached one.

Flagged inside traced bodies:

- ``global`` declarations, and ``nonlocal`` targets bound *outside* the
  outermost traced function (writes escaping the trace boundary);
- attribute / subscript stores and augmented assigns whose base object is
  defined outside the traced root (``self.n += 1``, ``CACHE[k] = v``);
- mutating method calls (``.append`` / ``.update`` / ``.add`` / ...) on
  such outside objects.

State created *inside* the traced root is fresh per trace and fine — the
sampler's ``flat_cache`` staging dict is the canonical example.  The
``COMPILE_COUNTS`` counter is whitelisted by name: incrementing it inside
the traced body is the repo's deliberate once-per-compilation
instrumentation idiom (see ``serving/sampler.py``).
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional, Set

from repro.analysis.astpass import ModuleContext, Rule, _FunctionNode
from repro.analysis.findings import Finding

WHITELIST = frozenset({"COMPILE_COUNTS"})
_MUTATORS = frozenset({
    "append", "extend", "insert", "add", "update", "setdefault", "pop",
    "popitem", "clear", "remove", "discard", "write", "appendleft",
})


def _base_name(node: ast.AST) -> Optional[str]:
    """Leftmost Name of an attribute/subscript chain (``a`` in ``a.b[c]``)."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _root_locals(ctx: ModuleContext, root: ast.AST) -> Set[str]:
    """Every name bound anywhere within ``root`` (any nesting depth).

    Coarse by design: an object bound anywhere inside the traced root was
    created during this trace, so mutating it cannot leak state across
    calls.  Stores in a function that declares the name ``nonlocal`` /
    ``global`` do not count — those bind outside their scope.
    """
    declared: dict = {}
    for node in ast.walk(root):
        if isinstance(node, (ast.Nonlocal, ast.Global)):
            fn = ctx.enclosing_function(node)
            declared.setdefault(fn, set()).update(node.names)
    names: Set[str] = set()
    for node in ast.walk(root):
        if isinstance(node, _FunctionNode):
            names.add(node.name)
            a = node.args
            names.update(p.arg for p in
                         (a.posonlyargs + a.args + a.kwonlyargs))
            if a.vararg:
                names.add(a.vararg.arg)
            if a.kwarg:
                names.add(a.kwarg.arg)
        elif isinstance(node, ast.Name) and \
                isinstance(node.ctx, (ast.Store, ast.Del)):
            fn = ctx.enclosing_function(node)
            if node.id not in declared.get(fn, ()):
                names.add(node.id)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                names.add((alias.asname or alias.name).split(".")[0])
        elif isinstance(node, ast.ExceptHandler) and node.name:
            names.add(node.name)
    return names


class TracedSideEffectRule(Rule):
    id = "traced-body-side-effect"
    description = ("mutation of state outliving the trace (globals, "
                   "closures, self) inside jitted/scanned bodies; "
                   "COMPILE_COUNTS is whitelisted")

    def check(self, ctx: ModuleContext) -> Iterator[Finding]:
        locals_of = {}
        for node in ast.walk(ctx.tree):
            root = ctx.traced_root(node)
            if root is None:
                continue
            if root not in locals_of:
                locals_of[root] = _root_locals(ctx, root)
            rl = locals_of[root]
            if isinstance(node, ast.Global):
                bad = [n for n in node.names if n not in WHITELIST]
                if bad:
                    yield ctx.finding(
                        self.id, node,
                        f"global {', '.join(bad)} inside a traced body — "
                        "writes happen at trace time, not per call")
            elif isinstance(node, ast.Nonlocal):
                bad = [n for n in node.names
                       if n not in rl and n not in WHITELIST]
                if bad:
                    yield ctx.finding(
                        self.id, node,
                        f"nonlocal {', '.join(bad)} escapes the traced "
                        "body — carry it through the scan/jit return value")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for tgt in targets:
                    if not isinstance(tgt, (ast.Attribute, ast.Subscript)):
                        continue
                    base = _base_name(tgt)
                    if base is None or base in WHITELIST or base in rl:
                        continue
                    yield ctx.finding(
                        self.id, tgt,
                        f"store into '{base}' defined outside the traced "
                        "body mutates trace-persistent state")
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _MUTATORS:
                base = _base_name(node.func.value)
                if base is None or base in WHITELIST or base in rl:
                    continue
                yield ctx.finding(
                    self.id, node,
                    f"'{base}.{node.func.attr}(...)' mutates an object "
                    "defined outside the traced body")

    triggers = (
        """\
import jax

_CALLS = []

@jax.jit
def f(x):
    _CALLS.append(1)
    return x * 2

def outer():
    total = 0

    @jax.jit
    def g(x):
        nonlocal total
        total += 1
        return x

    return g
""",
    )
    non_triggers = (
        """\
import jax
from collections import Counter

COMPILE_COUNTS = Counter()

@jax.jit
def f(x):
    COMPILE_COUNTS["f"] += 1
    scratch = {}
    scratch["x"] = x
    return x * 2
""",
    )
