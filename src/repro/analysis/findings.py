"""Finding record shared by both scopelint layers (AST rules and jaxpr pass)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location.

    ``path`` is the file as given to the scanner (or ``<jaxpr:name>`` for
    layer-2 findings, which have no source line).  ``suppressed`` marks a
    finding matched by an inline ``# scopelint: allow[rule] -- reason``
    comment; suppressed findings are reported but do not fail the run.
    """
    rule: str
    path: str
    line: int
    message: str
    suppressed: bool = False
    suppress_reason: str = ""

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        base = f"{loc}: [{self.rule}] {self.message}"
        if self.suppressed:
            base += f"  (suppressed: {self.suppress_reason})"
        return base
