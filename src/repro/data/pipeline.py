"""Batching / padding / sharded host->device pipeline."""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import PAD


def pad_to(seq: Sequence[int], length: int, pad: int = PAD) -> np.ndarray:
    out = np.full((length,), pad, np.int32)
    out[: len(seq)] = np.asarray(seq[:length], np.int32)
    return out


def make_lm_batch(prompts: List[List[int]], targets: List[List[int]],
                  max_len: int) -> Dict[str, np.ndarray]:
    """Concatenate prompt+target; labels = next-token, -100 on prompt/pad.

    Loss applies only to target tokens (SFT over the generated suffix, as in
    hindsight distillation — the prompt is conditioning, not supervision).
    """
    bsz = len(prompts)
    tokens = np.full((bsz, max_len), PAD, np.int32)
    labels = np.full((bsz, max_len), -100, np.int32)
    for i, (p, t) in enumerate(zip(prompts, targets, strict=True)):
        seq = (p + t)[:max_len]
        tokens[i, : len(seq)] = seq
        # label at position j predicts tokens[j+1]
        start = max(len(p) - 1, 0)
        end = min(len(seq) - 1, max_len - 1)
        for j in range(start, end + 1):
            nxt = j + 1
            if nxt < len(seq):
                labels[i, j] = seq[nxt]
    return {"tokens": tokens, "labels": labels}


def batches(data: Dict[str, np.ndarray], batch_size: int, *,
            shuffle: bool = True, seed: int = 0, drop_last: bool = True
            ) -> Iterator[Dict[str, jnp.ndarray]]:
    n = len(next(iter(data.values())))
    idx = np.arange(n)
    if shuffle:
        np.random.default_rng(seed).shuffle(idx)
    stop = n - (n % batch_size) if drop_last else n
    for i in range(0, stop, batch_size):
        sel = idx[i: i + batch_size]
        yield {k: jnp.asarray(v[sel]) for k, v in data.items()}


def stack_examples(examples: List[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    keys = examples[0].keys()
    return {k: np.stack([e[k] for e in examples]) for k in keys}


def shard_batch(batch: Dict[str, jnp.ndarray], mesh,
                spec) -> Dict[str, jnp.ndarray]:
    """Place a host batch onto the mesh with the given PartitionSpec."""
    from jax.sharding import NamedSharding
    sharding = NamedSharding(mesh, spec)
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}
