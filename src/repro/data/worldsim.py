"""Synthetic model-pool world simulator.

The repro band for this paper is 2/5: its data substrate is an 11-model
commercial API pool plus human benchmark corpora (SCOPE-60K).  We simulate
that gate with a generative world model that preserves every statistical
property the SCOPE algorithm depends on:

  * models have heterogeneous per-domain skills, verbosity profiles and
    $/token prices (mirroring Appendix Tab. 4's tiers, incl. the 4 held-out
    "unseen" models);
  * query correctness ~ Bernoulli(sigmoid(skill - difficulty));
  * completion tokens ~ verbosity * exp(difficulty) * lognormal noise, with
    reasoning models ~3-10x more verbose (Fig. 16/17 heterogeneity);
  * query embeddings cluster by domain, so dense retrieval over anchors is
    informative (Fig. 12 coverage).

Everything downstream (fingerprints, SFT/GRPO training, routing evaluation)
consumes only the *observable* interface: (query text features, model
metadata, sampled outcomes) — exactly what the paper's pipeline sees.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

DOMAINS = ("math", "physics", "chemistry", "biology",
           "history", "politics", "chinese", "engineering")
NUM_DOMAINS = len(DOMAINS)
EMBED_DIM = 32

# Fig. 3 composition (approximate, renormalized)
DOMAIN_WEIGHTS = np.array([0.20, 0.13, 0.14, 0.06, 0.14, 0.13, 0.12, 0.08])


@dataclasses.dataclass(frozen=True)
class PoolModel:
    name: str
    skill: np.ndarray          # (NUM_DOMAINS,) in difficulty units
    base_skill: float
    verbosity: float           # base completion tokens
    reasoning: bool
    price_in: float            # $ / 1M tokens
    price_out: float
    seen: bool                 # in the training pool


def _mk(name, base, tilt, verb, reasoning, pin, pout, seen, rng):
    skill = base + tilt + rng.normal(0, 0.15, NUM_DOMAINS)
    return PoolModel(name, skill, base, verb, reasoning, pin, pout, seen)


def default_pool(seed: int = 0) -> List[PoolModel]:
    """11 models mirroring Appendix Tab. 4 (7 seen + 4 unseen)."""
    rng = np.random.default_rng(seed)
    t = lambda *v: np.array(v)  # noqa: E731  per-domain tilt
    stem = t(.3, .3, .25, .1, -.1, -.1, -.15, .2)
    hum = -stem
    return [
        # ---- seen (training pool) ----
        _mk("deepseek-r1t2-chimera", 1.05, stem * .8, 900, True, 0.30, 1.20, True, rng),
        _mk("qwen3-235b-a22b", 0.95, stem * .5, 700, True, 0.18, 0.54, True, rng),
        _mk("nova-2-lite-v1", 0.45, hum * .3, 500, False, 0.30, 2.50, True, rng),
        _mk("qwen3-14b", 0.40, stem * .3, 450, True, 0.05, 0.22, True, rng),
        _mk("gpt-oss-20b", 0.50, stem * .4, 600, True, 0.03, 0.14, True, rng),
        _mk("llama-3.3-70b", 0.65, t(0, 0, 0, 0, .2, .2, .1, 0), 380, False, 0.10, 0.32, True, rng),
        _mk("gemma-3-27b", 0.45, hum * .2, 350, False, 0.04, 0.15, True, rng),
        # ---- unseen (OOD pool) ----
        _mk("claude-sonnet-4.5", 1.45, t(.2, .2, .2, .2, .25, .25, .2, .2), 800, True, 3.00, 15.00, False, rng),
        _mk("deepseek-v3.2", 1.00, stem * .6, 850, True, 0.25, 0.38, False, rng),
        _mk("gpt-5-mini", 0.90, t(.1, .1, .1, .1, .1, .1, .1, .1), 550, False, 0.25, 2.00, False, rng),
        _mk("grok-4.1-fast", 0.80, stem * .3, 500, True, 0.20, 0.50, False, rng),
    ]


@dataclasses.dataclass
class Query:
    qid: int
    domain: int
    difficulty: float
    embedding: np.ndarray      # (EMBED_DIM,) — what the retriever sees


class World:
    """Holds domain geometry and samples interactions."""

    def __init__(self, seed: int = 0, pool: Optional[List[PoolModel]] = None):
        self.rng = np.random.default_rng(seed)
        self.pool = pool if pool is not None else default_pool(seed)
        self.models: Dict[str, PoolModel] = {m.name: m for m in self.pool}
        # domain cluster centres, well separated
        self.centers = self.rng.normal(0, 1.0, (NUM_DOMAINS, EMBED_DIM))
        self.centers /= np.linalg.norm(self.centers, axis=1, keepdims=True)
        self.diff_dir = self.rng.normal(0, 1.0, EMBED_DIM)
        self.diff_dir /= np.linalg.norm(self.diff_dir)

    # ------------------------------------------------------------------
    def sample_queries(self, n: int, *, difficulty_shift: float = 0.0,
                       seed: Optional[int] = None) -> List[Query]:
        rng = np.random.default_rng(seed) if seed is not None else self.rng
        domains = rng.choice(NUM_DOMAINS, size=n, p=DOMAIN_WEIGHTS / DOMAIN_WEIGHTS.sum())
        out = []
        for i in range(n):
            d = int(domains[i])
            diff = float(np.clip(rng.normal(0.8 + difficulty_shift, 0.55), -0.5, 3.5))
            emb = (self.centers[d] + 0.35 * diff * self.diff_dir
                   + rng.normal(0, 0.25, EMBED_DIM))
            out.append(Query(i, d, diff, emb.astype(np.float32)))
        return out

    # ------------------------------------------------------------------
    def correct_prob(self, m: PoolModel, q: Query) -> float:
        margin = m.skill[q.domain] - q.difficulty
        return float(1.0 / (1.0 + np.exp(-3.0 * margin)))

    def expected_tokens(self, m: PoolModel, q: Query) -> float:
        boost = 1.0 + (2.0 if m.reasoning else 0.6) * max(q.difficulty, 0.0)
        return float(min(m.verbosity * boost, 16384.0))

    def sample_interaction(self, m: PoolModel, q: Query,
                           rng: Optional[np.random.Generator] = None
                           ) -> Tuple[int, int, float]:
        """Returns (y, completion_tokens, cost_dollars)."""
        rng = rng or self.rng
        y = int(rng.random() < self.correct_prob(m, q))
        mu = np.log(self.expected_tokens(m, q))
        tokens = int(np.clip(np.exp(rng.normal(mu, 0.35)), 5, 16384))
        prompt = int(rng.integers(80, 320))
        cost = (prompt * m.price_in + tokens * m.price_out) / 1e6
        return y, tokens, cost

    def embed(self, q: Query, rng: Optional[np.random.Generator] = None
              ) -> np.ndarray:
        """The retrieval embedder's view (Qwen3-Embedding stand-in)."""
        rng = rng or self.rng
        return (q.embedding + rng.normal(0, 0.02, EMBED_DIM)).astype(np.float32)
