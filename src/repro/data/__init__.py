"""Data substrate: world simulator, SCOPE-60K/250 synthesis, tokenizer,
batching pipeline."""
from repro.data import datasets, pipeline, tokenizer, worldsim  # noqa: F401
