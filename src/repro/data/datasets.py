"""SCOPE-60K / SCOPE-250 style dataset construction over the world sim.

``build_scope_data`` produces the (query, model, y, tokens, cost) interaction
corpus (SCOPE-60K analogue, size configurable); ``stratified_anchors``
produces the compact anchor set whose domain composition mirrors the full
corpus (SCOPE-250, Fig. 15).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.worldsim import (
    DOMAIN_WEIGHTS, NUM_DOMAINS, PoolModel, Query, World)


@dataclasses.dataclass
class Interaction:
    qid: int
    model: str
    y: int
    tokens: int
    cost: float


@dataclasses.dataclass
class ScopeData:
    world: World
    queries: List[Query]
    models: List[str]
    records: Dict[Tuple[int, str], Interaction]
    train_qids: np.ndarray
    test_qids: np.ndarray

    def record(self, qid: int, model: str) -> Interaction:
        return self.records[(qid, model)]

    def extend_models(self, names: Sequence[str], *, seed: int = 0) -> None:
        """Sample interactions for newly onboarded models over the existing
        query set — the world-sim analogue of serving them live."""
        rng = np.random.default_rng(seed)
        for name in names:
            if name in self.models:
                continue
            m = self.world.models[name]
            for q in self.queries:
                y, tokens, cost = self.world.sample_interaction(m, q, rng)
                self.records[(q.qid, name)] = Interaction(q.qid, name, y,
                                                          tokens, cost)
            self.models.append(name)


def build_scope_data(world: World, *, n_queries: int = 2000,
                     models: Optional[Sequence[str]] = None,
                     test_frac: float = 0.05, seed: int = 0,
                     difficulty_shift: float = 0.0) -> ScopeData:
    """Sample the interaction corpus for the given model pool."""
    names = list(models) if models is not None else [
        m.name for m in world.pool if m.seen]
    rng = np.random.default_rng(seed + 1)
    queries = world.sample_queries(n_queries, seed=seed + 2,
                                   difficulty_shift=difficulty_shift)
    records: Dict[Tuple[int, str], Interaction] = {}
    for q in queries:
        for name in names:
            m = world.models[name]
            y, tokens, cost = world.sample_interaction(m, q, rng)
            records[(q.qid, name)] = Interaction(q.qid, name, y, tokens, cost)
    qids = np.arange(n_queries)
    rng.shuffle(qids)
    n_test = max(1, int(n_queries * test_frac))
    return ScopeData(world, queries, names, records,
                     train_qids=np.sort(qids[n_test:]),
                     test_qids=np.sort(qids[:n_test]))


def stratified_anchors(world: World, n: int = 250, seed: int = 7
                       ) -> List[Query]:
    """Anchor queries whose domain mix mirrors DOMAIN_WEIGHTS (Fig. 15)."""
    rng = np.random.default_rng(seed)
    weights = DOMAIN_WEIGHTS / DOMAIN_WEIGHTS.sum()
    counts = np.floor(weights * n).astype(int)
    while counts.sum() < n:
        counts[int(rng.integers(NUM_DOMAINS))] += 1
    anchors: List[Query] = []
    pool = world.sample_queries(n * 8, seed=seed + 1)
    by_domain: Dict[int, List[Query]] = {d: [] for d in range(NUM_DOMAINS)}
    for q in pool:
        by_domain[q.domain].append(q)
    qid = 0
    for d in range(NUM_DOMAINS):
        take = by_domain[d][: counts[d]]
        for q in take:
            anchors.append(Query(qid, q.domain, q.difficulty, q.embedding))
            qid += 1
    return anchors


def ood_queries(world: World, n: int = 250, seed: int = 11) -> List[Query]:
    """Frontier-difficulty OOD queries (AIME/HLE analogue)."""
    return world.sample_queries(n, difficulty_shift=0.9, seed=seed)
