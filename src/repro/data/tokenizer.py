"""Structured field tokenizer for serialized SCOPE prompts.

The paper serializes retrieved fingerprint slices + the target query into a
text prompt (Eq. 4, Appendix H).  Our estimator LM consumes the same
structure through a compact field vocabulary: special markers, model
metadata tokens, per-domain tokens, similarity / length / count buckets and
quantized query-embedding feature tokens.  VOCAB_SIZE = 512 matches
``configs.scope_estimator.TINY``.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence

import numpy as np

from repro.data.worldsim import EMBED_DIM, NUM_DOMAINS

VOCAB_SIZE = 512

# ---------------------------------------------------------------------------
# Token map
# ---------------------------------------------------------------------------
PAD, BOS, EOS, SEP = 0, 1, 2, 3
ANCHOR, QUERY, PRED, THINK, THINK_END = 4, 5, 6, 7, 8
YES, NO = 9, 10
REASONING, STANDARD = 11, 12
UNK_MODEL = 13

_NEXT = 16
MODEL_BASE = _NEXT                      # 20 slots for seen-model name tokens
NUM_MODEL_TOKENS = 20
DOMAIN_BASE = MODEL_BASE + NUM_MODEL_TOKENS          # 36
SIM_BASE = DOMAIN_BASE + NUM_DOMAINS                 # 44
NUM_SIM_BUCKETS = 16
LEN_BASE = SIM_BASE + NUM_SIM_BUCKETS                # 60
NUM_LEN_BUCKETS = 32
PRICE_BASE = LEN_BASE + NUM_LEN_BUCKETS              # 92
NUM_PRICE_BUCKETS = 12
CNT_BASE = PRICE_BASE + NUM_PRICE_BUCKETS            # 104
NUM_CNT_TOKENS = 8                                   # counts 0..7
FEAT_BASE = CNT_BASE + NUM_CNT_TOKENS                # 112
NUM_FEAT_DIMS = 16
NUM_FEAT_BUCKETS = 16                                # 256 tokens -> ends 368

assert FEAT_BASE + NUM_FEAT_DIMS * NUM_FEAT_BUCKETS < VOCAB_SIZE

# length buckets: geometric from 8 to 16384
_LEN_EDGES = np.geomspace(8, 16384, NUM_LEN_BUCKETS + 1)
LEN_CENTERS = np.sqrt(_LEN_EDGES[:-1] * _LEN_EDGES[1:]).astype(np.float64)

_PRICE_EDGES = np.geomspace(0.01, 20.0, NUM_PRICE_BUCKETS + 1)


def len_bucket(tokens: float) -> int:
    return int(np.clip(np.searchsorted(_LEN_EDGES, tokens) - 1,
                       0, NUM_LEN_BUCKETS - 1))


def len_from_bucket(b: int) -> float:
    return float(LEN_CENTERS[int(np.clip(b, 0, NUM_LEN_BUCKETS - 1))])


def sim_bucket(sim: float) -> int:
    return int(np.clip((sim + 1.0) / 2.0 * NUM_SIM_BUCKETS, 0,
                       NUM_SIM_BUCKETS - 1))


def price_bucket(price_out: float) -> int:
    return int(np.clip(np.searchsorted(_PRICE_EDGES, price_out) - 1,
                       0, NUM_PRICE_BUCKETS - 1))


def feat_tokens(embedding: np.ndarray) -> List[int]:
    """Quantize the first NUM_FEAT_DIMS embedding dims into bucket tokens."""
    vals = np.clip(embedding[:NUM_FEAT_DIMS], -2.0, 2.0)
    buckets = ((vals + 2.0) / 4.0 * NUM_FEAT_BUCKETS).astype(int)
    buckets = np.clip(buckets, 0, NUM_FEAT_BUCKETS - 1)
    return [FEAT_BASE + i * NUM_FEAT_BUCKETS + int(b)
            for i, b in enumerate(buckets)]


def domain_token(d: int) -> int:
    return DOMAIN_BASE + int(d)


def model_token(model_index: int, seen: bool) -> int:
    if not seen:
        return UNK_MODEL
    return MODEL_BASE + int(model_index) % NUM_MODEL_TOKENS


def yesno(y: int) -> int:
    return YES if y else NO


def cnt_token(c: int) -> int:
    return CNT_BASE + int(np.clip(c, 0, NUM_CNT_TOKENS - 1))


# ---------------------------------------------------------------------------
# Decoding of predictions
# ---------------------------------------------------------------------------
def parse_prediction(tokens: Sequence[int]) -> Dict:
    """Parse a generated suffix into {y_hat, len_hat, well_formed}.

    Expected CoT format: THINK ... THINK_END (YES|NO) LEN_b EOS
    NoCoT format:        (YES|NO) LEN_b EOS
    The *format gate* G(o) of Eq. 6 is ``well_formed``.
    """
    toks = list(tokens)
    if THINK in toks:
        if THINK_END not in toks:
            return {"y_hat": 0, "len_hat": 0.0, "well_formed": False}
        toks = toks[toks.index(THINK_END) + 1:]
    # strip trailing pad/eos
    body = [t for t in toks if t not in (PAD,)]
    ok = (len(body) >= 3 and body[0] in (YES, NO)
          and LEN_BASE <= body[1] < LEN_BASE + NUM_LEN_BUCKETS
          and body[2] == EOS)
    if not ok:
        return {"y_hat": 0, "len_hat": 0.0, "well_formed": False}
    return {"y_hat": 1 if body[0] == YES else 0,
            "len_hat": len_from_bucket(body[1] - LEN_BASE),
            "well_formed": True}
