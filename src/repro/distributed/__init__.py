"""Distribution: meshes, sharding rules, collectives-by-construction."""
from repro.distributed import sharding  # noqa: F401
