"""Per-architecture parameter / activation / cache sharding rules.

Strategy (MaxText-style 2D sharding):
  * tensor parallelism on ``model``: attention head projections, FFN hidden,
    vocab, MoE experts (expert parallelism), Mamba heads;
  * FSDP on ``data`` (+ ``pod`` on the multi-pod mesh): the non-TP dim of
    every large matrix is additionally sharded, so optimizer state and
    weights fit; XLA inserts the per-layer all-gathers;
  * activations: batch on (pod, data); heads/ffn/vocab/experts on model;
  * decode caches: batch on (pod, data) when divisible, cache sequence on
    model otherwise (long_500k with batch 1 shards S over (data, model)).

Every rule is divisibility-checked against the mesh and silently dropped
when a dim does not divide — the dry-run must lower for every (arch, shape)
including kv_heads=2 and batch=1 cases.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))
    if isinstance(axes, str):
        return sizes[axes]
    return int(np.prod([sizes[a] for a in axes]))


def _fit(mesh: Mesh, dim: int, axes):
    """Return axes if dim divides their product, else None."""
    if axes is None:
        return None
    return axes if dim % axis_size(mesh, axes) == 0 else None


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------
_COL_PARALLEL = {"wq", "wk", "wv", "wi_gate", "wi_up", "w1", "w_uk", "w_uv",
                 "in_proj", "frontend_proj", "vision_proj", "lm_head"}
_ROW_PARALLEL = {"wo", "w2", "out_proj"}


def _param_spec(mesh: Mesh, path: Tuple[str, ...], shape: Tuple[int, ...]
                ) -> P:
    name = path[-1]
    in_moe_experts = ("moe" in path and "shared" not in path
                      and name in ("wi_gate", "wi_up", "wo"))
    fsdp = data_axes(mesh)

    if len(shape) == 0 or min(shape) == 0:
        return P()

    def pad(tail: Sequence) -> P:
        """Left-pad with None for stacked layer dims."""
        lead = len(shape) - len(tail)
        return P(*([None] * lead + list(tail)))

    if in_moe_experts:
        # (E, d, f) or (E, f, d): experts on model, fsdp on the larger inner dim
        e, a, b = shape[-3], shape[-2], shape[-1]
        return pad([_fit(mesh, e, "model"),
                    _fit(mesh, a, fsdp), None])
    if name == "router":
        return pad([_fit(mesh, shape[-2], fsdp), None])
    if name == "embed":
        return P(_fit(mesh, shape[0], "model"), _fit(mesh, shape[1], fsdp))
    if name in _COL_PARALLEL and len(shape) >= 2:
        return pad([_fit(mesh, shape[-2], fsdp),
                    _fit(mesh, shape[-1], "model")])
    if name in _ROW_PARALLEL and len(shape) >= 2:
        return pad([_fit(mesh, shape[-2], "model"),
                    _fit(mesh, shape[-1], fsdp)])
    if name == "w_dkv" and len(shape) >= 2:   # MLA down-proj: small, fsdp only
        return pad([_fit(mesh, shape[-2], fsdp), None])
    if name == "conv_w":
        return pad([None, _fit(mesh, shape[-1], "model")])
    # scales, biases, A_log, D, dt_bias, kv_norm ... replicated
    return P(*([None] * len(shape)))


def param_specs(mesh: Mesh, params_shapes) -> Any:
    """Map a pytree of ShapeDtypeStruct/arrays to PartitionSpecs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        names = tuple(_key_name(p) for p in path)
        specs.append(_param_spec(mesh, names, tuple(leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _key_name(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------
def batch_specs(mesh: Mesh, batch_shapes: Dict[str, Any]) -> Dict[str, P]:
    da = data_axes(mesh)
    out = {}
    for k, v in batch_shapes.items():
        shape = tuple(v.shape)
        if k == "positions_3d":            # (3, b, s)
            out[k] = P(None, _fit(mesh, shape[1], da), None)
        else:                               # (b, ...) leading batch
            out[k] = P(*( [_fit(mesh, shape[0], da)]
                          + [None] * (len(shape) - 1)))
    return out


def cache_specs(mesh: Mesh, cache_shapes) -> Any:
    """Decode-cache specs: (layer-stack, batch, ...) leaves."""
    da = data_axes(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    specs = []
    for path, leaf in flat:
        name = _key_name(path[-1])
        shape = tuple(leaf.shape)
        spec = [None] * len(shape)
        if len(shape) >= 2:
            spec[1] = _fit(mesh, shape[1], da)          # batch dim
        if name in ("k", "v", "ck", "cv") and len(shape) == 5:
            # (L, b, h, S, hd): heads on model if divisible, else seq
            h_ax = _fit(mesh, shape[2], "model")
            if h_ax is not None:
                spec[2] = h_ax
            else:
                spec[3] = _fit(mesh, shape[3], "model")
            if spec[1] is None and spec[3] is None:
                # batch unshardable (b=1): spread sequence over everything
                spec[3] = _fit(mesh, shape[3],
                               (da, "model") if isinstance(da, str)
                               else tuple(da) + ("model",))
                if spec[3] is not None:
                    spec[2] = None
        elif name in ("c_kv", "k_rope") and len(shape) == 4:
            # (L, b, S, dim): sequence on model
            spec[2] = _fit(mesh, shape[2], "model")
            if spec[1] is None and spec[2] is not None:
                full = (da, "model") if isinstance(da, str) else tuple(da) + ("model",)
                alt = _fit(mesh, shape[2], full)
                if alt is not None:
                    spec[2] = alt
        elif name == "ssm" and len(shape) == 5:
            spec[2] = _fit(mesh, shape[2], "model")     # heads
        elif name == "conv" and len(shape) == 4:
            spec[3] = _fit(mesh, shape[3], "model")     # channels
        specs.append(P(*spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Activation rules for models.common.activation_mesh
# ---------------------------------------------------------------------------
def activation_rules(mesh: Mesh) -> Dict[str, Any]:
    da = data_axes(mesh)
    return {"batch": da, "heads": "model", "ffn": "model",
            "vocab": "model", "expert": "model", "residual": "model"}
