"""repro: a multi-pod JAX training/serving framework implementing SCOPE
(Scalable and Controllable Outcome Performance Estimator) routing.

Public routing surface: ``repro.api`` (ScopeEngine, PoolRegistry,
RoutingPolicy, PredictionCache)."""

__version__ = "0.1.0"
