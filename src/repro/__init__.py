"""repro: a multi-pod JAX training/serving framework implementing SCOPE
(Scalable and Controllable Outcome Performance Estimator) routing."""

__version__ = "0.1.0"
