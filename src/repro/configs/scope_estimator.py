"""SCOPE's own estimator backbones.

``scope-qwen3-4b``: the paper's Qwen3-4B-Instruct-2507-shaped backbone.
``scope-tiny``: the CPU-trainable variant used by the end-to-end examples,
tests, and benchmarks (same family: dense GQA + RoPE + qk-norm).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="scope-qwen3-4b",
    arch_type="dense",
    source="arXiv:2505.09388 (Qwen3 technical report)",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    block_pattern=("attn",),
    qk_norm=True,
    rope_theta=1000000.0,
    supports_long_context=False,
)

TINY = ModelConfig(
    name="scope-tiny",
    arch_type="dense",
    source="reduced scope-qwen3-4b for CPU training",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=384,
    vocab_size=512,               # matches repro.data.tokenizer VOCAB_SIZE
    block_pattern=("attn",),
    qk_norm=True,
    rope_theta=10000.0,
    dtype="float32",
    supports_long_context=False,
)
