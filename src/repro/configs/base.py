"""Configuration dataclasses for the repro framework.

A single ``ModelConfig`` covers every assigned architecture family (dense
GQA, MoE, MLA, SSM, hybrid, encoder-decoder, VLM/audio backbones).  Layer
stacks are described by a repeating ``block_pattern`` of ``BlockKind``
strings; the model builder scans over stacked per-layer parameters so the
traced HLO stays small regardless of depth.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------
# "attn"        : global (full-window) self-attention + MLP
# "attn_local"  : sliding-window self-attention + MLP
# "mla"         : multi-head latent attention (DeepSeek-V2) + MLP
# "moe"         : global self-attention + MoE FFN
# "mla_moe"     : MLA attention + MoE FFN
# "mamba"       : Mamba2 SSD block (attention-free)
# "mamba_shared": Mamba2 block followed by a *shared* attention block
#                 (Zamba2: shared params reused at every occurrence)
VALID_BLOCK_KINDS = (
    "attn", "attn_local", "mla", "moe", "mla_moe", "mamba", "mamba_shared",
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""                    # paper / model card citation

    # Core transformer dims
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                   # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # Layer stacking: the pattern repeats until num_layers blocks are placed.
    block_pattern: Tuple[str, ...] = ("attn",)

    # Attention options
    rope_theta: float = 10000.0
    rope_kind: str = "standard"         # standard | mrope | none
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # t/h/w head-dim split
    sliding_window: int = 4096          # used by attn_local blocks
    logit_softcap: float = 0.0          # gemma2: 50.0 on attention logits
    final_logit_softcap: float = 0.0    # gemma2: 30.0 on lm head
    attn_scale: float = 0.0             # 0 -> 1/sqrt(head_dim)
    qk_norm: bool = False

    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 512
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                   # per-expert hidden (0 -> d_ff)
    first_dense_layers: int = 0         # DeepSeek-V2: layer 0 dense
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0                  # 0 -> d_inner // ssm_head_dim
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv_width: int = 4
    shared_attn_every: int = 6          # zamba2: shared attn after every k-th mamba

    # Encoder-decoder (whisper-style)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500         # stub audio frame count

    # Multimodal stub frontend (vlm / audio)
    num_stub_patches: int = 0           # vlm: patch embeddings prepended

    # Norm / misc
    rmsnorm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    mlp_kind: str = "swiglu"            # swiglu | gelu
    sandwich_norm: bool = False         # gemma2 post-norms
    scale_embeddings: bool = False      # gemma2: embed * sqrt(d_model)
    force_window: int = 0               # >0: every attn layer windowed (long-context variant)

    # Long-context policy
    supports_long_context: bool = False     # may lower long_500k
    long_context_window: int = 4096         # window used by the long variant

    def __post_init__(self):
        for k in self.block_pattern:
            if k not in VALID_BLOCK_KINDS:
                raise ValueError(f"unknown block kind {k!r}")

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def resolved_ssm_heads(self) -> int:
        return self.ssm_heads or self.d_inner // self.ssm_head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expand block_pattern to exactly num_layers entries."""
        reps = math.ceil(self.num_layers / len(self.block_pattern))
        return tuple((self.block_pattern * reps)[: self.num_layers])

    def is_attention_free(self) -> bool:
        return all(k in ("mamba",) for k in self.layer_kinds())

    def has_moe(self) -> bool:
        return any(k in ("moe", "mla_moe") for k in self.layer_kinds())

    def has_ssm(self) -> bool:
        return any(k.startswith("mamba") for k in self.layer_kinds())

    # ------------------------------------------------------------------
    def reduced(self, *, num_layers: int = 2, d_model: int = 256,
                num_heads: int = 4, vocab_size: int = 512,
                max_experts: int = 4) -> "ModelConfig":
        """A smoke-test variant of the same family (CPU-runnable)."""
        head_dim = max(32, d_model // num_heads)
        kv = max(1, min(self.num_kv_heads, num_heads))
        # keep the family's pattern but shrink counts
        changes = {
            "num_layers": num_layers,
            "d_model": d_model,
            "num_heads": num_heads,
            "num_kv_heads": kv,
            "head_dim": head_dim,
            "d_ff": d_model * 4,
            "vocab_size": vocab_size,
            "sliding_window": 64,
            "long_context_window": 64,
            "encoder_seq_len":
                32 if self.is_encoder_decoder else self.encoder_seq_len,
            "num_encoder_layers": 2 if self.is_encoder_decoder else 0,
            "num_stub_patches": 8 if self.num_stub_patches else 0,
            "dtype": "float32",
        }
        if self.has_moe():
            changes.update(
                num_experts=min(self.num_experts, max_experts),
                num_experts_per_tok=min(self.num_experts_per_tok, 2),
                num_shared_experts=min(self.num_shared_experts, 1),
                moe_d_ff=d_model * 2,
                first_dense_layers=min(self.first_dense_layers, 1),
            )
        if self.has_ssm():
            changes.update(
                ssm_state=min(self.ssm_state or 16, 16),
                ssm_head_dim=32,
                ssm_heads=0,
                ssm_chunk=16,
                shared_attn_every=2,
            )
        if self.rope_kind == "mrope":
            t = max(4, (head_dim // 4) // 2 * 2)
            hw = (head_dim - t) // 2
            changes.update(mrope_sections=(t, hw, head_dim - t - hw))
        if self.kv_lora_rank and any(k.startswith("mla") for k in self.layer_kinds()):
            changes.update(kv_lora_rank=64, qk_rope_head_dim=16,
                           qk_nope_head_dim=32, v_head_dim=32)
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                           # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) should lower; returns (ok, reason-if-skip)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch: long_500k requires sub-quadratic attention"
    return True, ""


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """Sub-quadratic variant for long_500k: every attention layer becomes
    sliding-window (SSM layers untouched).  Deviation recorded in DESIGN.md."""
    return dataclasses.replace(cfg, force_window=cfg.long_context_window)
