"""Gemma2-2B — dense, alternating local/global attention, logit softcaps
[arXiv:2408.00118]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    source="arXiv:2408.00118",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    block_pattern=("attn_local", "attn"),
    sliding_window=4096,
    logit_softcap=50.0,
    final_logit_softcap=30.0,
    sandwich_norm=True,
    scale_embeddings=True,
    supports_long_context=True,
    long_context_window=4096,
)
