"""DeepSeek-V2-Lite-16B — MLA (kv_lora=512) + MoE (2 shared + 64 routed,
top-6), first layer dense [arXiv:2405.04434]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    source="arXiv:2405.04434",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,                   # dense-layer FFN width
    vocab_size=102400,
    block_pattern=("mla_moe",),
    first_dense_layers=1,         # layer 0 is MLA + dense FFN
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    supports_long_context=False,
)
