"""Zamba2-7B — hybrid Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 Mamba2 layers; a *shared* (parameter-tied) attention+MLP block is applied
after every ``shared_attn_every``-th Mamba layer.  SSM state makes long_500k
decode O(1); the shared attention layers use a sliding window in the
long-context variant.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    source="arXiv:2411.15242",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("mamba",),      # shared attn handled via shared_attn_every
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    shared_attn_every=6,
    sliding_window=4096,
    supports_long_context=True,
    long_context_window=4096,
)
