"""Whisper-medium transformer backbone — encoder-decoder [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings of shape (batch, encoder_seq_len, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    source="arXiv:2212.04356",
    num_layers=24,                 # decoder layers
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51872,               # 51865 padded to /16 for TP (§Perf)
    block_pattern=("attn",),
    is_encoder_decoder=True,
    encoder_seq_len=1500,
    rope_kind="none",              # whisper uses learned/sinusoidal positions
    tie_embeddings=True,
    mlp_kind="gelu",
    supports_long_context=False,
)
