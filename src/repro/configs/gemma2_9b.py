"""Gemma2-9B — dense, alternating local/global attention, logit softcaps
[arXiv:2408.00118].

long_500k uses the long-context variant: global layers fall back to the
4096-token sliding window (deviation recorded in DESIGN.md §3).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    arch_type="dense",
    source="arXiv:2408.00118",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    block_pattern=("attn_local", "attn"),
    sliding_window=4096,
    logit_softcap=50.0,
    final_logit_softcap=30.0,
    sandwich_norm=True,
    scale_embeddings=True,
    supports_long_context=True,
    long_context_window=4096,
)
