"""Config registry: ``get_config(arch_id)`` and the assigned lists."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    shape_applicable,
)
from repro.configs import (  # noqa: F401
    starcoder2_3b,
    whisper_medium,
    internlm2_1_8b,
    zamba2_7b,
    gemma2_9b,
    qwen2_vl_7b,
    qwen3_moe_235b_a22b,
    gemma2_2b,
    mamba2_1_3b,
    deepseek_v2_lite_16b,
    scope_estimator,
)

_REGISTRY: Dict[str, ModelConfig] = {}
for _mod in (
    starcoder2_3b, whisper_medium, internlm2_1_8b, zamba2_7b, gemma2_9b,
    qwen2_vl_7b, qwen3_moe_235b_a22b, gemma2_2b, mamba2_1_3b,
    deepseek_v2_lite_16b, scope_estimator,
):
    _REGISTRY[_mod.CONFIG.name] = _mod.CONFIG
_REGISTRY[scope_estimator.TINY.name] = scope_estimator.TINY

ASSIGNED_ARCHS = (
    "starcoder2-3b",
    "whisper-medium",
    "internlm2-1.8b",
    "zamba2-7b",
    "gemma2-9b",
    "qwen2-vl-7b",
    "qwen3-moe-235b-a22b",
    "gemma2-2b",
    "mamba2-1.3b",
    "deepseek-v2-lite-16b",
)


def get_config(name: str) -> ModelConfig:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}") from None


def list_configs():
    return dict(_REGISTRY)


__all__ = [
    "ModelConfig", "InputShape", "INPUT_SHAPES", "shape_applicable",
    "get_config", "list_configs", "ASSIGNED_ARCHS",
]
