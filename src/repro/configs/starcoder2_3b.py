"""StarCoder2-3B — dense GQA decoder [arXiv:2402.19173]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    source="arXiv:2402.19173",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    block_pattern=("attn",),
    rope_theta=999999.0,
    supports_long_context=False,
)
