"""Qwen3-MoE-235B-A22B — 128 experts, top-8 [hf:Qwen/Qwen3-30B-A3B family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                    # per-expert hidden
    vocab_size=151936,
    block_pattern=("moe",),
    num_experts=128,
    num_experts_per_tok=8,
    moe_d_ff=1536,
    qk_norm=True,
    rope_theta=1000000.0,
    supports_long_context=False,
)
