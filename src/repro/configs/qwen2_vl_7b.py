"""Qwen2-VL-7B language backbone — M-RoPE, dynamic resolution [arXiv:2409.12191].

The ViT vision encoder + projector is a STUB: ``input_specs`` provides
precomputed patch embeddings (batch, num_stub_patches, d_model) which the
model scatters into the token stream; positions carry 3D (t,h,w) M-RoPE ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    source="arXiv:2409.12191",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    block_pattern=("attn",),
    rope_kind="mrope",
    mrope_sections=(16, 56, 56),   # sums to head_dim 128
    rope_theta=1000000.0,
    num_stub_patches=256,
    supports_long_context=False,
)
