"""Mamba2-1.3B — pure SSM, SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=2048,
    num_heads=0,                  # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50288,             # 50280 padded to /16 for TP (§Perf)
    block_pattern=("mamba",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    shared_attn_every=0,          # no shared attention (pure SSM)
    supports_long_context=True,
)
