"""Result status codes threaded through the serve stack.

Every answered (query, model) pair carries one of four statuses:

  OK        — the reasoning estimator decoded and parsed the pair
  DEGRADED  — the pair was answered from retrieval priors
              (``FallbackEstimator``): it was quarantined after repeated
              failures, expired past its SLO deadline, or degradation was
              requested directly
  FAILED    — the pair could not be answered at all (degradation disabled);
              its prediction fields are the malformed-estimate fallback
  DRIFTED   — the pair's estimate is a real decode, but its model's drift
              detector has alarmed (``serving.feedback``): the fingerprint
              it was conditioned on no longer matches the deployed model.
              Health-wise DRIFTED sits *between* OK and DEGRADED — the
              numbers are genuine yet stale, better than a retrieval prior
              but worse than a trusted decode — and an OK write after
              ``onboard(refresh=True)`` heals it (see
              ``PredictionCache._rank``).

The codes are small ints so they travel as numpy columns through
``ParsedBatch`` / ``PoolPredictions`` / ``CachedBatch``; ``status_name``
maps them back to the string surfaced on ``RouteDecision``.  DRIFTED is
appended as code 3 (the names tuple is ordinal-indexed), so existing
columns and checkpointed stats keep their values; its health *rank* is
what places it between OK and DEGRADED, not its numeric code.
"""
from __future__ import annotations

STATUS_OK = 0
STATUS_DEGRADED = 1
STATUS_FAILED = 2
STATUS_DRIFTED = 3

STATUS_NAMES = ("OK", "DEGRADED", "FAILED", "DRIFTED")


def status_name(code: int) -> str:
    return STATUS_NAMES[int(code)]
