"""Result status codes threaded through the serve stack.

Every answered (query, model) pair carries one of three statuses:

  OK        — the reasoning estimator decoded and parsed the pair
  DEGRADED  — the pair was answered from retrieval priors
              (``FallbackEstimator``): it was quarantined after repeated
              failures, expired past its SLO deadline, or degradation was
              requested directly
  FAILED    — the pair could not be answered at all (degradation disabled);
              its prediction fields are the malformed-estimate fallback

The codes are small ints so they travel as numpy columns through
``ParsedBatch`` / ``PoolPredictions`` / ``CachedBatch``; ``status_name``
maps them back to the string surfaced on ``RouteDecision``.
"""
from __future__ import annotations

STATUS_OK = 0
STATUS_DEGRADED = 1
STATUS_FAILED = 2

STATUS_NAMES = ("OK", "DEGRADED", "FAILED")


def status_name(code: int) -> str:
    return STATUS_NAMES[int(code)]
