"""Utility formulation and cost normalization (SCOPE §5.1, App. B.3).

  c~      — log-transformed min-max normalization (Eq. 11)
  gamma   — dynamic cost sensitivity gamma_dyn = gamma_base*(1+beta*(1-a)) (Eq. 13)
  u       — alpha * p_hat + (1-alpha) * (1-c~)^gamma_dyn (Eq. 12)
"""
from __future__ import annotations

from typing import Optional

import numpy as np

EPS = 1e-6


def normalize_cost(costs: np.ndarray, *, c_min: Optional[float] = None,
                   c_max: Optional[float] = None,
                   axis: Optional[int] = None) -> np.ndarray:
    """Log min-max normalization (Eq. 11); bounds default to the given set
    (per-query predicted costs online, per-cluster costs in calibration).

    ``axis`` takes bounds per slice along that axis — e.g. ``axis=1`` on a
    (Q, M) cost matrix normalizes each query row independently, matching a
    per-row loop of the scalar form.  Explicit ``c_min``/``c_max`` bounds
    are incompatible with ``axis``.
    """
    c = np.asarray(costs, np.float64)
    if axis is not None:
        if c_min is not None or c_max is not None:
            raise ValueError("pass either axis or explicit bounds, not both")
        lo = np.log(c.min(axis=axis, keepdims=True) + EPS)
        hi = np.log(c.max(axis=axis, keepdims=True) + EPS)
        span = hi - lo
        degenerate = span < 1e-12
        out = (np.log(c + EPS) - lo) / np.where(degenerate, 1.0, span)
        out = np.where(degenerate, 0.0, out)
        return np.clip(out, 0.0, 1.0)
    lo = np.log((c_min if c_min is not None else c.min()) + EPS)
    hi = np.log((c_max if c_max is not None else c.max()) + EPS)
    if hi - lo < 1e-12:
        return np.zeros_like(c)
    out = (np.log(c + EPS) - lo) / (hi - lo)
    return np.clip(out, 0.0, 1.0)


def gamma_dyn(alpha: float, *, gamma_base: float = 1.0,
              beta: float = 2.0) -> float:
    return gamma_base * (1.0 + beta * (1.0 - float(alpha)))


def cost_score(c_norm: np.ndarray, alpha: float, *, gamma_base: float = 1.0,
               beta: float = 2.0) -> np.ndarray:
    g = gamma_dyn(alpha, gamma_base=gamma_base, beta=beta)
    return np.power(np.clip(1.0 - np.asarray(c_norm), 0.0, 1.0), g)


def predicted_utility(p_hat: np.ndarray, c_norm: np.ndarray, alpha: float,
                      *, gamma_base: float = 1.0, beta: float = 2.0
                      ) -> np.ndarray:
    """Eq. 12 over aligned arrays of shape (..., M)."""
    s = cost_score(c_norm, alpha, gamma_base=gamma_base, beta=beta)
    return float(alpha) * np.asarray(p_hat, np.float64) + (1.0 - float(alpha)) * s


def w_cal(alpha: float, *, w_base: float = 0.2) -> float:
    """Dynamic calibration weight (Eq. 14): 0.1 at alpha=0 -> 0.2 at alpha=1."""
    return w_base * (0.5 + 0.5 * float(alpha))
