"""Router baselines the paper compares against (Table 1, Fig. 7).

Static: Random / Cheapest / Most-Expensive.
Supervised classifiers over query embeddings (trained on the same data as
SCOPE): KNN, MLP, Linear-hinge ("SVM").  Labels follow the oracle policy
(cheapest model that answers correctly; cheapest overall if none do).
Decision-rule baselines for the Fig. 7 ablation: augmented Chebyshev
scalarization and Highest-Cost-under-budget.  Plus test-time scaling (TTS):
execute every model, keep the best outcome (Fig. 9 token comparison).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.utility import normalize_cost
from repro.data.datasets import ScopeData
from repro.data.worldsim import Query, World
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


# ---------------------------------------------------------------------------
# Oracle / labels
# ---------------------------------------------------------------------------
def oracle_choice(data: ScopeData, qid: int, models: Sequence[str]) -> int:
    """Cheapest model that answers correctly; cheapest overall otherwise."""
    recs = [data.record(qid, m) for m in models]
    correct = [i for i, r in enumerate(recs) if r.y == 1]
    pool = correct if correct else range(len(models))
    return min(pool, key=lambda i: recs[i].cost)


def oracle_labels(data: ScopeData, qids: Sequence[int],
                  models: Sequence[str]) -> np.ndarray:
    return np.array([oracle_choice(data, int(q), models) for q in qids])


# ---------------------------------------------------------------------------
# Static baselines
# ---------------------------------------------------------------------------
def random_choices(n: int, num_models: int, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).integers(0, num_models, n)


def price_rank_choice(world: World, models: Sequence[str],
                      highest: bool) -> int:
    prices = [world.models[m].price_out for m in models]
    return int(np.argmax(prices) if highest else np.argmin(prices))


# ---------------------------------------------------------------------------
# KNN router
# ---------------------------------------------------------------------------
class KNNRouter:
    def __init__(self, k: int = 8):
        self.k = k
        self._embs: Optional[np.ndarray] = None
        self._labels: Optional[np.ndarray] = None
        self.num_models = 0

    def fit(self, embs: np.ndarray, labels: np.ndarray, num_models: int):
        self._embs = embs / (np.linalg.norm(embs, axis=1, keepdims=True) + 1e-8)
        self._labels = labels
        self.num_models = num_models

    def predict(self, embs: np.ndarray) -> np.ndarray:
        q = embs / (np.linalg.norm(embs, axis=1, keepdims=True) + 1e-8)
        sims = q @ self._embs.T
        nn = np.argsort(-sims, axis=1)[:, : self.k]
        votes = self._labels[nn]                          # (Q, k)
        out = np.zeros(len(embs), int)
        for i, v in enumerate(votes):
            out[i] = np.bincount(v, minlength=self.num_models).argmax()
        return out


# ---------------------------------------------------------------------------
# MLP router (jax)
# ---------------------------------------------------------------------------
class MLPRouter:
    def __init__(self, hidden: int = 64, steps: int = 400, lr: float = 1e-2,
                 seed: int = 0):
        self.hidden = hidden
        self.steps = steps
        self.lr = lr
        self.seed = seed
        self.params = None

    def fit(self, embs: np.ndarray, labels: np.ndarray, num_models: int):
        d = embs.shape[1]
        key = jax.random.PRNGKey(self.seed)
        k1, k2 = jax.random.split(key)
        params = {
            "w1": jax.random.normal(k1, (d, self.hidden)) * (1 / np.sqrt(d)),
            "b1": jnp.zeros((self.hidden,)),
            "w2": jax.random.normal(k2, (self.hidden, num_models))
                  * (1 / np.sqrt(self.hidden)),
            "b2": jnp.zeros((num_models,)),
        }
        x = jnp.asarray(embs)
        y = jnp.asarray(labels)
        ocfg = AdamWConfig(lr=self.lr, warmup_steps=10, total_steps=self.steps,
                           weight_decay=1e-4)
        ostate = adamw_init(params)

        def loss_fn(p):
            h = jnp.tanh(x @ p["w1"] + p["b1"])
            logits = h @ p["w2"] + p["b2"]
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))

        @jax.jit
        def step(p, s):
            loss, g = jax.value_and_grad(loss_fn)(p)
            p, s = adamw_update(ocfg, g, s, p)
            return p, s, loss

        for _ in range(self.steps):
            params, ostate, _ = step(params, ostate)
        self.params = jax.tree.map(np.asarray, params)

    def predict(self, embs: np.ndarray) -> np.ndarray:
        p = self.params
        h = np.tanh(embs @ p["w1"] + p["b1"])
        return np.argmax(h @ p["w2"] + p["b2"], axis=1)


# ---------------------------------------------------------------------------
# Linear hinge router ("SVM")
# ---------------------------------------------------------------------------
class LinearSVMRouter:
    def __init__(self, steps: int = 400, lr: float = 5e-3, margin: float = 1.0,
                 seed: int = 0):
        self.steps = steps
        self.lr = lr
        self.margin = margin
        self.seed = seed
        self.params = None

    def fit(self, embs: np.ndarray, labels: np.ndarray, num_models: int):
        d = embs.shape[1]
        params = {
            "w": jax.random.normal(jax.random.PRNGKey(self.seed),
                                   (d, num_models)) * (1 / np.sqrt(d)),
            "b": jnp.zeros((num_models,)),
        }
        x = jnp.asarray(embs)
        y = jnp.asarray(labels)
        ocfg = AdamWConfig(lr=self.lr, warmup_steps=10, total_steps=self.steps,
                           weight_decay=1e-3)
        ostate = adamw_init(params)

        def loss_fn(p):
            scores = x @ p["w"] + p["b"]                   # (N, M)
            true = jnp.take_along_axis(scores, y[:, None], 1)
            viol = jnp.maximum(0.0, self.margin + scores - true)
            viol = viol * (1 - jax.nn.one_hot(y, scores.shape[1]))
            return jnp.mean(jnp.sum(viol, axis=1))

        @jax.jit
        def step(p, s):
            loss, g = jax.value_and_grad(loss_fn)(p)
            p, s = adamw_update(ocfg, g, s, p)
            return p, s, loss

        for _ in range(self.steps):
            params, ostate, _ = step(params, ostate)
        self.params = jax.tree.map(np.asarray, params)

    def predict(self, embs: np.ndarray) -> np.ndarray:
        p = self.params
        return np.argmax(embs @ p["w"] + p["b"], axis=1)


# ---------------------------------------------------------------------------
# Decision-rule baselines over SCOPE's own predictions (Fig. 7 left)
# ---------------------------------------------------------------------------
def chebyshev_choices(p_hat: np.ndarray, cost_hat: np.ndarray, alpha: float,
                      rho: float = 0.05) -> np.ndarray:
    """Augmented Chebyshev scalarization (minimize the max weighted regret)."""
    Q, M = p_hat.shape
    out = np.zeros(Q, int)
    for q in range(Q):
        c = normalize_cost(cost_hat[q])
        t1 = alpha * (1.0 - p_hat[q])
        t2 = (1.0 - alpha) * c
        score = np.maximum(t1, t2) + rho * (t1 + t2)
        out[q] = int(np.argmin(score))
    return out


def highest_cost_choices(cost_hat: np.ndarray, per_query_budget: float
                         ) -> np.ndarray:
    """Always the most expensive model within the per-query budget."""
    Q, M = cost_hat.shape
    out = np.zeros(Q, int)
    for q in range(Q):
        ok = np.where(cost_hat[q] <= per_query_budget)[0]
        out[q] = int(ok[np.argmax(cost_hat[q][ok])]) if len(ok) \
            else int(np.argmin(cost_hat[q]))
    return out


# ---------------------------------------------------------------------------
# Test-time scaling (Fig. 9)
# ---------------------------------------------------------------------------
def tts_outcome(data: ScopeData, qid: int, models: Sequence[str]
                ) -> Tuple[int, int, float]:
    """Execute all models; pick best (correct, cheapest).  Returns
    (accuracy, total tokens executed, total $)."""
    recs = [data.record(qid, m) for m in models]
    tokens = sum(r.tokens for r in recs)
    cost = sum(r.cost for r in recs)
    acc = int(any(r.y == 1 for r in recs))
    return acc, tokens, cost
