"""Budget-controlled alpha selection (SCOPE Appendix D, Prop. D.1).

For a query set X and budget B, find alpha* maximizing the expected accuracy
proxy P(alpha; X) subject to C(alpha; X) <= B.  Per Prop. D.1, routing
decisions under the affine score u = alpha*p + (1-alpha)*s only change at
pairwise intersection breakpoints; enumerating {0, 1, breakpoints, interval
representatives} suffices.

Everything here is vectorized numpy — the policies (``SetBudgetPolicy``,
``AccuracyFloorPolicy``) run this per serve batch, so the O(Q*M^2) pairwise
intersection enumeration and the O(A*Q*M) candidate sweep must not be
Python loops.  Float comparisons use ``TIE_TOL``: breakpoints are deduped
with a tolerance (exact ``set()`` dedup on floats kept near-identical
alphas that route identically) and the best-candidate tiebreak treats
performances within the tolerance as equal (an exact ``==`` tiebreak is
brittle under reordered float sums).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

TIE_TOL = 1e-9          # tolerance for dedup + perf/cost tie-breaking
_PARALLEL_EPS = 1e-12   # slopes closer than this never intersect usefully
_SWEEP_BLOCK = 256      # candidate alphas per vectorized routing block


def route_for_alpha(p_hat: np.ndarray, s_hat: np.ndarray, alpha: float
                    ) -> np.ndarray:
    """Affine decision (Eq. 17) with deterministic lowest-index tiebreak.

    p_hat, s_hat: (Q, M).  Returns argmax indices (Q,).
    """
    u = alpha * p_hat + (1.0 - alpha) * s_hat
    return np.argmax(u, axis=1)            # np.argmax: first max index


def route_for_alphas(p_hat: np.ndarray, s_hat: np.ndarray,
                     alphas: np.ndarray, *, block: int = _SWEEP_BLOCK
                     ) -> np.ndarray:
    """Vectorized ``route_for_alpha`` over a whole candidate set.

    Returns (A, Q) argmax indices.  Blocked so the (A, Q, M) utility tensor
    never materializes for large candidate sets (A grows as Q*M^2).
    """
    alphas = np.asarray(alphas, np.float64)
    A, Q = len(alphas), p_hat.shape[0]
    out = np.empty((A, Q), np.int64)
    for i in range(0, A, block):
        a = alphas[i: i + block][:, None, None]
        u = a * p_hat[None] + (1.0 - a) * s_hat[None]
        out[i: i + len(u)] = np.argmax(u, axis=2)
    return out


def breakpoints(p_hat: np.ndarray, s_hat: np.ndarray, *,
                tol: float = TIE_TOL) -> np.ndarray:
    """All pairwise intersection alphas in (0, 1) (Eq. 22-23).

    One vectorized pass over the upper-triangle (i, j) pair grid; sorted and
    deduped with ``tol``.
    """
    p = np.asarray(p_hat, np.float64)
    s = np.asarray(s_hat, np.float64)
    M = p.shape[1]
    if M < 2:
        return np.zeros(0)
    iu, ju = np.triu_indices(M, k=1)
    slopes = p - s                                   # (Q, M)
    denom = slopes[:, iu] - slopes[:, ju]            # (Q, P)
    num = s[:, ju] - s[:, iu]
    ok = np.abs(denom) >= _PARALLEL_EPS
    a = num[ok] / denom[ok]
    a = a[(a > 0.0) & (a < 1.0)]
    if a.size == 0:
        return np.zeros(0)
    a = np.sort(a)
    keep = np.empty(a.shape, bool)
    keep[0] = True
    np.greater(np.diff(a), tol, out=keep[1:])
    return a[keep]


def candidate_alphas(p_hat: np.ndarray, s_hat: np.ndarray) -> np.ndarray:
    """{0, 1} + breakpoints + interval representatives (Prop. D.1)."""
    bps = breakpoints(p_hat, s_hat)
    grid = np.concatenate([[0.0], bps, [1.0]])
    reps = (grid[:-1] + grid[1:]) / 2.0
    return np.unique(np.concatenate([grid, reps]))


def budget_alpha(p_hat: np.ndarray, s_hat: np.ndarray, c_hat: np.ndarray,
                 budget: float) -> Tuple[float, np.ndarray, Dict]:
    """Solve Eq. 20: maximize sum p_hat(chosen) s.t. sum c_hat(chosen) <= B.

    Returns (alpha*, choices (Q,), info).  If no alpha is feasible, falls
    back to the cheapest-cost alpha (most budget-conservative policy).
    Among feasible candidates, performances within ``TIE_TOL`` count as
    tied and the cheaper routing wins; remaining ties go to the smallest
    alpha (candidates are enumerated in ascending order).
    """
    cands = candidate_alphas(p_hat, s_hat)
    choices = route_for_alphas(p_hat, s_hat, cands)          # (A, Q)
    rows = np.arange(p_hat.shape[0])
    costs = np.asarray(c_hat, np.float64)[rows[None], choices].sum(axis=1)
    perfs = np.asarray(p_hat, np.float64)[rows[None], choices].sum(axis=1)

    cheapest_i = int(np.argmin(costs))                       # first min
    feas = costs <= budget
    feasible = bool(feas.any())
    if feasible:
        fi = np.flatnonzero(feas)
        best_perf = perfs[fi].max()
        tied = fi[perfs[fi] >= best_perf - TIE_TOL]          # perf ties
        best_i = int(tied[np.argmin(costs[tied])])           # cheapest, first
    else:
        best_i = cheapest_i
    return (float(cands[best_i]), choices[best_i],
            {"expected_cost": float(costs[best_i]),
             "expected_perf": float(perfs[best_i]),
             "feasible": feasible,
             "num_candidates": len(cands)})
