"""Budget-controlled alpha selection (SCOPE Appendix D, Prop. D.1).

For a query set X and budget B, find alpha* maximizing the expected accuracy
proxy P(alpha; X) subject to C(alpha; X) <= B.  Per Prop. D.1, routing
decisions under the affine score u = alpha*p + (1-alpha)*s only change at
pairwise intersection breakpoints; enumerating {0, 1, breakpoints, interval
representatives} suffices.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


def route_for_alpha(p_hat: np.ndarray, s_hat: np.ndarray, alpha: float
                    ) -> np.ndarray:
    """Affine decision (Eq. 17) with deterministic lowest-index tiebreak.

    p_hat, s_hat: (Q, M).  Returns argmax indices (Q,).
    """
    u = alpha * p_hat + (1.0 - alpha) * s_hat
    return np.argmax(u, axis=1)            # np.argmax: first max index


def breakpoints(p_hat: np.ndarray, s_hat: np.ndarray) -> np.ndarray:
    """All pairwise intersection alphas in (0, 1) (Eq. 22-23)."""
    Q, M = p_hat.shape
    slopes = p_hat - s_hat                  # (Q, M)
    pts = []
    for q in range(Q):
        for i in range(M):
            di = slopes[q, i]
            for j in range(i + 1, M):
                dj = slopes[q, j]
                if abs(di - dj) < 1e-12:
                    continue
                a = (s_hat[q, j] - s_hat[q, i]) / (di - dj)
                if 0.0 < a < 1.0:
                    pts.append(a)
    return np.asarray(sorted(set(pts)))


def candidate_alphas(p_hat: np.ndarray, s_hat: np.ndarray) -> np.ndarray:
    """{0, 1} + breakpoints + interval representatives (Prop. D.1)."""
    bps = breakpoints(p_hat, s_hat)
    grid = np.concatenate([[0.0], bps, [1.0]])
    reps = (grid[:-1] + grid[1:]) / 2.0
    return np.unique(np.concatenate([grid, reps]))


def budget_alpha(p_hat: np.ndarray, s_hat: np.ndarray, c_hat: np.ndarray,
                 budget: float) -> Tuple[float, np.ndarray, Dict]:
    """Solve Eq. 20: maximize sum p_hat(chosen) s.t. sum c_hat(chosen) <= B.

    Returns (alpha*, choices (Q,), info).  If no alpha is feasible, falls
    back to the cheapest-cost alpha (most budget-conservative policy).
    """
    cands = candidate_alphas(p_hat, s_hat)
    best: Optional[Tuple[float, float, float, np.ndarray]] = None
    cheapest: Optional[Tuple[float, float, float, np.ndarray]] = None
    for a in cands:
        choice = route_for_alpha(p_hat, s_hat, a)
        cost = float(np.sum(c_hat[np.arange(len(choice)), choice]))
        perf = float(np.sum(p_hat[np.arange(len(choice)), choice]))
        if cheapest is None or cost < cheapest[1]:
            cheapest = (a, cost, perf, choice)
        if cost <= budget and (best is None or perf > best[2]
                               or (perf == best[2] and cost < best[1])):
            best = (a, cost, perf, choice)
    feasible = best is not None
    if best is None:
        best = cheapest
    a, cost, perf, choice = best
    return float(a), choice, {"expected_cost": cost, "expected_perf": perf,
                              "feasible": feasible,
                              "num_candidates": len(cands)}
