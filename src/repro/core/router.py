"""The SCOPE router: fingerprint retrieval -> pre-hoc estimation ->
calibrated, budget-aware decision (SCOPE §5, Eq. 15/16/20).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import alpha_search, calibration, serialization, utility
from repro.core.estimator import Prediction, ReasoningEstimator
from repro.core.fingerprint import FingerprintLibrary
from repro.core.retrieval import AnchorRetriever
from repro.data.worldsim import PoolModel, Query

PROMPT_TOKENS_EST = 200.0       # serialized prompt size charged to the pool model


@dataclasses.dataclass
class PoolPredictions:
    """Pool-wide pre-hoc estimates for a query set (alpha-independent)."""
    models: List[str]
    p_hat: np.ndarray           # (Q, M) correctness confidence in [0,1]
    y_hat: np.ndarray           # (Q, M) binary labels
    len_hat: np.ndarray         # (Q, M) predicted completion tokens
    cost_hat: np.ndarray        # (Q, M) predicted $ per call
    well_formed: np.ndarray     # (Q, M) format gate
    pred_overhead: np.ndarray   # (Q, M) estimator tokens spent predicting
    sims: np.ndarray            # (Q, K) retrieval similarities
    idx: np.ndarray             # (Q, K) retrieved anchor ids


class ScopeRouter:
    def __init__(self, estimator: ReasoningEstimator,
                 retriever: AnchorRetriever, library: FingerprintLibrary,
                 models_meta: Dict[str, PoolModel],
                 model_indices: Dict[str, int], *, k: int = 5,
                 gamma_base: float = 1.0, beta: float = 2.0,
                 w_base: float = 0.2, use_confidence: bool = True):
        self.estimator = estimator
        self.retriever = retriever
        self.library = library
        self.models_meta = models_meta
        self.model_indices = model_indices
        self.k = k
        self.gamma_base = gamma_base
        self.beta = beta
        self.w_base = w_base
        self.use_confidence = use_confidence

    # ------------------------------------------------------------------
    def predict_pool(self, queries: Sequence[Query],
                     models: Sequence[str],
                     query_embs: Optional[np.ndarray] = None,
                     rng: Optional[jax.Array] = None) -> PoolPredictions:
        """Run the estimator for every (query, model) pair — Eq. 24's
        prediction overhead term; one batched engine pass."""
        models = list(models)
        Q, M = len(queries), len(models)
        if query_embs is None:
            query_embs = np.stack([q.embedding for q in queries])
        sims, idx = self.retriever.retrieve(query_embs, self.k)

        prompts: List[List[int]] = []
        for qi, q in enumerate(queries):
            for m in models:
                fp = self.library.get(m)
                meta = self.models_meta[m]
                prompts.append(serialization.serialize_prompt(
                    meta, self.model_indices.get(m, 0), self.library.anchor_set,
                    fp, sims[qi], idx[qi], q))
        preds = self.estimator.predict(prompts, rng=rng)

        p_hat = np.zeros((Q, M))
        y_hat = np.zeros((Q, M), int)
        len_hat = np.zeros((Q, M))
        cost_hat = np.zeros((Q, M))
        wf = np.zeros((Q, M), bool)
        overhead = np.zeros((Q, M))
        for qi in range(Q):
            for mi, m in enumerate(models):
                pr: Prediction = preds[qi * M + mi]
                meta = self.models_meta[m]
                p_hat[qi, mi] = pr.p_conf if self.use_confidence else float(pr.y_hat)
                y_hat[qi, mi] = pr.y_hat
                lh = pr.len_hat if pr.well_formed else 512.0
                len_hat[qi, mi] = lh
                cost_hat[qi, mi] = (PROMPT_TOKENS_EST * meta.price_in
                                    + lh * meta.price_out) / 1e6
                wf[qi, mi] = pr.well_formed
                overhead[qi, mi] = pr.pred_tokens
        return PoolPredictions(models, p_hat, y_hat, len_hat, cost_hat, wf,
                               overhead, sims, idx)

    # ------------------------------------------------------------------
    def utilities(self, pool: PoolPredictions, alpha: float,
                  *, with_calibration: bool = True) -> np.ndarray:
        """Final decision scores (Eq. 15) for each (query, model)."""
        Q, M = pool.p_hat.shape
        u_final = np.zeros((Q, M))
        wc = utility.w_cal(alpha, w_base=self.w_base) if with_calibration else 0.0
        fps = {m: self.library.get(m) for m in pool.models}
        for qi in range(Q):
            c_norm = utility.normalize_cost(pool.cost_hat[qi])
            u_pred = utility.predicted_utility(
                pool.p_hat[qi], c_norm, alpha,
                gamma_base=self.gamma_base, beta=self.beta)
            if with_calibration and wc > 0.0:
                u_cal = calibration.calibration_utilities(
                    fps, pool.models, pool.idx[qi], pool.sims[qi], alpha,
                    gamma_base=self.gamma_base, beta=self.beta)
            else:
                u_cal = np.zeros(M)
            u_final[qi] = (1.0 - wc) * u_pred + wc * u_cal
        return u_final

    def route(self, pool: PoolPredictions, alpha: float,
              *, with_calibration: bool = True) -> np.ndarray:
        """argmax model index per query (Eq. 15)."""
        return np.argmax(self.utilities(pool, alpha,
                                        with_calibration=with_calibration),
                         axis=1)

    # ------------------------------------------------------------------
    def route_with_budget(self, pool: PoolPredictions, budget: float
                          ) -> Tuple[float, np.ndarray, Dict]:
        """Appendix D: pick alpha* maximizing expected accuracy s.t. the
        set-level budget, via the Prop. D.1 finite breakpoint search."""
        Q, M = pool.p_hat.shape
        s_hat = np.zeros((Q, M))
        for qi in range(Q):
            c_norm = utility.normalize_cost(pool.cost_hat[qi])
            s_hat[qi] = utility.cost_score(c_norm, 1.0,
                                           gamma_base=self.gamma_base,
                                           beta=0.0)
        return alpha_search.budget_alpha(pool.p_hat, s_hat, pool.cost_hat,
                                         budget)
