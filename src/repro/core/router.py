"""The SCOPE router: fingerprint retrieval -> pre-hoc estimation ->
calibrated, budget-aware decision (SCOPE §5, Eq. 15/16/20).

``ScopeRouter`` is now a thin legacy shim over ``repro.api.ScopeEngine``
(see ``repro/api/engine.py`` for the canonical implementation); it keeps the
frozen-dict constructor signature for existing callers.  New code should
build a ``ScopeEngine`` directly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.data.worldsim import PoolModel, Query


@dataclasses.dataclass
class PoolPredictions:
    """Pool-wide pre-hoc estimates for a query set (alpha-independent)."""
    models: List[str]
    p_hat: np.ndarray           # (Q, M) correctness confidence in [0,1]
    y_hat: np.ndarray           # (Q, M) binary labels
    len_hat: np.ndarray         # (Q, M) predicted completion tokens
    cost_hat: np.ndarray        # (Q, M) predicted $ per call
    well_formed: np.ndarray     # (Q, M) format gate
    pred_overhead: np.ndarray   # (Q, M) estimator tokens spent on this call
    sims: np.ndarray            # (Q, K) retrieval similarities
    idx: np.ndarray             # (Q, K) retrieved anchor ids
    cache_hits: int = 0         # pairs served from the PredictionCache
    cache_misses: int = 0       # pairs that ran the estimator


class ScopeRouter:
    """Legacy facade: frozen model dicts in, engine-backed routing out.

    The shim runs uncached (every ``predict_pool`` call hits the estimator),
    matching the pre-engine behavior; use ``repro.api.ScopeEngine`` for the
    prediction cache and pluggable policies.
    """

    def __init__(self, estimator, retriever, library,
                 models_meta: Dict[str, PoolModel],
                 model_indices: Dict[str, int], *, k: int = 5,
                 gamma_base: float = 1.0, beta: float = 2.0,
                 w_base: float = 0.2, use_confidence: bool = True):
        self.estimator = estimator
        self.retriever = retriever
        self.library = library
        self.models_meta = models_meta
        self.model_indices = model_indices
        self.k = k
        self.gamma_base = gamma_base
        self.beta = beta
        self.w_base = w_base
        self.use_confidence = use_confidence
        # deferred import: repro.api depends on this module for the
        # PoolPredictions type, so the shim resolves the engine lazily
        from repro.api import EngineConfig, PoolRegistry, ScopeEngine
        registry = PoolRegistry(library, models_meta, indices=model_indices)
        self.engine = ScopeEngine.build(EngineConfig(
            estimator=estimator, retriever=retriever, library=library,
            registry=registry, k=k, gamma_base=gamma_base, beta=beta,
            w_base=w_base, use_confidence=use_confidence,
            enable_cache=False))

    # ------------------------------------------------------------------
    def predict_pool(self, queries: Sequence[Query],
                     models: Sequence[str],
                     query_embs: Optional[np.ndarray] = None,
                     rng: Optional[jax.Array] = None) -> PoolPredictions:
        """Run the estimator for every (query, model) pair — Eq. 24's
        prediction overhead term; one batched engine pass."""
        from repro.api import RouteRequest
        return self.engine.predict(
            RouteRequest(list(queries), models=list(models),
                         query_embs=query_embs), rng=rng)

    # ------------------------------------------------------------------
    def utilities(self, pool: PoolPredictions, alpha: float,
                  *, with_calibration: bool = True) -> np.ndarray:
        """Final decision scores (Eq. 15) for each (query, model)."""
        return self.engine.utilities(pool, alpha,
                                     with_calibration=with_calibration)

    def route(self, pool: PoolPredictions, alpha: float,
              *, with_calibration: bool = True) -> np.ndarray:
        """argmax model index per query (Eq. 15)."""
        return np.argmax(self.utilities(pool, alpha,
                                        with_calibration=with_calibration),
                         axis=1)

    # ------------------------------------------------------------------
    def route_with_budget(self, pool: PoolPredictions, budget: float
                          ) -> Tuple[float, np.ndarray, Dict]:
        """Appendix D: pick alpha* maximizing expected accuracy s.t. the
        set-level budget, via the Prop. D.1 finite breakpoint search."""
        from repro.api import SetBudgetPolicy
        d = self.engine.decide(pool, SetBudgetPolicy(budget))
        return float(d.alpha), d.choices, d.info
