"""Pool-wide prediction container for SCOPE routing.

``PoolPredictions`` is the alpha-independent product of the pre-hoc
estimation pass (SCOPE §5, Eq. 15/16/20): everything a ``RoutingPolicy``
needs to decide, for every (query, model) pair.  The decision math and the
serving verbs live on ``repro.api.ScopeEngine`` — the legacy ``ScopeRouter``
/ ``RouterService`` shims were removed once every caller migrated to the
engine + policy surface.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.status import STATUS_OK


@dataclasses.dataclass
class PoolPredictions:
    """Pool-wide pre-hoc estimates for a query set (alpha-independent)."""
    models: List[str]
    p_hat: np.ndarray           # (Q, M) correctness confidence in [0,1]
    y_hat: np.ndarray           # (Q, M) binary labels
    len_hat: np.ndarray         # (Q, M) predicted completion tokens
    cost_hat: np.ndarray        # (Q, M) predicted $ per call
    well_formed: np.ndarray     # (Q, M) format gate
    pred_overhead: np.ndarray   # (Q, M) estimator tokens spent on this call
    sims: np.ndarray            # (Q, K) retrieval similarities
    idx: np.ndarray             # (Q, K) retrieved anchor ids
    cache_hits: int = 0         # pairs served from the PredictionCache
    cache_misses: int = 0       # pairs that ran the estimator
    status: Optional[np.ndarray] = None     # (Q, M) core.status codes;
    #                                         None -> all OK (batch path)
    tier0_answered: int = 0     # pairs answered by the tier-0 pre-router
    escalated: int = 0          # pairs the gate sent to the reasoning
    #                             decode (== cache_misses with a tier-0
    #                             head configured; 0 without one)

    @property
    def degraded_fraction(self) -> float:
        """Fraction of (query, model) pairs not answered by a full
        estimator decode (DEGRADED or FAILED)."""
        if self.status is None or self.status.size == 0:
            return 0.0
        return float((self.status != STATUS_OK).mean())
