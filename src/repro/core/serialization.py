"""Prompt / target serialization (SCOPE Eq. 4, Appendix H).

P(x, M) = I || Ser(phi_K(x, M)) || x  becomes a structured token sequence:

  [BOS] <model-or-UNK> <reasoning|standard> <price-bucket> [SEP]
  { [ANCHOR] <domain> <sim-bucket> <yes|no> <len-bucket> } * K
  [QUERY] <domain> <feat tokens...> [PRED]

Targets (what the estimator must generate after [PRED]):
  CoT:    [THINK] <cnt-correct> <mean-len-bucket> <domain> [THINK_END]
          <yes|no> <len-bucket> [EOS]
  NoCoT:  <yes|no> <len-bucket> [EOS]

The CoT rationale mirrors hindsight distillation: a teacher conditioned on
realized outcomes emits a concise, grounded analysis (here: the sufficient
statistics of the retrieved fingerprint slice).  Token budget ~6 vs the
untrained model's free-form rambling — the source of the paper's 90%
predictor-overhead reduction (Appendix E).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.fingerprint import AnchorSet, Fingerprint
from repro.data import tokenizer as tok
from repro.data.worldsim import PoolModel, Query

MAX_PROMPT_LEN = 64          # 5 anchors x 5 + query block + header < 64
MAX_TARGET_LEN = 12
SEQ_LEN = 80                 # prompt + target padded length


def serialize_prompt(model: PoolModel, model_index: int,
                     anchor_set: AnchorSet, fp: Fingerprint,
                     sims: np.ndarray, idx: np.ndarray,
                     query: Query) -> List[int]:
    """Build the estimator prompt for (query, model) with retrieved anchors."""
    toks = [tok.BOS,
            tok.model_token(model_index, model.seen),
            tok.REASONING if model.reasoning else tok.STANDARD,
            tok.PRICE_BASE + tok.price_bucket(model.price_out),
            tok.SEP]
    for s, i in zip(sims, idx, strict=True):
        aq = anchor_set.queries[int(i)]
        toks += [tok.ANCHOR,
                 tok.domain_token(aq.domain),
                 tok.SIM_BASE + tok.sim_bucket(float(s)),
                 # round, not truncate: a buffer-refreshed fingerprint
                 # (serving.feedback) carries expected correctness in
                 # [0, 1]; binary fingerprints round to themselves
                 tok.yesno(int(round(float(fp.y[int(i)])))),
                 tok.LEN_BASE + tok.len_bucket(float(fp.tokens[int(i)]))]
    toks += [tok.QUERY, tok.domain_token(query.domain)]
    toks += tok.feat_tokens(query.embedding)
    toks += [tok.PRED]
    return toks


def teacher_target(fp_slice_y: Sequence[int], fp_slice_tokens: Sequence[float],
                   y_gt: int, len_gt: float, query: Query,
                   *, cot: bool = True) -> List[int]:
    """Hindsight-distillation target: concise grounded rationale + prediction."""
    out: List[int] = []
    if cot:
        cnt = int(np.sum(fp_slice_y))
        mean_len = float(np.mean(fp_slice_tokens)) if len(fp_slice_tokens) else 64.0
        out += [tok.THINK,
                tok.cnt_token(cnt),
                tok.LEN_BASE + tok.len_bucket(mean_len),
                tok.domain_token(query.domain),
                tok.THINK_END]
    out += [tok.yesno(int(y_gt)),
            tok.LEN_BASE + tok.len_bucket(float(len_gt)),
            tok.EOS]
    return out


def build_sft_example(model: PoolModel, model_index: int,
                      anchor_set: AnchorSet, fp: Fingerprint,
                      sims: np.ndarray, idx: np.ndarray, query: Query,
                      y_gt: int, len_gt: float, *, cot: bool = True
                      ) -> Tuple[List[int], List[int]]:
    prompt = serialize_prompt(model, model_index, anchor_set, fp, sims, idx,
                              query)
    target = teacher_target(fp.y[idx], fp.tokens[idx], y_gt, len_gt, query,
                            cot=cot)
    return prompt, target
