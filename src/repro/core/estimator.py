"""The reasoning estimator (SCOPE §4.1, Eq. 5).

Wraps an in-framework LM: conditioned on the serialized retrieval-augmented
prompt it generates a rationale z then the structured tuple (y_hat, l_hat).
Besides the parsed binary label we expose the correctness *confidence*
p(YES)/(p(YES)+p(NO)) at the decision token — Appendix D's p_hat(x, M) in
[0, 1] used by the budget-controlled alpha search.

Parsing is a single batched numpy pass over the whole generation matrix
(``parse_generations``); ``_parse_one`` remains as the scalar reference the
parity tests pin the batched parse against.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.status import STATUS_DEGRADED, STATUS_FAILED, STATUS_OK
from repro.data import tokenizer as tok
from repro.serving import sampler


@dataclasses.dataclass
class Prediction:
    y_hat: int
    len_hat: float
    well_formed: bool
    p_conf: float               # P(correct) in [0, 1]
    pred_tokens: int            # prediction overhead (generated tokens)
    rationale_len: int


@dataclasses.dataclass
class ParsedBatch:
    """Columnar predictions for N generations (the serve-path layout).

    ``status`` (``core.status``) marks how each row was answered: OK rows
    came off a real decode, DEGRADED rows from retrieval priors, FAILED
    rows not at all.  Defaulting to all-OK keeps every existing
    constructor call (and the parser) unchanged.
    """
    y_hat: np.ndarray           # (N,) int
    len_hat: np.ndarray         # (N,) float
    well_formed: np.ndarray     # (N,) bool
    p_conf: np.ndarray          # (N,) float
    pred_tokens: np.ndarray     # (N,) int
    rationale_len: np.ndarray   # (N,) int
    status: Optional[np.ndarray] = None     # (N,) int8, None -> all OK

    def __post_init__(self):
        if self.status is None:
            self.status = np.full(len(self.y_hat), STATUS_OK, np.int8)

    def __len__(self) -> int:
        return len(self.y_hat)

    def to_predictions(self) -> List[Prediction]:
        return [Prediction(int(self.y_hat[i]), float(self.len_hat[i]),
                           bool(self.well_formed[i]), float(self.p_conf[i]),
                           int(self.pred_tokens[i]),
                           int(self.rationale_len[i]))
                for i in range(len(self))]

    @classmethod
    def from_predictions(cls, preds: Sequence[Prediction]) -> "ParsedBatch":
        return cls(
            y_hat=np.asarray([p.y_hat for p in preds], int),
            len_hat=np.asarray([p.len_hat for p in preds], np.float64),
            well_formed=np.asarray([p.well_formed for p in preds], bool),
            p_conf=np.asarray([p.p_conf for p in preds], np.float64),
            pred_tokens=np.asarray([p.pred_tokens for p in preds], int),
            rationale_len=np.asarray([p.rationale_len for p in preds], int))

    @classmethod
    def empty(cls) -> "ParsedBatch":
        return cls.from_predictions([])


def parse_generations(gen: np.ndarray, dec_logits: np.ndarray, *,
                      starts: Optional[np.ndarray] = None,
                      lens: Optional[np.ndarray] = None) -> ParsedBatch:
    """Batched parse of (N, T) generations + (N, T, 2) YES/NO logit pairs.

    Vectorizes ``_parse_one`` (decision-token location, confidence, format
    gate, rationale length) over the whole generation matrix — no per-sample
    or per-token Python loops.

    ``starts``/``lens`` (N,) select a per-row **window** of the buffer: row
    i's generation is ``gen[i, starts[i] : starts[i] + lens[i]]``.  A
    refilled decode slot's tokens start mid-buffer (at the segment boundary
    it was admitted) and stop at its own ``max_new_tokens`` budget, so the
    rows of one continuous-batching buffer are parsed at different offsets;
    positions outside a row's window read as PAD with zero logits, which is
    exactly what a standalone run of the same prompt produces past EOS.
    """
    g = np.asarray(gen)
    if g.ndim != 2:
        raise ValueError(f"gen must be (N, T), got {g.shape}")
    N, T = g.shape
    if N == 0:
        return ParsedBatch.empty()
    dec_logits = np.asarray(dec_logits, np.float64)
    if starts is not None or lens is not None:
        starts = (np.zeros(N, int) if starts is None
                  else np.asarray(starts, int).reshape(-1))
        lens = (np.full(N, T, dtype=int) if lens is None
                else np.asarray(lens, int).reshape(-1))
        if starts.shape != (N,) or lens.shape != (N,):
            raise ValueError(
                f"starts/lens must be ({N},), got {starts.shape}/{lens.shape}")
        if (starts < 0).any() or (lens < 0).any() or (starts + lens > T).any():
            raise ValueError(
                f"row windows must lie inside the (N, {T}) buffer")
        W = max(int(lens.max()), 1)
        cols_w = np.arange(W)[None, :]
        valid = cols_w < lens[:, None]
        idx = np.clip(starts[:, None] + cols_w, 0, T - 1)
        rows_w = np.arange(N)[:, None]
        g = np.where(valid, g[rows_w, idx], tok.PAD)
        dec_logits = np.where(valid[:, :, None], dec_logits[rows_w, idx], 0.0)
        T = W
    rows = np.arange(N)
    cols = np.arange(T)[None, :]

    is_think = g == tok.THINK
    is_tend = g == tok.THINK_END
    has_think = is_think.any(axis=1)
    has_tend = is_tend.any(axis=1)
    cot = has_think & has_tend
    first_think = np.argmax(is_think, axis=1)
    first_tend = np.argmax(is_tend, axis=1)

    # --- format gate (tok.parse_prediction): strip the CoT span, drop PADs,
    # require body == (YES|NO) LEN_b EOS ... -----------------------------
    body_start = np.where(cot, first_tend + 1, 0)
    body_mask = (cols >= body_start[:, None]) & (g != tok.PAD)
    n_body = body_mask.sum(axis=1)
    # stable argsort floats body positions to the front, original order kept
    order = np.argsort(~body_mask, axis=1, kind="stable")
    first3 = order[:, :3] if T >= 3 else np.zeros((N, 3), int)
    b0, b1, b2 = (g[rows, first3[:, j]] for j in range(3))
    wf = ((~has_think | has_tend) & (n_body >= 3)
          & ((b0 == tok.YES) | (b0 == tok.NO))
          & (b1 >= tok.LEN_BASE) & (b1 < tok.LEN_BASE + tok.NUM_LEN_BUCKETS)
          & (b2 == tok.EOS))
    y_hat = np.where(wf, (b0 == tok.YES).astype(int), 0)
    len_hat = np.where(
        wf, tok.LEN_CENTERS[np.clip(b1 - tok.LEN_BASE, 0,
                                    tok.NUM_LEN_BUCKETS - 1)], 0.0)

    # --- decision step: first YES/NO after THINK_END (CoT) or from 0 ----
    dec_search = ((g == tok.YES) | (g == tok.NO)) & (
        cols >= np.where(cot, first_tend + 1, 0)[:, None])
    has_dec = dec_search.any(axis=1)
    dec_pos = np.argmax(dec_search, axis=1)
    d = dec_logits[rows, dec_pos]                       # (N, 2) = (YES, NO)
    m = d.max(axis=1)
    py = np.exp(d[:, 0] - m)
    pn = np.exp(d[:, 1] - m)
    conf = np.where(has_dec, py / (py + pn), 0.5)

    return ParsedBatch(
        y_hat=y_hat, len_hat=len_hat, well_formed=wf, p_conf=conf,
        pred_tokens=(g != tok.PAD).sum(axis=1),
        rationale_len=np.where(cot, first_tend - first_think + 1, 0))


class FallbackEstimator:
    """Degraded-mode estimator: answers a (query, model) pair from
    retrieval priors instead of a reasoning decode.

    The prediction is the similarity-weighted outcome of the model's
    fingerprint at the query's nearest anchors — the same signal the
    serialized prompt shows the reasoning estimator, minus the reasoning:
    ``p_conf`` is the weighted anchor correctness, ``len_hat`` the
    weighted anchor completion tokens, and ``y_hat = p_conf >= 0.5``.
    Zero decode tokens are spent, rows are marked ``STATUS_DEGRADED``,
    and ``well_formed=True`` so the cost model prices the predicted
    length rather than the malformed-estimate pessimistic fallback.
    """

    def __init__(self, library):
        self.library = library

    def predict_pairs(self, sims: np.ndarray, idx: np.ndarray,
                      models: Sequence[str]) -> ParsedBatch:
        """One degraded prediction per row of (N, K) ``sims``/``idx``."""
        sims = np.atleast_2d(np.asarray(sims, np.float64))
        idx = np.atleast_2d(np.asarray(idx, int))
        n = len(models)
        p = np.zeros(n, np.float64)
        len_hat = np.zeros(n, np.float64)
        for i, model in enumerate(models):
            fp = self.library.get(model)
            w = np.clip(sims[i], 0.0, None)
            total = w.sum()
            w = w / total if total > 0 else np.full(len(w), 1.0 / len(w))
            p[i] = float(w @ np.asarray(fp.y, np.float64)[idx[i]])
            len_hat[i] = float(w @ np.asarray(fp.tokens,
                                              np.float64)[idx[i]])
        return ParsedBatch(
            y_hat=(p >= 0.5).astype(int), len_hat=len_hat,
            well_formed=np.ones(n, bool), p_conf=p,
            pred_tokens=np.zeros(n, int), rationale_len=np.zeros(n, int),
            status=np.full(n, STATUS_DEGRADED, np.int8))

    @staticmethod
    def failed_pairs(n: int) -> ParsedBatch:
        """All-FAILED rows for when degradation itself is disabled: the
        malformed-estimate shape (``well_formed=False``, ``p_conf=0``)
        so policies price these pairs at the pessimistic fallback."""
        return ParsedBatch(
            y_hat=np.zeros(n, int), len_hat=np.zeros(n, np.float64),
            well_formed=np.zeros(n, bool), p_conf=np.zeros(n, np.float64),
            pred_tokens=np.zeros(n, int), rationale_len=np.zeros(n, int),
            status=np.full(n, STATUS_FAILED, np.int8))


@dataclasses.dataclass
class DecodeHandle:
    """In-flight generation: device arrays dispatched, not yet parsed.

    ``is_ready`` polls the device buffers without blocking;``parse`` blocks
    (``np.asarray``) and runs the batched parse.  The serve runtime keeps
    one handle in flight while assembling the next microbatch on the host.
    ``windows`` optionally carries one (start, length) pair per row of the
    concatenated buffer — the per-row ``max_new_tokens``/``used``
    accounting of a segment-chunked decode whose refilled rows start
    mid-buffer.
    """
    chunks: List[tuple]             # [(gen (b, T), dec (b, T, 2)), ...]
    windows: Optional[List[tuple]] = None   # [(start, length)] per row

    def is_ready(self) -> bool:
        return all(g.is_ready() and d.is_ready() for g, d in self.chunks)

    def parse(self) -> ParsedBatch:
        if not self.chunks:
            return ParsedBatch.empty()
        gens = [np.asarray(g) for g, _ in self.chunks]
        decs = [np.asarray(d) for _, d in self.chunks]
        starts = lens = None
        if self.windows is not None:
            starts = np.asarray([w[0] for w in self.windows], int)
            lens = np.asarray([w[1] for w in self.windows], int)
        return parse_generations(np.concatenate(gens, axis=0),
                                 np.concatenate(decs, axis=0),
                                 starts=starts, lens=lens)


@dataclasses.dataclass
class _Slot:
    """One live request occupying a decode slot.

    ``prompt`` keeps the row's serialized tokens so a failed row can be
    requeued into the scheduler without a reverse lookup.
    """
    tag: object
    start: int              # decode-step offset of its window in the run
    refilled: bool
    prompt: List[int] = dataclasses.field(default_factory=list)


class SlotRun:
    """One live continuous-batching decode state (the refill serve path).

    Wraps a ``sampler.DecodeState`` over a fixed (b, L) bucket and drives
    it in ``segment_len``-step scan segments: after each segment, rows that
    drained at EOS (or exhausted the per-request ``max_new_tokens`` budget)
    are parsed from their own window of the accumulated decode buffer and
    their slot freed; ``admit`` prefills freshly popped prompts into the
    free slots — one batched prefill per boundary, padded to the warmed
    (b, L) executable shape, however many slots drain together.  The slot
    cache is allocated ``horizon`` decode steps deep (default 4x the
    budget, rounded up to whole segments) so a slot serves several requests
    back-to-back before the state retires; ``can_admit`` turns False once
    the remaining horizon cannot fit a full budget — a request is never
    admitted into a window it could not finish, so every admitted request
    decodes exactly the window a standalone run would.
    """

    def __init__(self, estimator: "ReasoningEstimator", tokens, *,
                 lengths=None, tags=None, segment_len: int = 4,
                 horizon: Optional[int] = None,
                 rng: Optional[jax.Array] = None,
                 kv_pool=None, kv_kernel=None):
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 2:
            raise ValueError(f"tokens must be (b, L), got {tokens.shape}")
        b, L = tokens.shape
        self.est = estimator
        self.batch = b
        self.width = L
        self.budget = int(estimator.max_new_tokens)
        self.segment_len = int(segment_len)
        if not 1 <= self.segment_len <= self.budget:
            raise ValueError(
                f"segment_len must lie in [1, {self.budget}] "
                f"(max_new_tokens), got {segment_len}")
        # a request admitted at a boundary is freed at the first boundary
        # >= budget steps later, so a row writes at most this many decode
        # slots past its prompt — the paged per-row capacity and the unit
        # the host decode buffers grow by
        self.budget_steps = -(-self.budget // self.segment_len) \
            * self.segment_len
        self.kv_pool = kv_pool
        if kv_pool is None:
            horizon = int(horizon) if horizon else 4 * self.budget
            horizon = max(horizon, self.budget)
            # whole segments only: a window admitted while can_admit()
            # holds always completes by the horizon boundary
            self.horizon = -(-horizon // self.segment_len) \
                * self.segment_len
            buf = self.horizon
        else:
            # paged mode has no shared horizon: admission is gated on free
            # pages and the host buffers grow per segment instead
            self.horizon = None
            buf = self.budget_steps
        tags = list(tags) if tags is not None else list(range(b))
        if len(tags) > b:
            raise ValueError(f"{len(tags)} tags for {b} slots")
        lens = None if lengths is None else np.asarray(lengths, int)
        # per-row true lengths only when genuinely ragged: exact-fit
        # buckets stay on the unmasked path (SSM backbones included)
        pl = lens if lens is not None and (lens != L).any() else None
        if kv_pool is None:
            self.state = sampler.prefill_state(
                estimator.params, estimator.cfg,
                estimator._place_batch(tokens),
                max_new_tokens=self.horizon, prompt_lens=pl, rng=rng)
        else:
            from repro.kernels.decode_attention import KernelType
            self.state = sampler.prefill_state(
                estimator.params, estimator.cfg,
                estimator._place_batch(tokens),
                max_new_tokens=self.budget_steps, prompt_lens=pl, rng=rng,
                kv_pool=kv_pool,
                kv_kernel=kv_kernel or KernelType.XLA,
                kv_active=np.arange(b) < len(tags))
        # rows past the real tags are free slots from the start (a
        # partially-filled opening bucket refills instead of padding)
        true_lens = lens if lens is not None else np.full(b, L, int)
        self.slots: List[Optional[_Slot]] = [
            _Slot(tags[i], 0, False,
                  prompt=tokens[i, : int(true_lens[i])].tolist())
            if i < len(tags) else None
            for i in range(b)]
        self.steps_run = 0                  # decode steps *launched*
        self.steps_done = 0                 # decode steps synced to host
        # host copy of the decode buffer, written once per segment
        self._gen = np.full((b, buf), -1, np.int32)
        self._dec = np.zeros((b, buf, 2), np.float32)
        # slot-aligned refills admitted since the last launch; fused into
        # the next ``decode_segment(refill=...)`` executable
        self._pending: Optional[tuple] = None
        self._inflight: Optional[tuple] = None      # (gen, dec) futures
        # decode-slot accounting (token granularity; folded into
        # SchedulerStats by ``account``)
        self.slot_steps_total = 0
        self.slot_steps_active = 0
        self.refill_steps = 0               # active steps on refilled rows

    # -- slot bookkeeping ----------------------------------------------
    @property
    def n_live(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def finished(self) -> bool:
        return self.n_live == 0

    def free_rows(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def paged(self) -> bool:
        return self.kv_pool is not None

    def can_admit(self) -> bool:
        """Whether one more request may be admitted into a free slot.

        Dense mode gates on the remaining horizon fitting a full budget;
        paged mode gates on the pool having a worst-case row's pages free
        — the ``refill_horizon`` ceiling does not exist there, so a
        queued prompt drains as soon as pages free up, however long the
        run has already decoded.
        """
        if self.paged:
            return self.state.paged.can_admit(self.width)
        return self.steps_run + self.budget <= self.horizon

    @property
    def deferral_reason(self) -> str:
        """Which resource a ``can_admit() == False`` boundary waits on
        (the stats counter the serve runtime bumps)."""
        return "pages" if self.paged else "horizon"

    def admit(self, items: Sequence[tuple]) -> None:
        """Refill free slots with ``items`` = [(tag, prompt, length)].

        Admissions are **deferred and fused**: every refill collected at a
        boundary rides the next ``decode_segment(refill=...)`` launch —
        the slot-aligned prompt matrix is prefilled, merged, and decoded
        in one executable, so a boundary costs a single launch however
        many slots drained.  Each refilled row's window starts at the
        current boundary (``steps_run``).
        """
        if not items:
            return
        if self._inflight is not None:
            raise RuntimeError(
                "cannot admit while a segment is in flight — sync() first")
        free = self.free_rows()
        if len(items) > len(free):
            raise ValueError(
                f"{len(items)} refills for {len(free)} free slots")
        if self._pending is None:
            self._pending = (np.zeros(self.batch, bool),
                             np.full((self.batch, self.width), tok.PAD,
                                     np.int32),
                             np.ones(self.batch, np.int64))
        mask, mat, lens = self._pending
        for (tag, prompt, length), row in zip(items, free, strict=False):
            if not self.can_admit():
                raise ValueError(
                    "cannot admit: the kv pool has no room for a "
                    "worst-case row" if self.paged else
                    "remaining horizon cannot fit a full decode budget")
            p = np.asarray(prompt, np.int32).reshape(-1)
            if not 1 <= len(p) <= self.width:
                raise ValueError(
                    f"refill prompt of {len(p)} tokens does not fit the "
                    f"slot width {self.width}")
            mask[row] = True
            mat[row] = tok.PAD
            mat[row, : len(p)] = p
            lens[row] = int(length) if length else len(p)
            if self.paged:
                # reserve the row's pages NOW so the next can_admit()
                # check sees the pool as the coming launch will leave it
                self.state.paged.pre_admit(row, int(lens[row]))
            self.slots[row] = _Slot(tag, self.steps_run, True,
                                    prompt=p.tolist())

    # -- failure surface (serve-runtime fault tolerance) ---------------
    @property
    def in_flight(self) -> bool:
        """Whether a launched segment is awaiting ``sync``."""
        return self._inflight is not None

    def live_rows(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def pick_live_row(self, k: int) -> Optional[int]:
        """The k-th live row (mod the live count) — how an injected pool
        fault selects its victim deterministically."""
        live = self.live_rows()
        return live[int(k) % len(live)] if live else None

    def starved_rows(self) -> List[int]:
        """Live rows the next segment's page allocation would starve
        (paged mode; always empty within reserved budgets)."""
        if not self.paged:
            return []
        return self.state.paged.starved_rows(self.segment_len)

    def fail_row(self, row: int) -> Optional[tuple]:
        """Row-level failure (KV pool exhaustion, injected or real):
        release the row's pages and free its slot, returning
        ``(tag, prompt)`` for requeue.  The slot decodes PAD into the
        trash page until the state retires — exactly a retired row."""
        slot = self.slots[row]
        if slot is None:
            return None
        self.slots[row] = None
        if self.paged:
            self.state.paged.retire_row(row)
        return (slot.tag, slot.prompt)

    def abort(self) -> List[tuple]:
        """Tear down a poisoned run: release every live row's pages,
        drop pending refills and in-flight futures, and return the live
        ``(tag, prompt)`` pairs for requeue.  The state is dead afterwards
        (``finished`` is True); rows already completed by ``sync`` are
        *not* returned — they parsed (or will parse) normally."""
        failed = []
        for row in self.live_rows():
            failed.append(self.fail_row(row))
        self._pending = None
        self._inflight = None
        return failed

    # -- decode --------------------------------------------------------
    def launch(self) -> None:
        """Dispatch the next decode segment without blocking, fusing any
        pending refills into the same executable.  ``sync`` collects it;
        launching before the host parses the previous boundary overlaps
        host work with device decode."""
        if self._inflight is not None:
            raise RuntimeError("a segment is already in flight")
        if not self.paged and \
                self.steps_run + self.segment_len > self.horizon:
            raise RuntimeError(
                f"segment overruns the {self.horizon}-step slot horizon")
        self.state, g, d = sampler.decode_segment(
            self.est.params, self.est.cfg, self.state, self.segment_len,
            refill=self._pending)
        self._pending = None
        self._inflight = (g, d)
        self.steps_run += self.segment_len
        self.slot_steps_total += self.batch * self.segment_len

    def sync(self) -> List[tuple]:
        """Block on the in-flight segment (launching one first if needed)
        and free the slots whose rows completed at this boundary.

        Returns the freed ``(row, slot)`` pairs for ``parse_completed`` —
        the parse is split off so the serve runtime can launch the next
        segment *before* parsing, keeping the device busy while the host
        assembles results.
        """
        if self._inflight is None:
            self.launch()
        g, d = self._inflight
        self._inflight = None
        t0, t1 = self.steps_done, self.steps_done + self.segment_len
        if t1 > self._gen.shape[1]:
            # paged runs have no horizon, so the host buffers grow in
            # budget-sized chunks as the run outlives its initial window
            grow = -(-(t1 - self._gen.shape[1]) // self.budget_steps) \
                * self.budget_steps
            self._gen = np.concatenate(
                [self._gen, np.full((self.batch, grow), -1, np.int32)], 1)
            self._dec = np.concatenate(
                [self._dec,
                 np.zeros((self.batch, grow, 2), np.float32)], 1)
        self._gen[:, t0:t1] = np.asarray(g)
        self._dec[:, t0:t1] = np.asarray(d)
        self.steps_done = t1
        done = np.asarray(self.state.done)
        completed = []
        for row, slot in enumerate(self.slots):
            if slot is None:
                continue
            if bool(done[row]) or t1 - slot.start >= self.budget:
                completed.append((row, slot))
                self.slots[row] = None
                if self.paged:
                    # hand the row's pages back the moment it drains —
                    # its table entries fall back to the trash page, so
                    # the still-running PAD decode scatters harmlessly
                    self.state.paged.retire_row(row)
        return completed

    def parse_completed(self, completed: List[tuple]):
        """(tags, ParsedBatch) for the rows ``sync`` freed: each row's
        generation is its own window of the decode buffer."""
        if not completed:
            return [], ParsedBatch.empty()
        rows = [r for r, _ in completed]
        starts = np.asarray([s.start for _, s in completed], int)
        lens = np.minimum(self.budget, self.steps_done - starts)
        batch = parse_generations(self._gen[rows, : self.steps_done],
                                  self._dec[rows, : self.steps_done],
                                  starts=starts, lens=lens)
        self.slot_steps_active += int(batch.pred_tokens.sum())
        refilled = [i for i, (_, s) in enumerate(completed) if s.refilled]
        if refilled:
            self.refill_steps += int(batch.pred_tokens[refilled].sum())
        return [s.tag for _, s in completed], batch

    def step(self):
        """``sync`` + ``parse_completed`` in one blocking call — the
        unpipelined drive (unit tests); the serve runtime interleaves a
        ``launch`` between the two to overlap host parsing with decode."""
        return self.parse_completed(self.sync())

    def account(self, stats) -> None:
        """Fold this run's decode-slot counters into ``SchedulerStats``."""
        stats.slot_steps_total += self.slot_steps_total
        stats.slot_steps_active += self.slot_steps_active
        stats.refill_steps_saved += self.refill_steps
        if self.paged:
            pool = self.kv_pool
            stats.kv_page_size = pool.page_size
            stats.pages_in_use = pool.pages_in_use
            stats.pages_peak = max(stats.pages_peak, pool.pages_peak)
            stats.kv_live_tokens = pool.live_tokens
            stats.kv_peak_tokens = max(stats.kv_peak_tokens,
                                       pool.tokens_peak)
        else:
            # dense KV is committed wholesale at prefill: every slot holds
            # max_len token positions for the whole run
            stats.kv_peak_tokens = max(
                stats.kv_peak_tokens, self.batch * self.state.max_len)


class ReasoningEstimator:
    def __init__(self, cfg: ModelConfig, params, *, cot: bool = True,
                 max_new_tokens: int = 12, batch_size: int = 256):
        self.cfg = cfg
        self.params = params
        self.cot = cot
        self.max_new_tokens = max_new_tokens
        self.batch_size = batch_size
        self.mesh = None            # set by shard(): data-parallel serving

    # ------------------------------------------------------------------
    def shard(self, mesh) -> "ReasoningEstimator":
        """Place the estimator on a device mesh for data-parallel serving.

        Params are placed per ``distributed.sharding.param_specs`` (FSDP on
        ``data``, TP on ``model`` where divisible) and every subsequent
        ``predict_batch`` shards its token batch across ``data`` via
        ``batch_specs`` — prefill and the decode scan then run SPMD over
        the whole mesh.  Returns self.
        """
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        from repro.distributed import sharding as shd
        pspecs = shd.param_specs(mesh, self.params)
        self.params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            self.params, pspecs, is_leaf=lambda x: isinstance(x, P))
        self.mesh = mesh
        return self

    def _place_batch(self, arr: np.ndarray):
        """Shard a (b, L) token batch across the mesh's data axis."""
        if self.mesh is None:
            return arr
        from jax.sharding import NamedSharding
        from repro.distributed import sharding as shd
        spec = shd.batch_specs(self.mesh, {"tokens": arr})["tokens"]
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    # ------------------------------------------------------------------
    def dispatch_batch(self, prompts, *, prompt_lens=None,
                       temperature: float = 0.0,
                       rng: Optional[jax.Array] = None) -> DecodeHandle:
        """Launch generation for a batch and return without blocking.

        ``prompts`` may be a list of constant-length token lists or an
        already-assembled (b, L) int array (the scheduler's microbatches);
        ``prompt_lens`` (b,) marks true per-row lengths under a bucket
        grid.  The returned ``DecodeHandle`` parses on demand — the serve
        runtime overlaps the next microbatch's host assembly with this
        one's device decode.
        """
        if len(prompts) == 0:
            return DecodeHandle([])
        if prompt_lens is None:
            lens = {len(p) for p in prompts}
            assert len(lens) == 1, "structured prompts must be constant-length"
        arr = np.asarray(prompts, np.int32)
        chunks = []
        key = rng
        for i in range(0, len(arr), self.batch_size):
            sub = None
            if key is not None:
                key, sub = jax.random.split(key)
            pl = (None if prompt_lens is None
                  else np.asarray(prompt_lens)[i: i + self.batch_size])
            chunks.append(sampler.generate_async(
                self.params, self.cfg,
                self._place_batch(arr[i: i + self.batch_size]),
                max_new_tokens=self.max_new_tokens, temperature=temperature,
                rng=sub, prompt_lens=pl))
        return DecodeHandle(chunks)

    def open_slots(self, tokens, *, lengths=None, tags=None,
                   segment_len: int = 4, horizon: Optional[int] = None,
                   rng: Optional[jax.Array] = None,
                   kv_pool=None, kv_kernel=None) -> SlotRun:
        """Open a continuous-batching decode state over one microbatch.

        The engine's segment-chunked refill path drives the returned
        ``SlotRun``: ``step`` decode segments, ``admit`` fresh prompts into
        drained slots between them.  ``tokens``/``lengths``/``tags`` are a
        scheduler ``Microbatch``'s fields; rows beyond the real tags are
        immediately-free slots.  Passing a ``kv_pool`` (``serving.kv_pool.
        KVPool``) switches the slot cache to the block-paged layout —
        ``horizon`` must then stay None (admission is page-gated).
        """
        if kv_pool is not None and horizon is not None:
            raise ValueError("horizon and kv_pool are mutually exclusive: "
                             "paged admission is gated on free pages")
        return SlotRun(self, tokens, lengths=lengths, tags=tags,
                       segment_len=segment_len, horizon=horizon, rng=rng,
                       kv_pool=kv_pool, kv_kernel=kv_kernel)

    def predict_batch(self, prompts: List[List[int]], *,
                      prompt_lens=None, temperature: float = 0.0,
                      rng: Optional[jax.Array] = None) -> ParsedBatch:
        """Columnar predictions — the serve hot path (no per-pair objects)."""
        if len(prompts) == 0:
            return ParsedBatch.empty()
        return self.dispatch_batch(prompts, prompt_lens=prompt_lens,
                                   temperature=temperature,
                                   rng=rng).parse()

    def predict(self, prompts: List[List[int]], *,
                temperature: float = 0.0,
                rng: Optional[jax.Array] = None) -> List[Prediction]:
        return self.predict_batch(prompts, temperature=temperature,
                                  rng=rng).to_predictions()

    # ------------------------------------------------------------------
    @staticmethod
    def _parse_one(gen: np.ndarray, dec_logits: np.ndarray) -> Prediction:
        """Scalar reference parse for one generation; ``dec_logits`` is the
        (T, 2) YES/NO logit pair per step.  Kept as the parity oracle for
        ``parse_generations``."""
        toks = [int(t) for t in gen]
        parsed = tok.parse_prediction(toks)
        # locate the decision step: first YES/NO after THINK_END (CoT) or at 0
        dec_pos = None
        start = 0
        if tok.THINK in toks and tok.THINK_END in toks:
            start = toks.index(tok.THINK_END) + 1
        for j in range(start, len(toks)):
            if toks[j] in (tok.YES, tok.NO):
                dec_pos = j
                break
        if dec_pos is not None:
            row = np.asarray(dec_logits[dec_pos], np.float64)
            m = max(row[0], row[1])
            py = np.exp(row[0] - m)
            pn = np.exp(row[1] - m)
            conf = float(py / (py + pn))
        else:
            conf = 0.5
        n_gen = int(np.sum(np.asarray(toks) != tok.PAD))
        rat = 0
        if tok.THINK in toks and tok.THINK_END in toks:
            rat = toks.index(tok.THINK_END) - toks.index(tok.THINK) + 1
        return Prediction(
            y_hat=parsed["y_hat"], len_hat=parsed["len_hat"],
            well_formed=parsed["well_formed"], p_conf=conf,
            pred_tokens=n_gen, rationale_len=rat)
