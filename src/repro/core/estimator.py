"""The reasoning estimator (SCOPE §4.1, Eq. 5).

Wraps an in-framework LM: conditioned on the serialized retrieval-augmented
prompt it generates a rationale z then the structured tuple (y_hat, l_hat).
Besides the parsed binary label we expose the correctness *confidence*
p(YES)/(p(YES)+p(NO)) at the decision token — Appendix D's p_hat(x, M) in
[0, 1] used by the budget-controlled alpha search.

Parsing is a single batched numpy pass over the whole generation matrix
(``parse_generations``); ``_parse_one`` remains as the scalar reference the
parity tests pin the batched parse against.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import tokenizer as tok
from repro.serving import sampler


@dataclasses.dataclass
class Prediction:
    y_hat: int
    len_hat: float
    well_formed: bool
    p_conf: float               # P(correct) in [0, 1]
    pred_tokens: int            # prediction overhead (generated tokens)
    rationale_len: int


@dataclasses.dataclass
class ParsedBatch:
    """Columnar predictions for N generations (the serve-path layout)."""
    y_hat: np.ndarray           # (N,) int
    len_hat: np.ndarray         # (N,) float
    well_formed: np.ndarray     # (N,) bool
    p_conf: np.ndarray          # (N,) float
    pred_tokens: np.ndarray     # (N,) int
    rationale_len: np.ndarray   # (N,) int

    def __len__(self) -> int:
        return len(self.y_hat)

    def to_predictions(self) -> List[Prediction]:
        return [Prediction(int(self.y_hat[i]), float(self.len_hat[i]),
                           bool(self.well_formed[i]), float(self.p_conf[i]),
                           int(self.pred_tokens[i]),
                           int(self.rationale_len[i]))
                for i in range(len(self))]

    @classmethod
    def from_predictions(cls, preds: Sequence[Prediction]) -> "ParsedBatch":
        return cls(
            y_hat=np.asarray([p.y_hat for p in preds], int),
            len_hat=np.asarray([p.len_hat for p in preds], np.float64),
            well_formed=np.asarray([p.well_formed for p in preds], bool),
            p_conf=np.asarray([p.p_conf for p in preds], np.float64),
            pred_tokens=np.asarray([p.pred_tokens for p in preds], int),
            rationale_len=np.asarray([p.rationale_len for p in preds], int))

    @classmethod
    def empty(cls) -> "ParsedBatch":
        return cls.from_predictions([])


def parse_generations(gen: np.ndarray, dec_logits: np.ndarray) -> ParsedBatch:
    """Batched parse of (N, T) generations + (N, T, 2) YES/NO logit pairs.

    Vectorizes ``_parse_one`` (decision-token location, confidence, format
    gate, rationale length) over the whole generation matrix — no per-sample
    or per-token Python loops.
    """
    g = np.asarray(gen)
    if g.ndim != 2:
        raise ValueError(f"gen must be (N, T), got {g.shape}")
    N, T = g.shape
    if N == 0:
        return ParsedBatch.empty()
    dec_logits = np.asarray(dec_logits, np.float64)
    rows = np.arange(N)
    cols = np.arange(T)[None, :]

    is_think = g == tok.THINK
    is_tend = g == tok.THINK_END
    has_think = is_think.any(axis=1)
    has_tend = is_tend.any(axis=1)
    cot = has_think & has_tend
    first_think = np.argmax(is_think, axis=1)
    first_tend = np.argmax(is_tend, axis=1)

    # --- format gate (tok.parse_prediction): strip the CoT span, drop PADs,
    # require body == (YES|NO) LEN_b EOS ... -----------------------------
    body_start = np.where(cot, first_tend + 1, 0)
    body_mask = (cols >= body_start[:, None]) & (g != tok.PAD)
    n_body = body_mask.sum(axis=1)
    # stable argsort floats body positions to the front, original order kept
    order = np.argsort(~body_mask, axis=1, kind="stable")
    first3 = order[:, :3] if T >= 3 else np.zeros((N, 3), int)
    b0, b1, b2 = (g[rows, first3[:, j]] for j in range(3))
    wf = ((~has_think | has_tend) & (n_body >= 3)
          & ((b0 == tok.YES) | (b0 == tok.NO))
          & (b1 >= tok.LEN_BASE) & (b1 < tok.LEN_BASE + tok.NUM_LEN_BUCKETS)
          & (b2 == tok.EOS))
    y_hat = np.where(wf, (b0 == tok.YES).astype(int), 0)
    len_hat = np.where(
        wf, tok.LEN_CENTERS[np.clip(b1 - tok.LEN_BASE, 0,
                                    tok.NUM_LEN_BUCKETS - 1)], 0.0)

    # --- decision step: first YES/NO after THINK_END (CoT) or from 0 ----
    dec_search = ((g == tok.YES) | (g == tok.NO)) & (
        cols >= np.where(cot, first_tend + 1, 0)[:, None])
    has_dec = dec_search.any(axis=1)
    dec_pos = np.argmax(dec_search, axis=1)
    d = dec_logits[rows, dec_pos]                       # (N, 2) = (YES, NO)
    m = d.max(axis=1)
    py = np.exp(d[:, 0] - m)
    pn = np.exp(d[:, 1] - m)
    conf = np.where(has_dec, py / (py + pn), 0.5)

    return ParsedBatch(
        y_hat=y_hat, len_hat=len_hat, well_formed=wf, p_conf=conf,
        pred_tokens=(g != tok.PAD).sum(axis=1),
        rationale_len=np.where(cot, first_tend - first_think + 1, 0))


@dataclasses.dataclass
class DecodeHandle:
    """In-flight generation: device arrays dispatched, not yet parsed.

    ``is_ready`` polls the device buffers without blocking;``parse`` blocks
    (``np.asarray``) and runs the batched parse.  The serve runtime keeps
    one handle in flight while assembling the next microbatch on the host.
    """
    chunks: List[tuple]             # [(gen (b, T), dec (b, T, 2)), ...]

    def is_ready(self) -> bool:
        return all(g.is_ready() and d.is_ready() for g, d in self.chunks)

    def parse(self) -> ParsedBatch:
        if not self.chunks:
            return ParsedBatch.empty()
        gens = [np.asarray(g) for g, _ in self.chunks]
        decs = [np.asarray(d) for _, d in self.chunks]
        return parse_generations(np.concatenate(gens, axis=0),
                                 np.concatenate(decs, axis=0))


class ReasoningEstimator:
    def __init__(self, cfg: ModelConfig, params, *, cot: bool = True,
                 max_new_tokens: int = 12, batch_size: int = 256):
        self.cfg = cfg
        self.params = params
        self.cot = cot
        self.max_new_tokens = max_new_tokens
        self.batch_size = batch_size
        self.mesh = None            # set by shard(): data-parallel serving

    # ------------------------------------------------------------------
    def shard(self, mesh) -> "ReasoningEstimator":
        """Place the estimator on a device mesh for data-parallel serving.

        Params are placed per ``distributed.sharding.param_specs`` (FSDP on
        ``data``, TP on ``model`` where divisible) and every subsequent
        ``predict_batch`` shards its token batch across ``data`` via
        ``batch_specs`` — prefill and the decode scan then run SPMD over
        the whole mesh.  Returns self.
        """
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P
        from repro.distributed import sharding as shd
        pspecs = shd.param_specs(mesh, self.params)
        self.params = jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            self.params, pspecs, is_leaf=lambda x: isinstance(x, P))
        self.mesh = mesh
        return self

    def _place_batch(self, arr: np.ndarray):
        """Shard a (b, L) token batch across the mesh's data axis."""
        if self.mesh is None:
            return arr
        from jax.sharding import NamedSharding
        from repro.distributed import sharding as shd
        spec = shd.batch_specs(self.mesh, {"tokens": arr})["tokens"]
        return jax.device_put(arr, NamedSharding(self.mesh, spec))

    # ------------------------------------------------------------------
    def dispatch_batch(self, prompts, *, prompt_lens=None,
                       temperature: float = 0.0,
                       rng: Optional[jax.Array] = None) -> DecodeHandle:
        """Launch generation for a batch and return without blocking.

        ``prompts`` may be a list of constant-length token lists or an
        already-assembled (b, L) int array (the scheduler's microbatches);
        ``prompt_lens`` (b,) marks true per-row lengths under a bucket
        grid.  The returned ``DecodeHandle`` parses on demand — the serve
        runtime overlaps the next microbatch's host assembly with this
        one's device decode.
        """
        if len(prompts) == 0:
            return DecodeHandle([])
        if prompt_lens is None:
            lens = {len(p) for p in prompts}
            assert len(lens) == 1, "structured prompts must be constant-length"
        arr = np.asarray(prompts, np.int32)
        chunks = []
        key = rng
        for i in range(0, len(arr), self.batch_size):
            sub = None
            if key is not None:
                key, sub = jax.random.split(key)
            pl = (None if prompt_lens is None
                  else np.asarray(prompt_lens)[i: i + self.batch_size])
            chunks.append(sampler.generate_async(
                self.params, self.cfg,
                self._place_batch(arr[i: i + self.batch_size]),
                max_new_tokens=self.max_new_tokens, temperature=temperature,
                rng=sub, prompt_lens=pl))
        return DecodeHandle(chunks)

    def predict_batch(self, prompts: List[List[int]], *,
                      prompt_lens=None, temperature: float = 0.0,
                      rng: Optional[jax.Array] = None) -> ParsedBatch:
        """Columnar predictions — the serve hot path (no per-pair objects)."""
        if len(prompts) == 0:
            return ParsedBatch.empty()
        return self.dispatch_batch(prompts, prompt_lens=prompt_lens,
                                   temperature=temperature,
                                   rng=rng).parse()

    def predict(self, prompts: List[List[int]], *,
                temperature: float = 0.0,
                rng: Optional[jax.Array] = None) -> List[Prediction]:
        return self.predict_batch(prompts, temperature=temperature,
                                  rng=rng).to_predictions()

    # ------------------------------------------------------------------
    @staticmethod
    def _parse_one(gen: np.ndarray, dec_logits: np.ndarray) -> Prediction:
        """Scalar reference parse for one generation; ``dec_logits`` is the
        (T, 2) YES/NO logit pair per step.  Kept as the parity oracle for
        ``parse_generations``."""
        toks = [int(t) for t in gen]
        parsed = tok.parse_prediction(toks)
        # locate the decision step: first YES/NO after THINK_END (CoT) or at 0
        dec_pos = None
        start = 0
        if tok.THINK in toks and tok.THINK_END in toks:
            start = toks.index(tok.THINK_END) + 1
        for j in range(start, len(toks)):
            if toks[j] in (tok.YES, tok.NO):
                dec_pos = j
                break
        if dec_pos is not None:
            row = np.asarray(dec_logits[dec_pos], np.float64)
            m = max(row[0], row[1])
            py = np.exp(row[0] - m)
            pn = np.exp(row[1] - m)
            conf = float(py / (py + pn))
        else:
            conf = 0.5
        n_gen = int(np.sum(np.asarray(toks) != tok.PAD))
        rat = 0
        if tok.THINK in toks and tok.THINK_END in toks:
            rat = toks.index(tok.THINK_END) - toks.index(tok.THINK) + 1
        return Prediction(
            y_hat=parsed["y_hat"], len_hat=parsed["len_hat"],
            well_formed=parsed["well_formed"], p_conf=conf,
            pred_tokens=n_gen, rationale_len=rat)
