"""The reasoning estimator (SCOPE §4.1, Eq. 5).

Wraps an in-framework LM: conditioned on the serialized retrieval-augmented
prompt it generates a rationale z then the structured tuple (y_hat, l_hat).
Besides the parsed binary label we expose the correctness *confidence*
p(YES)/(p(YES)+p(NO)) at the decision token — Appendix D's p_hat(x, M) in
[0, 1] used by the budget-controlled alpha search.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import tokenizer as tok
from repro.serving import sampler


@dataclasses.dataclass
class Prediction:
    y_hat: int
    len_hat: float
    well_formed: bool
    p_conf: float               # P(correct) in [0, 1]
    pred_tokens: int            # prediction overhead (generated tokens)
    rationale_len: int


class ReasoningEstimator:
    def __init__(self, cfg: ModelConfig, params, *, cot: bool = True,
                 max_new_tokens: int = 12, batch_size: int = 256):
        self.cfg = cfg
        self.params = params
        self.cot = cot
        self.max_new_tokens = max_new_tokens
        self.batch_size = batch_size

    # ------------------------------------------------------------------
    def predict(self, prompts: List[List[int]], *,
                temperature: float = 0.0,
                rng: Optional[jax.Array] = None) -> List[Prediction]:
        if not prompts:
            return []
        lens = {len(p) for p in prompts}
        assert len(lens) == 1, "structured prompts must be constant-length"
        arr = np.asarray(prompts, np.int32)
        out: List[Prediction] = []
        key = rng if rng is not None else jax.random.PRNGKey(0)
        for i in range(0, len(arr), self.batch_size):
            key, sub = jax.random.split(key)
            gen, lg = sampler.generate(
                self.params, self.cfg, arr[i: i + self.batch_size],
                max_new_tokens=self.max_new_tokens, temperature=temperature,
                rng=sub)
            for g, l in zip(gen, lg):
                out.append(self._parse_one(g, l))
        return out

    # ------------------------------------------------------------------
    def _parse_one(self, gen: np.ndarray, logits: np.ndarray) -> Prediction:
        toks = [int(t) for t in gen]
        parsed = tok.parse_prediction(toks)
        # locate the decision step: first YES/NO after THINK_END (CoT) or at 0
        dec_pos = None
        start = 0
        if tok.THINK in toks and tok.THINK_END in toks:
            start = toks.index(tok.THINK_END) + 1
        for j in range(start, len(toks)):
            if toks[j] in (tok.YES, tok.NO):
                dec_pos = j
                break
        if dec_pos is not None:
            row = logits[dec_pos].astype(np.float64)
            m = max(row[tok.YES], row[tok.NO])
            py = np.exp(row[tok.YES] - m)
            pn = np.exp(row[tok.NO] - m)
            conf = float(py / (py + pn))
        else:
            conf = 0.5
        n_gen = int(np.sum(np.asarray(toks) != tok.PAD))
        rat = 0
        if tok.THINK in toks and tok.THINK_END in toks:
            rat = toks.index(tok.THINK_END) - toks.index(tok.THINK) + 1
        return Prediction(
            y_hat=parsed["y_hat"], len_hat=parsed["len_hat"],
            well_formed=parsed["well_formed"], p_conf=conf,
            pred_tokens=n_gen, rationale_len=rat)
