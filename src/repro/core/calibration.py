"""Anchor-based calibration (SCOPE §5.2, Fig. 11).

U_cal(M) aggregates the *ground-truth* performance of the retrieved anchors,
similarity-weighted, then maps through the same utility as the prediction:
a historical prior that corrects estimator errors and smooths the frontier
(Fig. 7 right).
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from repro.core.fingerprint import Fingerprint
from repro.core.utility import normalize_cost, predicted_utility


def anchor_stats(fp: Fingerprint, idx: np.ndarray, sims: np.ndarray):
    """Similarity-weighted accuracy / cost of the retrieved slice."""
    w = np.clip(np.asarray(sims, np.float64), 0.0, None) + 1e-6
    w = w / w.sum()
    y = fp.y[idx].astype(np.float64)
    c = fp.cost[idx].astype(np.float64)
    return float(np.sum(w * y)), float(np.sum(w * c))


def calibration_utilities(fps: Dict[str, Fingerprint], models: Sequence[str],
                          idx: np.ndarray, sims: np.ndarray, alpha: float,
                          *, gamma_base: float = 1.0, beta: float = 2.0
                          ) -> np.ndarray:
    """U_cal per model for one query's retrieved anchor cluster."""
    p_cal = np.zeros(len(models))
    c_cal = np.zeros(len(models))
    for j, m in enumerate(models):
        p_cal[j], c_cal[j] = anchor_stats(fps[m], idx, sims)
    # cluster-wise log min-max normalization (Eq. 11 with cluster bounds)
    c_norm = normalize_cost(c_cal)
    return predicted_utility(p_cal, c_norm, alpha,
                             gamma_base=gamma_base, beta=beta)


def calibration_utilities_batch(fps: Dict[str, Fingerprint],
                                models: Sequence[str], idx: np.ndarray,
                                sims: np.ndarray, alpha: float, *,
                                gamma_base: float = 1.0, beta: float = 2.0
                                ) -> np.ndarray:
    """U_cal for a whole batch: idx/sims (Q, K) -> utilities (Q, M).

    Vectorizes ``calibration_utilities`` over queries — one gather per
    anchor statistic instead of a per-query Python loop on the serve path.
    """
    idx = np.asarray(idx, int)
    w = np.clip(np.asarray(sims, np.float64), 0.0, None) + 1e-6
    w = w / w.sum(axis=-1, keepdims=True)               # (Q, K)
    Y = np.stack([fps[m].y for m in models]).astype(np.float64)     # (M, A)
    C = np.stack([fps[m].cost for m in models]).astype(np.float64)  # (M, A)
    p_cal = np.einsum("qk,mqk->qm", w, Y[:, idx])
    c_cal = np.einsum("qk,mqk->qm", w, C[:, idx])
    c_norm = normalize_cost(c_cal, axis=-1)             # per-cluster bounds
    return predicted_utility(p_cal, c_norm, alpha,
                             gamma_base=gamma_base, beta=beta)
