"""Model fingerprinting over a fixed anchor set (SCOPE §3.1, Eq. 1).

A fingerprint phi_B(M) = {(x_i, y_i^M, c_i^M)} records a model's realized
correctness and token cost on every anchor query.  Onboarding a new model is
training-free: one pass over the anchor set (here: one batch of world-sim
interactions, standing in for one batch of API calls).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.data.worldsim import PoolModel, Query, World


@dataclasses.dataclass
class Fingerprint:
    model: str
    y: np.ndarray           # (N,) int — correctness on anchors
    tokens: np.ndarray      # (N,) int — completion tokens on anchors
    cost: np.ndarray        # (N,) float — $ per anchor

    def slice(self, idx: np.ndarray) -> "Fingerprint":
        return Fingerprint(self.model, self.y[idx], self.tokens[idx],
                           self.cost[idx])


@dataclasses.dataclass
class AnchorSet:
    queries: List[Query]
    embeddings: np.ndarray  # (N, d) retrieval embeddings

    def __len__(self):
        return len(self.queries)


def build_anchor_set(world: World, anchors: Sequence[Query]) -> AnchorSet:
    embs = np.stack([world.embed(q) for q in anchors])
    return AnchorSet(list(anchors), embs)


def build_fingerprint(world: World, model_name: str, anchor_set: AnchorSet,
                      seed: int = 0) -> Fingerprint:
    """One pass of model ``model_name`` over the anchor set."""
    rng = np.random.default_rng(seed)
    m = world.models[model_name]
    y, tokens, cost = [], [], []
    for q in anchor_set.queries:
        yi, ti, ci = world.sample_interaction(m, q, rng)
        y.append(yi)
        tokens.append(ti)
        cost.append(ci)
    return Fingerprint(model_name, np.asarray(y), np.asarray(tokens),
                       np.asarray(cost, np.float64))


class FingerprintLibrary:
    """The maintained fingerprint store: model name -> Fingerprint.

    Adding an unseen model never touches estimator weights — this is the
    mechanism behind SCOPE's training-free generalization (Table 1 OOD).
    """

    def __init__(self, anchor_set: AnchorSet):
        self.anchor_set = anchor_set
        self._store: Dict[str, Fingerprint] = {}

    def add(self, fp: Fingerprint) -> None:
        if len(fp.y) != len(self.anchor_set):
            raise ValueError("fingerprint/anchor size mismatch")
        self._store[fp.model] = fp

    def onboard(self, world: World, model_name: str, seed: int = 0) -> Fingerprint:
        fp = build_fingerprint(world, model_name, self.anchor_set, seed)
        self.add(fp)
        return fp

    def get(self, model: str) -> Fingerprint:
        return self._store[model]

    def models(self) -> List[str]:
        return list(self._store)

    def __contains__(self, model: str) -> bool:
        return model in self._store
