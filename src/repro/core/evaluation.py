"""Routing evaluation metrics: Average Accuracy, total Cost, PGR (Table 1).

PGR (Performance Gap Recovered, after RouteLLM as used by the paper):
    PGR = (A_router - A_cheapest) / (A_oracle - A_cheapest)
where the oracle picks the cheapest correct model per query (the paper's
"optimal choice") and A_cheapest is the always-cheapest-model policy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.core.baselines import oracle_choice
from repro.data.datasets import ScopeData


@dataclasses.dataclass
class RoutingEval:
    avg_acc: float
    total_cost: float
    pgr: float
    per_model_share: Dict[str, float]
    exec_tokens: int


def evaluate_choices(data: ScopeData, qids: Sequence[int],
                     models: Sequence[str], choices: np.ndarray
                     ) -> RoutingEval:
    accs, costs, tokens = [], [], 0
    share = {m: 0 for m in models}
    for q, c in zip(qids, choices, strict=True):
        r = data.record(int(q), models[int(c)])
        accs.append(r.y)
        costs.append(r.cost)
        tokens += r.tokens
        share[models[int(c)]] += 1
    n = len(qids)
    avg_acc = float(np.mean(accs))

    # reference policies for PGR
    cheap_idx = int(np.argmin(
        [data.world.models[m].price_out for m in models]))
    a_cheap = float(np.mean(
        [data.record(int(q), models[cheap_idx]).y for q in qids]))
    a_oracle = float(np.mean(
        [data.record(int(q), models[oracle_choice(data, int(q), models)]).y
         for q in qids]))
    denom = a_oracle - a_cheap
    pgr = float((avg_acc - a_cheap) / denom) if abs(denom) > 1e-9 else 1.0
    return RoutingEval(avg_acc=avg_acc, total_cost=float(np.sum(costs)),
                       pgr=pgr,
                       per_model_share={m: v / n for m, v in share.items()},
                       exec_tokens=tokens)


def predictive_metrics(y_hat: np.ndarray, y_gt: np.ndarray,
                       len_hat: np.ndarray, len_gt: np.ndarray,
                       domains: np.ndarray = None) -> Dict:
    """Table 2: ACC for correctness, MAE for token length (per category)."""
    acc = float(np.mean(np.asarray(y_hat) == np.asarray(y_gt)))
    mae = float(np.mean(np.abs(np.asarray(len_hat) - np.asarray(len_gt))))
    out = {"acc": acc, "mae": mae}
    if domains is not None:
        for d in np.unique(domains):
            sel = domains == d
            out[f"acc_d{d}"] = float(np.mean(y_hat[sel] == y_gt[sel]))
            out[f"mae_d{d}"] = float(np.mean(np.abs(len_hat[sel] - len_gt[sel])))
    return out
