"""GRPO reward (SCOPE Eq. 6, 9, 10).

R(o) = G(o) * (R_corr + R_token)
  G       — binary format gate (well-formed structured prediction)
  R_corr  — 1 iff predicted correctness label matches ground truth
  R_token — plateau-with-decay around the ground-truth token count with the
            adaptive tolerance tau = max(200, 0.5 * len_gt): full reward
            within tau/2, linear decay to zero at tau.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def adaptive_tolerance(len_gt: float) -> float:
    return max(200.0, 0.5 * float(len_gt))


def token_reward(len_hat: float, len_gt: float) -> float:
    tau = adaptive_tolerance(len_gt)
    d = abs(float(len_hat) - float(len_gt))
    if d <= tau / 2:
        return 1.0
    if d <= tau:
        return (tau - d) / (0.5 * tau)
    return 0.0


def correctness_reward(y_hat: int, y_gt: int) -> float:
    return 1.0 if int(y_hat) == int(y_gt) else 0.0


def grpo_reward(parsed: Dict, y_gt: int, len_gt: float) -> float:
    """parsed: output of ``tokenizer.parse_prediction``."""
    gate = 1.0 if parsed.get("well_formed", False) else 0.0
    if gate == 0.0:
        return 0.0
    return gate * (correctness_reward(parsed["y_hat"], y_gt)
                   + token_reward(parsed["len_hat"], len_gt))
