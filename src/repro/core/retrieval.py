"""Dense top-K anchor retrieval (SCOPE §3.2, Eq. 2-3).

Cosine similarity between query and anchor embeddings; the hot path is the
Pallas ``topk_retrieval`` kernel (``impl="pallas"``), with the XLA twin as
default on CPU.

Serve-ready: the retriever pre-normalizes and caches the anchor matrix at
construction (the anchor set is fixed for the retriever's lifetime, so
re-normalizing it per call is pure waste) and memoizes one jitted dispatch
per ``k``, so repeated ``retrieve`` calls hit a compiled executable instead
of retracing or running op-by-op.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fingerprint import AnchorSet
from repro.kernels import ops


class AnchorRetriever:
    def __init__(self, anchor_set: AnchorSet, *, impl: str = "xla"):
        self.anchor_set = anchor_set
        self.impl = impl
        embs = jnp.asarray(anchor_set.embeddings, jnp.float32)
        self._anchor_embs = embs
        # unit rows, same epsilon as the kernels' in-call normalization
        self._anchors_norm = embs / (
            jnp.linalg.norm(embs, axis=-1, keepdims=True) + 1e-8)
        self._dispatch: Dict[int, Callable] = {}

    def _fn(self, k: int) -> Callable:
        """One compiled (queries, anchors) -> top-k executable per k."""
        fn = self._dispatch.get(k)
        if fn is None:
            impl = self.impl

            def call(q, a):
                return ops.topk_retrieval(q, a, k, impl=impl,
                                          anchors_prenormalized=True)

            fn = jax.jit(call)
            self._dispatch[k] = fn
        return fn

    def retrieve(self, query_embs: np.ndarray, k: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """query_embs: (Q, d) or (d,).  Returns (sims (Q, k), idx (Q, k))."""
        q = np.atleast_2d(np.asarray(query_embs, np.float32))
        scores, idx = self._fn(int(k))(jnp.asarray(q), self._anchors_norm)
        return np.asarray(scores), np.asarray(idx)
