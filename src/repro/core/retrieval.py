"""Dense top-K anchor retrieval (SCOPE §3.2, Eq. 2-3).

Cosine similarity between query and anchor embeddings; the hot path is the
Pallas ``topk_retrieval`` kernel (``impl="pallas"``), with the XLA twin as
default on CPU.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.fingerprint import AnchorSet
from repro.kernels import ops


class AnchorRetriever:
    def __init__(self, anchor_set: AnchorSet, *, impl: str = "xla"):
        self.anchor_set = anchor_set
        self.impl = impl
        self._anchor_embs = jnp.asarray(anchor_set.embeddings)

    def retrieve(self, query_embs: np.ndarray, k: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """query_embs: (Q, d) or (d,).  Returns (sims (Q, k), idx (Q, k))."""
        q = np.atleast_2d(np.asarray(query_embs, np.float32))
        scores, idx = ops.topk_retrieval(jnp.asarray(q), self._anchor_embs,
                                         k, impl=self.impl)
        return np.asarray(scores), np.asarray(idx)
