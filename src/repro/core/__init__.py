"""SCOPE core: the paper's contribution as composable JAX modules.

  fingerprint   — anchor-set behavioral fingerprints (Eq. 1)
  retrieval     — dense top-K anchor retrieval (Eq. 2-3)
  serialization — structured prompt/target construction (Eq. 4, App. H)
  estimator     — reasoning estimator wrapper (Eq. 5)
  rewards       — gated composite GRPO reward (Eq. 6, 9, 10)
  utility       — log-min-max cost norm + dynamic-gamma utility (Eq. 11-13)
  calibration   — anchor-calibrated prior (Eq. 14-15)
  alpha_search  — budget-controlled alpha (App. D, Prop. D.1)
  router        — PoolPredictions container (decision math: repro.api)
  baselines     — Table 1 / Fig. 7 comparison systems
  evaluation    — PGR / Avg-A / Cost metrics
"""
from repro.core import (  # noqa: F401
    alpha_search, baselines, calibration, estimator, evaluation, fingerprint,
    retrieval, rewards, router, serialization, utility)
