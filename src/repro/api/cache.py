"""Prediction cache for pre-hoc estimates.

Keyed by ``(query_id, model, estimator_version)`` so ``ScopeEngine.predict``
only runs the estimator for missing pairs.  Onboarding a new model onto an
already-served query set then costs O(Q) estimator calls instead of a full
O(Q x M) recompute (the Appendix F adaptation argument, applied to serving).

``query_id`` must identify query *content* — the engine derives it from the
query embedding, not the dataset-local ``qid``, so two datasets that reuse
integer ids never collide.
"""
from __future__ import annotations

import dataclasses
import zlib
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from repro.data.worldsim import Query


def query_key(query: Query) -> int:
    """Stable content-derived id: dataset qid mixed with an embedding CRC."""
    crc = zlib.crc32(np.ascontiguousarray(query.embedding,
                                          np.float32).tobytes())
    return (int(query.qid) << 32) ^ crc


@dataclasses.dataclass(frozen=True)
class CachedPrediction:
    """The estimator's raw parsed output for one (query, model) pair."""
    y_hat: int
    len_hat: float
    well_formed: bool
    p_conf: float
    pred_tokens: int            # overhead spent when this entry was computed
    prompt_tokens: int          # serialized prompt length (cost accounting)


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def snapshot(self) -> "CacheStats":
        return CacheStats(self.hits, self.misses, self.evictions)

    def delta(self, since: "CacheStats") -> "CacheStats":
        return CacheStats(self.hits - since.hits, self.misses - since.misses,
                          self.evictions - since.evictions)


class PredictionCache:
    """LRU map ``(query_id, model, estimator_version) -> CachedPrediction``."""

    def __init__(self, capacity: Optional[int] = None):
        if capacity is not None and capacity <= 0:
            raise ValueError("capacity must be positive or None")
        self.capacity = capacity
        self.stats = CacheStats()
        self._store: "OrderedDict[Tuple[int, str, str], CachedPrediction]" = \
            OrderedDict()

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: Tuple[int, str, str]) -> bool:
        return key in self._store

    def get(self, query_id: int, model: str, version: str
            ) -> Optional[CachedPrediction]:
        entry = self._store.get((query_id, model, version))
        if entry is None:
            self.stats.misses += 1
            return None
        self._store.move_to_end((query_id, model, version))
        self.stats.hits += 1
        return entry

    def put(self, query_id: int, model: str, version: str,
            pred: CachedPrediction) -> None:
        key = (query_id, model, version)
        self._store[key] = pred
        self._store.move_to_end(key)
        if self.capacity is not None:
            while len(self._store) > self.capacity:
                self._store.popitem(last=False)
                self.stats.evictions += 1

    def invalidate_model(self, model: str) -> int:
        """Drop every entry for ``model`` (e.g. after re-fingerprinting)."""
        drop = [k for k in self._store if k[1] == model]
        for k in drop:
            del self._store[k]
        return len(drop)

    def clear(self) -> None:
        self._store.clear()
