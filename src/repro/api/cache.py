"""Prediction cache for pre-hoc estimates.

Keyed by ``(query_id, model, estimator_version)`` so ``ScopeEngine.predict``
only runs the estimator for missing pairs.  Onboarding a new model onto an
already-served query set then costs O(Q) estimator calls instead of a full
O(Q x M) recompute (the Appendix F adaptation argument, applied to serving).

``query_id`` must identify query *content* — the engine derives it from the
query embedding, not the dataset-local ``qid``, so two datasets that reuse
integer ids never collide.
"""
from __future__ import annotations

import dataclasses
import zlib
from collections import OrderedDict
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.status import STATUS_DRIFTED, STATUS_OK
from repro.data.worldsim import Query


def query_key(query: Query) -> int:
    """Stable content-derived id: dataset qid mixed with an embedding CRC."""
    crc = zlib.crc32(np.ascontiguousarray(query.embedding,
                                          np.float32).tobytes())
    return (int(query.qid) << 32) ^ crc


@dataclasses.dataclass(frozen=True)
class CachedPrediction:
    """The estimator's raw parsed output for one (query, model) pair.

    ``status`` marks degraded-mode entries (``core.status``): a DEGRADED
    entry is a provisional answer from retrieval priors.  ``tier`` marks
    which estimator produced the entry: 0 for the pre-router head, 1 for
    the reasoning decode.  Both feed the same overwrite rule — see
    ``PredictionCache._downgrades``.
    """
    y_hat: int
    len_hat: float
    well_formed: bool
    p_conf: float
    pred_tokens: int            # overhead spent when this entry was computed
    prompt_tokens: int          # serialized prompt length (cost accounting)
    status: int = STATUS_OK
    tier: int = 1               # 0 = pre-router head, 1 = reasoning decode


@dataclasses.dataclass
class CachedBatch:
    """Columnar result of a batched cache probe (one model, Q queries).

    ``mask[i]`` says whether query i hit; field rows where ``mask`` is False
    are zero-filled and must be ignored by the caller.
    """
    mask: np.ndarray            # (Q,) bool
    y_hat: np.ndarray           # (Q,) int
    len_hat: np.ndarray         # (Q,) float
    well_formed: np.ndarray     # (Q,) bool
    p_conf: np.ndarray          # (Q,) float
    pred_tokens: np.ndarray     # (Q,) int
    prompt_tokens: np.ndarray   # (Q,) int
    status: np.ndarray          # (Q,) int8 (core.status codes)


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def snapshot(self) -> "CacheStats":
        return CacheStats(self.hits, self.misses, self.evictions)

    def delta(self, since: "CacheStats") -> "CacheStats":
        return CacheStats(self.hits - since.hits, self.misses - since.misses,
                          self.evictions - since.evictions)


class PredictionCache:
    """LRU map ``(query_id, model, estimator_version) -> CachedPrediction``."""

    def __init__(self, capacity: Optional[int] = None):
        if capacity is not None and capacity <= 0:
            raise ValueError("capacity must be positive or None")
        self.capacity = capacity
        self.stats = CacheStats()
        self._store: "OrderedDict[Tuple[int, str, str], CachedPrediction]" = \
            OrderedDict()

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: Tuple[int, str, str]) -> bool:
        return key in self._store

    def get(self, query_id: int, model: str, version: str
            ) -> Optional[CachedPrediction]:
        entry = self._store.get((query_id, model, version))
        if entry is None:
            self.stats.misses += 1
            return None
        self._store.move_to_end((query_id, model, version))
        self.stats.hits += 1
        return entry

    @staticmethod
    def _health(status: int) -> int:
        """Three-level health: OK(2) > DRIFTED(1) > DEGRADED/FAILED(0).

        DRIFTED entries are real decodes conditioned on a stale
        fingerprint — more trustworthy than a retrieval prior, less than a
        fresh decode — so they sit on the middle rung: an OK write (e.g.
        the first probe after ``onboard(refresh=True)``) heals them, and a
        drifted write never clobbers an OK entry."""
        if status == STATUS_OK:
            return 2
        if status == STATUS_DRIFTED:
            return 1
        return 0

    @classmethod
    def _rank(cls, pred: CachedPrediction) -> Tuple[int, int]:
        """Overwrite rank: health first (OK beats DRIFTED beats
        DEGRADED/FAILED), then tier (reasoning decode beats pre-router
        head)."""
        return (cls._health(pred.status), pred.tier)

    def _downgrades(self, key: Tuple[int, str, str],
                    pred: CachedPrediction) -> bool:
        """Whether writing ``pred`` would replace a strictly better entry.

        An entry's rank is ``(health, tier)``: an OK escalated (tier-1)
        decode heals anything; an OK tier-0 answer heals drifted/degraded
        entries but never clobbers a real decode; non-OK entries never
        clobber an OK entry of either tier.  Equal-rank writes refresh in
        place (a newer answer of the same quality wins)."""
        old = self._store.get(key)
        return old is not None and self._rank(pred) < self._rank(old)

    def put(self, query_id: int, model: str, version: str,
            pred: CachedPrediction) -> None:
        key = (query_id, model, version)
        if self._downgrades(key, pred):
            return
        self._store[key] = pred
        self._store.move_to_end(key)
        if self.capacity is not None:
            while len(self._store) > self.capacity:
                self._store.popitem(last=False)
                self.stats.evictions += 1

    # -- batched probes (the serve hot path) ---------------------------
    def get_many(self, query_ids: Sequence[int], model: str, version: str
                 ) -> CachedBatch:
        """Probe Q keys for one model in a single pass.

        Counts one hit/miss per key and refreshes LRU recency of hits, like
        Q ``get`` calls, but returns columnar arrays so the caller never
        touches per-entry objects.
        """
        n = len(query_ids)
        out = CachedBatch(
            mask=np.zeros(n, bool), y_hat=np.zeros(n, int),
            len_hat=np.zeros(n, np.float64), well_formed=np.zeros(n, bool),
            p_conf=np.zeros(n, np.float64), pred_tokens=np.zeros(n, int),
            prompt_tokens=np.zeros(n, int), status=np.zeros(n, np.int8))
        store = self._store
        hits = 0
        for i, qid in enumerate(query_ids):
            key = (qid, model, version)
            e = store.get(key)
            if e is None:
                continue
            store.move_to_end(key)
            hits += 1
            out.mask[i] = True
            out.y_hat[i] = e.y_hat
            out.len_hat[i] = e.len_hat
            out.well_formed[i] = e.well_formed
            out.p_conf[i] = e.p_conf
            out.pred_tokens[i] = e.pred_tokens
            out.prompt_tokens[i] = e.prompt_tokens
            out.status[i] = e.status
        self.stats.hits += hits
        self.stats.misses += n - hits
        return out

    def put_many(self, keys: Sequence[Tuple[int, str, str]],
                 preds: Sequence[CachedPrediction]) -> None:
        """Insert many entries in one pass; eviction runs once at the end."""
        if len(keys) != len(preds):
            raise ValueError(f"{len(keys)} keys for {len(preds)} entries")
        store = self._store
        for key, pred in zip(keys, preds, strict=True):
            if self._downgrades(key, pred):
                continue
            store[key] = pred
            store.move_to_end(key)
        if self.capacity is not None:
            while len(store) > self.capacity:
                store.popitem(last=False)
                self.stats.evictions += 1

    def demote_model(self, model: str,
                     status: int = STATUS_DRIFTED) -> int:
        """Demote every *healthier* entry for ``model`` to ``status`` in
        place (drift quarantine: the entries' numbers are genuine decodes,
        but the fingerprint they were conditioned on is stale).

        This is an administrative rewrite, not a ``put``: it bypasses
        ``_downgrades`` (which exists to stop *data* writes from clobbering
        better entries) and preserves LRU recency.  Entries already at or
        below the target health (degraded/failed provisional answers) are
        left alone.  Returns the number of entries demoted."""
        target = self._health(status)
        n = 0
        for key, e in self._store.items():
            if key[1] == model and self._health(e.status) > target:
                self._store[key] = dataclasses.replace(e, status=status)
                n += 1
        return n

    def invalidate_model(self, model: str) -> int:
        """Drop every entry for ``model`` (e.g. after re-fingerprinting)."""
        drop = [k for k in self._store if k[1] == model]
        for k in drop:
            del self._store[k]
        return len(drop)

    def clear(self) -> None:
        self._store.clear()
