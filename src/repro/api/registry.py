"""Live model-pool registry.

Replaces the frozen ``models_meta`` / ``model_indices`` dicts that used to be
baked into ``ScopeRouter.__init__``: the pool is now a runtime object that
models join (``add_model`` / ``onboard``) and leave (``remove_model``)
mid-session.  ``onboard`` is training-free — one fingerprinting pass over the
anchor set via ``FingerprintLibrary.onboard`` (SCOPE §3.1), never a weight
update.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional

from repro.core.fingerprint import Fingerprint, FingerprintLibrary
from repro.data.worldsim import PoolModel, World


class PoolRegistry:
    def __init__(self, library: FingerprintLibrary,
                 models_meta: Optional[Mapping[str, PoolModel]] = None, *,
                 indices: Optional[Mapping[str, int]] = None):
        self.library = library
        self._meta: Dict[str, PoolModel] = {}
        self._indices: Dict[str, int] = {}
        indices = dict(indices) if indices else {}
        # auto-assigned indices start above every explicit one so indices
        # stay unique (the tokenizer still folds them mod NUM_MODEL_TOKENS,
        # so token aliasing is possible once a session burns >20 indices)
        self._next_index = max(indices.values(), default=-1) + 1
        for meta in (models_meta or {}).values():
            self.add_model(meta, index=indices.get(meta.name))

    # -- membership ----------------------------------------------------
    def add_model(self, meta: PoolModel, *, index: Optional[int] = None) -> int:
        """Register metadata; returns the model's serialization index.

        Re-adding an existing model updates its metadata but keeps its index
        (the estimator's model token must stay stable across a session).
        """
        if meta.name in self._meta:
            self._meta[meta.name] = meta
            return self._indices[meta.name]
        if index is None:
            index = self._next_index
        self._meta[meta.name] = meta
        self._indices[meta.name] = int(index)
        self._next_index = max(self._next_index, int(index)) + 1
        return self._indices[meta.name]

    def remove_model(self, name: str) -> None:
        """Take a model out of the routable pool.

        Its fingerprint stays in the library (history is cheap and makes
        re-adding free); its index is never reused within a session.
        """
        if name not in self._meta:
            raise KeyError(name)
        del self._meta[name]
        del self._indices[name]

    def onboard(self, world: World, name: str, *, seed: int = 0,
                meta: Optional[PoolModel] = None,
                refresh: bool = False) -> Fingerprint:
        """Training-free onboarding: register metadata + fingerprint pass.

        An existing fingerprint is reused unless ``refresh`` forces a new
        pass (e.g. the deployed model drifted).
        """
        meta = meta if meta is not None else world.models[name]
        self.add_model(meta)
        if name in self.library and not refresh:
            return self.library.get(name)
        return self.library.onboard(world, name, seed=seed)

    # -- lookups -------------------------------------------------------
    def models(self) -> List[str]:
        """Registered pool, in insertion order."""
        return list(self._meta)

    def routable(self) -> List[str]:
        """Registered models that also have a fingerprint."""
        return [m for m in self._meta if m in self.library]

    def meta(self, name: str) -> PoolModel:
        return self._meta[name]

    def index(self, name: str) -> int:
        return self._indices.get(name, 0)

    def __contains__(self, name: str) -> bool:
        return name in self._meta

    def __len__(self) -> int:
        return len(self._meta)
