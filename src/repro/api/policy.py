"""Pluggable routing policies.

A ``RoutingPolicy`` turns pool-wide pre-hoc estimates into per-query model
choices.  The four shipped policies cover the paper's control scenarios —
fixed alpha (Eq. 15), set-level budget (Appendix D) — plus two new ones the
decomposition makes one-subclass cheap: an expected-accuracy floor and a
per-query cost ceiling.  New trade-off scenarios subclass ``RoutingPolicy``
instead of growing another kwarg on the serving entry point.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import TYPE_CHECKING, Any, Dict, Optional

import numpy as np

from repro.core import alpha_search
from repro.core.router import PoolPredictions

if TYPE_CHECKING:
    from repro.api.engine import ScopeEngine


@dataclasses.dataclass
class PolicyDecision:
    """What a policy resolved for one batch: trade-off point + choices."""
    alpha: Optional[float]      # None when the policy is not alpha-shaped
    choices: np.ndarray         # (Q,) indices into pool.models
    info: Dict[str, Any] = dataclasses.field(default_factory=dict)


class RoutingPolicy(abc.ABC):
    """Maps (pool predictions, engine) -> PolicyDecision."""

    name: str = "policy"

    @abc.abstractmethod
    def decide(self, pool: PoolPredictions, engine: "ScopeEngine"
               ) -> PolicyDecision:
        ...


class FixedAlphaPolicy(RoutingPolicy):
    """Route every query at one accuracy/cost trade-off point (Eq. 15)."""

    name = "fixed_alpha"

    def __init__(self, alpha: float, *, with_calibration: bool = True):
        if not 0.0 <= alpha <= 1.0:
            raise ValueError(f"alpha must be in [0, 1], got {alpha}")
        self.alpha = float(alpha)
        self.with_calibration = with_calibration

    def decide(self, pool: PoolPredictions, engine: "ScopeEngine"
               ) -> PolicyDecision:
        u = engine.utilities(pool, self.alpha,
                             with_calibration=self.with_calibration)
        return PolicyDecision(self.alpha, np.argmax(u, axis=1))


class SetBudgetPolicy(RoutingPolicy):
    """Solve for alpha* under a set-level dollar budget (App. D, Prop. D.1).

    Degenerate budgets behave conservatively: below the cheapest routing
    the policy falls back to the cheapest candidate (``feasible=False`` in
    the decision info); above the most expensive it reduces to max expected
    accuracy.
    """

    name = "set_budget"

    def __init__(self, budget: float):
        if budget < 0.0:
            raise ValueError(f"budget must be >= 0, got {budget}")
        self.budget = float(budget)

    def decide(self, pool: PoolPredictions, engine: "ScopeEngine"
               ) -> PolicyDecision:
        p_hat, s_hat = engine.affine_scores(pool)
        alpha, choices, info = alpha_search.budget_alpha(
            p_hat, s_hat, pool.cost_hat, self.budget)
        info = dict(info, budget=self.budget)
        return PolicyDecision(alpha, choices, info)


class AccuracyFloorPolicy(RoutingPolicy):
    """Cheapest alpha whose *expected* mean accuracy clears a floor.

    Enumerates the same Prop. D.1 candidate set as the budget search, keeps
    the alphas with mean p_hat >= floor, and picks the one with minimum
    expected cost.  If no alpha clears the floor, falls back to the most
    accurate candidate (``feasible=False``).
    """

    name = "accuracy_floor"

    def __init__(self, floor: float):
        if not 0.0 <= floor <= 1.0:
            raise ValueError(f"floor must be in [0, 1], got {floor}")
        self.floor = float(floor)

    def decide(self, pool: PoolPredictions, engine: "ScopeEngine"
               ) -> PolicyDecision:
        p_hat, s_hat = engine.affine_scores(pool)
        rows = np.arange(p_hat.shape[0])
        cands = alpha_search.candidate_alphas(p_hat, s_hat)
        all_choices = alpha_search.route_for_alphas(p_hat, s_hat, cands)
        accs = p_hat[rows[None], all_choices].mean(axis=1)
        costs = pool.cost_hat[rows[None], all_choices].sum(axis=1)
        feas = np.flatnonzero(accs >= self.floor)
        feasible = bool(len(feas))
        if feasible:
            # cheapest feasible; ties by higher acc, then smallest alpha
            order = np.lexsort((np.arange(len(feas)), -accs[feas],
                                costs[feas]))
            i = int(feas[order[0]])
        else:
            # most accurate overall; ties by lower cost, then smallest alpha
            order = np.lexsort((np.arange(len(cands)), costs, -accs))
            i = int(order[0])
        return PolicyDecision(float(cands[i]), all_choices[i],
                              {"floor": self.floor, "feasible": feasible,
                               "expected_acc": float(accs[i]),
                               "expected_cost": float(costs[i])})


class CostCeilingPolicy(RoutingPolicy):
    """Per-query hard cost cap: never pick a model whose predicted cost
    exceeds the ceiling; route at ``alpha`` among the survivors.

    Queries where every model busts the cap fall back to the cheapest
    predicted model (counted in ``info['fallback_queries']``).
    """

    name = "cost_ceiling"

    def __init__(self, ceiling: float, *, alpha: float = 0.6,
                 with_calibration: bool = True):
        if ceiling <= 0.0:
            raise ValueError(f"ceiling must be > 0, got {ceiling}")
        self.ceiling = float(ceiling)
        self.alpha = float(alpha)
        self.with_calibration = with_calibration

    def decide(self, pool: PoolPredictions, engine: "ScopeEngine"
               ) -> PolicyDecision:
        u = engine.utilities(pool, self.alpha,
                             with_calibration=self.with_calibration)
        over = pool.cost_hat > self.ceiling
        u = np.where(over, -np.inf, u)
        choices = np.argmax(u, axis=1)
        all_over = over.all(axis=1)
        if all_over.any():
            choices = np.where(all_over, np.argmin(pool.cost_hat, axis=1),
                               choices)
        return PolicyDecision(self.alpha, choices,
                              {"ceiling": self.ceiling,
                               "capped_pairs": int(over.sum()),
                               "fallback_queries": int(all_over.sum())})


class DriftAwarePolicy(RoutingPolicy):
    """Quarantine-aware wrapper: route around drifted models.

    Reads the engine's ``FeedbackMonitor`` quarantine set and either
    removes the drifted models from the candidate pool before delegating
    to ``inner`` (``mode="exclude"``) or scales their p_hat down by
    ``1 - weight`` so the inner policy's own utility math deprioritizes
    them (``mode="downweight"`` — a drifted model can still win when
    nothing else is affordable).  With no monitor or an empty quarantine
    set the wrapper is a pass-through: the inner policy sees the pool
    unchanged, decision-identical to running unwrapped.  If *every* model
    is quarantined, excluding would leave nothing to route — the wrapper
    falls back to the full pool (``info["drift_all_quarantined"]``).
    """

    def __init__(self, inner: RoutingPolicy, *, mode: str = "exclude",
                 weight: float = 0.5):
        if mode not in ("exclude", "downweight"):
            raise ValueError(f"unknown mode {mode!r} "
                             "(expected 'exclude' or 'downweight')")
        if not 0.0 <= weight <= 1.0:
            raise ValueError(f"weight must be in [0, 1], got {weight}")
        self.inner = inner
        self.mode = mode
        self.weight = float(weight)
        self.name = f"drift_aware({inner.name})"

    def decide(self, pool: PoolPredictions, engine: "ScopeEngine"
               ) -> PolicyDecision:
        monitor = getattr(engine, "monitor", None)
        drifted = (monitor.drifted if monitor is not None else set())
        hit = [m for m in pool.models if m in drifted]
        if not hit:
            return self.inner.decide(pool, engine)
        if self.mode == "downweight":
            mask = np.asarray([m in drifted for m in pool.models])
            p = np.where(mask[None, :], pool.p_hat * (1.0 - self.weight),
                         pool.p_hat)
            decision = self.inner.decide(
                dataclasses.replace(pool, p_hat=p), engine)
            decision.info["drift_downweighted"] = hit
            return decision
        keep = np.asarray([i for i, m in enumerate(pool.models)
                           if m not in drifted], int)
        if len(keep) == 0:
            decision = self.inner.decide(pool, engine)
            decision.info["drift_all_quarantined"] = True
            return decision
        sliced = dataclasses.replace(
            pool,
            models=[pool.models[i] for i in keep],
            p_hat=pool.p_hat[:, keep], y_hat=pool.y_hat[:, keep],
            len_hat=pool.len_hat[:, keep], cost_hat=pool.cost_hat[:, keep],
            well_formed=pool.well_formed[:, keep],
            pred_overhead=pool.pred_overhead[:, keep],
            status=(None if pool.status is None else pool.status[:, keep]))
        decision = self.inner.decide(sliced, engine)
        # remap the inner policy's column choices back into the full pool
        decision.choices = keep[np.asarray(decision.choices, int)]
        decision.info["drift_excluded"] = hit
        return decision
