"""repro.api — the public SCOPE routing surface.

  ScopeEngine      — facade owning estimator, retriever, library, and pool
  EngineConfig     — single typed builder input (``ScopeEngine.build``)
  PoolRegistry     — live pool: add_model / remove_model / onboard
  RoutingPolicy    — pluggable decision policies (subclass to extend)
  PredictionCache  — (query_id, model, estimator_version) -> estimate

Streaming traffic enters through ``ScopeEngine.predict_stream`` /
``serve_stream``, backed by ``repro.serving.scheduler``.  (The legacy
``ScopeRouter`` / ``RouterService`` shims are gone — every caller now goes
through this package.)
"""
from repro.api.cache import CachedPrediction, CacheStats, PredictionCache
from repro.api.engine import ScopeEngine
from repro.api.policy import (
    AccuracyFloorPolicy, CostCeilingPolicy, DriftAwarePolicy,
    FixedAlphaPolicy, PolicyDecision, RoutingPolicy, SetBudgetPolicy)
from repro.api.registry import PoolRegistry
from repro.api.types import (
    BatchReport, EngineConfig, PoolPredictions, RouteDecision, RouteRequest)

__all__ = [
    "AccuracyFloorPolicy",
    "BatchReport",
    "CacheStats",
    "CachedPrediction",
    "CostCeilingPolicy",
    "DriftAwarePolicy",
    "EngineConfig",
    "FixedAlphaPolicy",
    "PolicyDecision",
    "PoolPredictions",
    "PoolRegistry",
    "PredictionCache",
    "RouteDecision",
    "RouteRequest",
    "RoutingPolicy",
    "ScopeEngine",
    "SetBudgetPolicy",
]
