"""Typed protocol of the public routing surface.

These dataclasses are the stable contract between callers and the
``ScopeEngine`` facade: a ``RouteRequest`` goes in, ``RouteDecision`` /
``BatchReport`` come out, and ``EngineConfig`` is the single builder input
(in the spirit of workload-spec interfaces: configuration and components in
one typed object, behavior behind a facade).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.router import PoolPredictions  # noqa: F401  (re-export)
from repro.data.worldsim import Query

if TYPE_CHECKING:                               # components, no runtime cycle
    from repro.api.registry import PoolRegistry
    from repro.core.estimator import ReasoningEstimator
    from repro.core.fingerprint import FingerprintLibrary
    from repro.core.retrieval import AnchorRetriever
    from repro.data.worldsim import PoolModel
    from repro.models.tier0 import Tier0Head
    from repro.serving.faults import FaultPlan


@dataclasses.dataclass
class EngineConfig:
    """Everything ``ScopeEngine.build`` needs: owned components + knobs.

    Exactly one of ``registry`` / ``models_meta`` describes the pool;
    ``models_meta`` is the legacy dict form and is wrapped in a fresh
    ``PoolRegistry`` by the builder.
    """
    estimator: "ReasoningEstimator"
    retriever: "AnchorRetriever"
    library: "FingerprintLibrary"
    registry: Optional["PoolRegistry"] = None
    models_meta: Optional[Dict[str, "PoolModel"]] = None
    # router hyper-parameters (SCOPE Eq. 12-15)
    k: int = 5
    gamma_base: float = 1.0
    beta: float = 2.0
    w_base: float = 0.2
    use_confidence: bool = True
    # prediction cache
    estimator_version: str = "v0"
    enable_cache: bool = True
    cache_capacity: Optional[int] = None
    # streaming serve runtime (predict_stream / serve_stream)
    refill: bool = False            # segment-chunked mid-batch slot refill
    segment_len: int = 4            # decode steps per scan segment (refill)
    refill_horizon: Optional[int] = None    # decode-slot capacity in steps
    #                                         (None = 4x max_new_tokens)
    max_pending: Optional[int] = None       # in-flight microbatches in the
    #                                         ServeRuntime pipeline (None =
    #                                         1 if overlap else 0)
    # paged KV cache (refill path only): block-paged decode-cache pool
    # instead of the dense per-slot horizon — KV memory scales with live
    # tokens, admission gates on free pages
    kv_paged: bool = False
    kv_page_size: int = 16          # token positions per KV page
    kv_pool_pages: Optional[int] = None     # pool size in pages (None =
    #                                         auto-size to the opening
    #                                         bucket's worst case)
    kv_kernel: str = "xla"          # paged decode-attention impl:
    #                                 "xla" (gather, bit-parity with dense)
    #                                 or "pallas"
    # fault tolerance (stream paths): a failed microbatch / slot segment
    # requeues its rows and retries up to max_retries times (exponential
    # backoff from retry_backoff_s); rows that keep failing are
    # quarantined and answered from retrieval priors (degrade=True) or
    # marked FAILED.  deadline_ms bounds a request's queue + in-flight
    # age — past it the pair is answered degraded immediately.
    # fault_plan (serving.faults.FaultPlan) injects deterministic chaos;
    # None and FaultPlan.none() are bit-identical no-ops.
    max_retries: int = 2
    retry_backoff_s: float = 0.0
    deadline_ms: Optional[float] = None
    degrade: bool = True
    fault_plan: Optional["FaultPlan"] = None
    # two-tier routing: a distilled pre-router head answers (query, model)
    # pairs whose calibrated confidence max(p, 1-p) clears
    # escalation_threshold in one jitted forward; only the remainder pays
    # the reasoning decode.  Thresholds <= 0.5 escalate nothing (conf is
    # always >= 0.5); thresholds > 1.0 escalate everything, bit-identical
    # to tier0=None.  Tier-0 answers never enter the scheduler or the
    # in-flight dedup map, and their cache entries carry tier=0 so an
    # escalated decode overwrites them but never the reverse.
    tier0: Optional["Tier0Head"] = None
    escalation_threshold: float = 0.9
    # drift-aware self-healing (serving.feedback): with drift_detect on,
    # every executed (query, model) pair's (predicted, observed) outcome
    # lands in a bounded replay buffer and feeds a per-model Page–Hinkley
    # detector over the calibration residual p_hat - y.  On alarm the
    # model's cached predictions are demoted to DRIFTED (an OK write
    # after onboard(refresh=True) heals them), its serve-time status
    # columns are stamped DRIFTED, and DriftAwarePolicy can exclude or
    # down-weight it.  Collection is passive: with no model_drift fault
    # in the plan, detector-on serving is bit-identical to detector-off
    # (predictions, cache contents, deterministic stats outside the
    # drift block).  drift_threshold is the Page–Hinkley alarm mass
    # (lambda) — sized above the bounded oscillation calibrated Bernoulli
    # residuals show on run-structured traffic — drift_delta the
    # per-observation drift allowance, drift_min_obs the observations a
    # model needs before it may alarm, feedback_capacity the
    # replay-buffer bound in rows.
    drift_detect: bool = False
    drift_threshold: float = 5.0
    drift_delta: float = 0.05
    drift_min_obs: int = 8
    feedback_capacity: int = 4096


@dataclasses.dataclass
class RouteRequest:
    """A batch of queries to route.

    ``models`` defaults to the engine's full registered pool; ``query_embs``
    may carry precomputed retrieval embeddings (one row per query).
    """
    queries: List[Query]
    models: Optional[Sequence[str]] = None
    query_embs: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return len(self.queries)


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """One routed query: which model, under what trade-off, at what estimate."""
    query_id: int
    model: str
    alpha: Optional[float]
    p_hat: float                # estimator's P(correct) for the chosen model
    cost_hat: float             # predicted $ for the chosen model
    status: str = "OK"          # how the chosen pair was estimated
    #                             (core.status: OK / DEGRADED / FAILED)


@dataclasses.dataclass
class BatchReport:
    """Outcome of routing (and optionally executing) one request batch."""
    policy: str
    alpha: Optional[float]
    decisions: List[RouteDecision]
    accuracy: float             # realized on execution, expected otherwise
    total_cost: float
    exec_tokens: int
    overhead_tokens: int        # estimator tokens spent on *this* call
    per_model_share: Dict[str, float]
    cache_hits: int = 0
    cache_misses: int = 0
    executed: bool = True
    info: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def choices(self) -> np.ndarray:
        return np.asarray([d.model for d in self.decisions])

    @property
    def n_queries(self) -> int:
        return len(self.decisions)

    @classmethod
    def empty(cls, policy: str, models: Sequence[str]) -> "BatchReport":
        return cls(policy=policy, alpha=None, decisions=[], accuracy=0.0,
                   total_cost=0.0, exec_tokens=0, overhead_tokens=0,
                   per_model_share={m: 0.0 for m in models},
                   executed=False, info={"empty": True})
