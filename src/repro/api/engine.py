"""``ScopeEngine`` — the single public entry point for SCOPE routing.

The engine owns the four components the paper's pipeline needs at serve time
(reasoning estimator, anchor retriever, fingerprint library, model pool) and
exposes the routing surface as four verbs:

  predict  — cache-aware pool-wide pre-hoc estimation (Eq. 5, Eq. 24)
  route    — apply a ``RoutingPolicy`` to a request, report expected metrics
  serve    — route + execute against a ``ScopeData`` world, report realized
  onboard  — training-free pool growth (fingerprint pass, §3.1)

plus their streaming duals for continuous traffic:

  predict_stream — drain an iterator of requests through the bucketed
                   microbatch scheduler (``serving.scheduler``); results
                   are bit-identical to ``predict`` under greedy decoding
  serve_stream   — predict_stream + per-tick policy decision + execution

``predict`` consults the ``PredictionCache`` keyed by
``(query_id, model, estimator_version)`` and runs the estimator only for the
missing (query, model) pairs, so onboarding a model onto an already-served
query set costs O(Q) new estimator calls instead of an O(Q x M) recompute.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import (
    TYPE_CHECKING, Any, Deque, Dict, Iterable, Iterator, List, Optional,
    Sequence, Tuple)

import jax
import numpy as np

from repro.api.cache import (
    CachedBatch, CachedPrediction, PredictionCache, query_key)
from repro.api.policy import PolicyDecision, RoutingPolicy
from repro.api.registry import PoolRegistry
from repro.api.types import (
    BatchReport, EngineConfig, RouteDecision, RouteRequest)
from repro.core import calibration, serialization, utility
from repro.core.fingerprint import Fingerprint
from repro.core.router import PoolPredictions
from repro.core.status import STATUS_DRIFTED, STATUS_OK, status_name
from repro.data.datasets import ScopeData
from repro.data.worldsim import PoolModel, World

if TYPE_CHECKING:
    from repro.serving.feedback import FeedbackMonitor
    from repro.serving.scheduler import MicrobatchScheduler

FALLBACK_LEN_HAT = 512.0    # tokens charged when the estimate is malformed

_UNSET = object()           # hot_swap: "caller passed no tier-0 head"


@dataclasses.dataclass
class _PredictState:
    """Per-request prediction state between cache probe and assembly."""
    models: List[str]
    queries: List
    qkeys: List[int]
    sims: np.ndarray            # (Q, K)
    idx: np.ndarray             # (Q, K)
    hit: np.ndarray             # (Q, M) bool — cache probe result
    y_hat: np.ndarray
    len_hat: np.ndarray
    wf: np.ndarray
    p_conf: np.ndarray
    prompt_tok: np.ndarray
    missing: np.ndarray         # (n, 2) row-major (query, model) misses
    prompts: List[List[int]]    # serialized prompt per missing pair
    use_cache: bool
    status: Optional[np.ndarray] = None     # (Q, M) core.status codes
    # two-tier gate outcome: after ``_gate_tier0`` runs, ``missing`` /
    # ``prompts`` hold only the escalated pairs; answered pairs were
    # scattered into the prediction columns directly.  ``t0_rows`` keeps
    # the escalated pairs' tier-0 (p, len_hat, y_hat) so a quarantined or
    # expired escalation degrades to the head's answer, not the retrieval
    # prior.
    tier0_answered: int = 0
    escalated: int = 0
    t0_rows: Optional[Dict[int, Tuple[float, float, int]]] = None


class _StreamEntry:
    """One in-flight stream request: collects estimator rows as the
    scheduler's microbatches land, in ``missing``-pair order."""

    def __init__(self, state: _PredictState):
        self.state = state
        n = len(state.prompts)
        self.remaining = n
        self.y_hat = np.zeros(n, int)
        self.len_hat = np.zeros(n, np.float64)
        self.well_formed = np.zeros(n, bool)
        self.p_conf = np.zeros(n, np.float64)
        self.pred_tokens = np.zeros(n, int)
        self.rationale_len = np.zeros(n, int)
        self.status = np.full(n, STATUS_OK, np.int8)

    def fill(self, i: int, batch, row: int, *, shared: bool = False) -> None:
        """``shared=True`` marks a pair that rode an in-flight duplicate's
        generation: it copies the estimate but spends no new tokens."""
        self.y_hat[i] = batch.y_hat[row]
        self.len_hat[i] = batch.len_hat[row]
        self.well_formed[i] = batch.well_formed[row]
        self.p_conf[i] = batch.p_conf[row]
        self.pred_tokens[i] = 0 if shared else batch.pred_tokens[row]
        self.rationale_len[i] = batch.rationale_len[row]
        self.status[i] = batch.status[row]
        self.remaining -= 1

    def parsed(self):
        from repro.core.estimator import ParsedBatch
        return ParsedBatch(self.y_hat, self.len_hat, self.well_formed,
                           self.p_conf, self.pred_tokens, self.rationale_len,
                           status=self.status)


def _mb_rows(mb) -> List[Tuple[Any, List[int]]]:
    """(tag, prompt) per real row of a failed microbatch, for requeue."""
    return [(mb.tags[r], mb.tokens[r, : mb.lengths[r]].tolist())
            for r in range(mb.n_real)]


class _StreamControl:
    """Per-stream fault tolerance: bounded retry/requeue, quarantine, SLO
    deadlines, and degraded answers from retrieval priors.

    One instance per ``predict_stream`` call.  It owns the stream's
    ``FaultInjector`` (a no-op without an ``EngineConfig.fault_plan``) and
    the per-prompt failure ledger: ``attempts`` counts failures per
    in-flight dedup key, ``unresolved`` is the ordered set of keys whose
    waiters have not been answered yet, ``t_submit``/``n_prompt`` back the
    deadline check and late cache writes.  Exactly-once delivery is the
    invariant everything here preserves: a key leaves ``unresolved`` the
    moment its waiters are filled — by a real parse (``note_resolved`` via
    ``_stream_fill``) or by ``degrade`` — and every later event on that
    key (a requeue race, a late parse of an expired row) only touches the
    cache, never the waiters.
    """

    def __init__(self, engine: "ScopeEngine", sched, inflight: Dict,
                 use_cache: bool):
        from repro.core.estimator import FallbackEstimator
        from repro.serving.faults import FaultInjector
        cfg = engine.config
        self.engine = engine
        self.sched = sched
        self.inflight = inflight
        self.use_cache = use_cache
        self.injector = FaultInjector(cfg.fault_plan)
        self.max_retries = int(cfg.max_retries)
        self.backoff_s = float(cfg.retry_backoff_s)
        self.deadline_s = (None if cfg.deadline_ms is None
                           else float(cfg.deadline_ms) / 1e3)
        self.fallback = FallbackEstimator(engine.library)
        self.attempts: Dict[Any, int] = {}
        self.t_submit: Dict[Any, float] = {}
        self.n_prompt: Dict[Any, int] = {}
        self.unresolved: Dict[Any, bool] = {}   # insertion-ordered set
        # escalated pairs' stashed tier-0 (p, len_hat, y_hat): the degrade
        # ladder prefers the head's answer over the retrieval prior
        self.t0_rows: Dict[Any, Tuple[float, float, int]] = {}
        self.sleep = time.sleep                 # injectable in tests

    def now(self) -> float:
        """Deadline time base: the scheduler's (injectable) clock plus the
        seconds injected by fired ``stall`` faults."""
        return self.sched.now() + self.injector.stall_offset

    # -- ledger --------------------------------------------------------
    def note_submit(self, key, prompt) -> None:
        """A key was scheduled (fresh, or fresh again after an earlier
        resolution): reset its deadline epoch and failure budget."""
        self.t_submit[key] = self.now()
        self.n_prompt[key] = len(prompt)
        self.attempts.pop(key, None)
        self.unresolved[key] = True

    def note_resolved(self, key) -> None:
        self.unresolved.pop(key, None)

    def prompt_tokens(self, key) -> int:
        return self.n_prompt.get(key, 0)

    # -- injection hooks ------------------------------------------------
    def pre_dispatch(self) -> None:
        """Microbatch-launch boundary: one stall event, one dispatch event."""
        self.injector.tick("stall")
        self.injector.raise_if("dispatch")

    def corrupt(self, batch):
        return self.injector.corrupt_parse(batch)

    # -- bounded retry / quarantine --------------------------------------
    def on_failed(self, rows, exc: Optional[Exception] = None) -> None:
        """Route one failure event's rows (``[(key, prompt)]``) back into
        the scheduler, quarantining rows past their retry budget.  Keys no
        longer unresolved (already answered degraded — e.g. a deadline
        expiry racing the in-flight decode) are dropped: their requests
        were served exactly once already."""
        stats = self.sched.stats
        stats.retries += 1
        worst = 0
        for key, prompt in rows:
            if key not in self.unresolved:
                continue
            n = self.attempts.get(key, 0) + 1
            self.attempts[key] = n
            if n <= self.max_retries:
                worst = max(worst, n)
                self.sched.requeue(key, prompt)
            else:
                stats.quarantined += 1
                self.degrade(key)
        if worst and self.backoff_s > 0.0:
            self.sleep(self.backoff_s * (2 ** (worst - 1)))

    def on_failed_mb(self, mb, exc: Optional[Exception] = None) -> None:
        self.on_failed(_mb_rows(mb), exc)

    # -- SLO deadlines ----------------------------------------------------
    def expire(self) -> None:
        """Answer every unresolved key past its deadline in degraded mode.
        Queued rows are cancelled outright; in-flight rows keep decoding
        and their late parse heals the cache entry."""
        if self.deadline_s is None or not self.unresolved:
            return
        now = self.now()
        for key in list(self.unresolved):
            if now - self.t_submit[key] < self.deadline_s:
                continue
            self.sched.cancel(key)
            self.sched.stats.deadline_expired += 1
            self.degrade(key)

    # -- graceful degradation ---------------------------------------------
    def degrade(self, key) -> None:
        """Answer every waiter on ``key`` in degraded mode and resolve the
        key.  The fallback ladder: the pair's stashed tier-0 answer (an
        escalation that never completed its decode still has the head's
        calibrated estimate), then retrieval priors, then FAILED when
        ``EngineConfig.degrade`` is off.  All waiters share one fallback
        row — they are the same (query, model) content by construction of
        the dedup key."""
        waiters = self.inflight.pop(key, None)
        self.note_resolved(key)
        if not waiters:
            return
        cfg = self.engine.config
        stats = self.sched.stats
        owner, miss_i = waiters[0]
        st = owner.state
        qi, mi = st.missing[miss_i]
        tier = 1
        stash = self.t0_rows.get(key) if cfg.degrade else None
        if stash is not None and stash[0] != cfg.estimator_version:
            # stashed at submit time under a since-swapped estimator: the
            # old head's answer is miscalibrated for the new version — fall
            # through to the retrieval-prior rung (exactly-once unchanged)
            stash = None
        if stash is not None:
            from repro.core.estimator import ParsedBatch
            from repro.core.status import STATUS_DEGRADED
            p, lh, y = stash[1]
            batch = ParsedBatch(
                np.asarray([y]), np.asarray([lh]), np.ones(1, bool),
                np.asarray([p]), np.zeros(1, int), np.zeros(1, int),
                status=np.full(1, STATUS_DEGRADED, np.int8))
            stats.degraded += 1
            stats.tier0_fallbacks += 1
            tier = 0
        elif cfg.degrade:
            batch = self.fallback.predict_pairs(
                st.sims[qi:qi + 1], st.idx[qi:qi + 1], [st.models[mi]])
            stats.degraded += 1
        else:
            batch = self.fallback.failed_pairs(1)
            stats.failed_pairs += 1
        for j, (entry, i) in enumerate(waiters):
            entry.fill(i, batch, 0, shared=j > 0)
        if self.use_cache and cfg.degrade:
            self.engine.cache.put_many([key], [CachedPrediction(
                y_hat=int(batch.y_hat[0]), len_hat=float(batch.len_hat[0]),
                well_formed=bool(batch.well_formed[0]),
                p_conf=float(batch.p_conf[0]), pred_tokens=0,
                prompt_tokens=self.prompt_tokens(key),
                status=int(batch.status[0]), tier=tier)])


class ScopeEngine:
    def __init__(self, config: EngineConfig, registry: PoolRegistry,
                 cache: PredictionCache, *,
                 monitor: Optional["FeedbackMonitor"] = None):
        from repro.serving.faults import FaultInjector
        self.config = config
        self.registry = registry
        self.cache = cache
        # drift-aware self-healing: the outcome monitor (None unless
        # EngineConfig.drift_detect), the engine-lifetime injector that
        # arms model_drift faults at outcome-observation events (streams
        # own separate injectors for the serve-boundary sites), and the
        # hot-swap ledger
        self.monitor = monitor
        self._outcome_injector = FaultInjector(config.fault_plan)
        self._hot_swaps = 0

    # -- construction --------------------------------------------------
    @classmethod
    def build(cls, config: EngineConfig) -> "ScopeEngine":
        """Validate an ``EngineConfig`` and wire the facade."""
        for field in ("estimator", "retriever", "library"):
            if getattr(config, field) is None:
                raise ValueError(f"EngineConfig.{field} is required")
        if config.registry is not None and config.models_meta is not None:
            raise ValueError(
                "pass either EngineConfig.registry or .models_meta, not both")
        registry = config.registry
        if registry is None:
            registry = PoolRegistry(config.library, config.models_meta)
        elif registry.library is not config.library:
            raise ValueError("registry.library and config.library differ")
        monitor = None
        if config.drift_detect:
            from repro.serving.feedback import FeedbackMonitor
            monitor = FeedbackMonitor(
                capacity=config.feedback_capacity,
                delta=config.drift_delta,
                threshold=config.drift_threshold,
                min_obs=config.drift_min_obs)
        return cls(config, registry, PredictionCache(config.cache_capacity),
                   monitor=monitor)

    # -- owned components ----------------------------------------------
    @property
    def estimator(self):
        return self.config.estimator

    @property
    def retriever(self):
        return self.config.retriever

    @property
    def library(self):
        return self.config.library

    def set_estimator(self, estimator, version: str) -> None:
        """Swap estimator weights; the version bump keys a fresh cache space."""
        self.config.estimator = estimator
        self.config.estimator_version = version

    def hot_swap(self, estimator, version: str, *, tier0=_UNSET) -> None:
        """Swap the estimator under live traffic, exactly-once preserved.

        Safe mid-stream on the refill path: the live slot state keeps the
        old params (its rows finish on them — ``ReasoningEstimator.
        open_slots`` closed over the params at state open), while the next
        state opened at a segment boundary binds the new estimator.  The
        required version bump invalidates the ``PredictionCache`` and the
        in-flight dedup keys for free — both are keyed on
        ``estimator_version`` — and stashed tier-0 fallback answers carry
        their submit-time version, so ``_StreamControl.degrade`` refuses
        any stash minted before the swap.

        ``tier0``: a head distilled/calibrated against the *new* estimator
        (stamped with ``version``); omitted, any configured head is
        dropped — its probabilities and temperature calibrate the old
        estimator, and serving miscalibrated tier-0 answers under a new
        version would poison the fresh cache space.  Pass ``tier0=None``
        explicitly for the same drop without the implicit-behavior read.

        Stages no new executables: this is a host-side pointer swap (the
        new params pytree was compiled against the same bucketed shapes),
        so the jaxpr registry gains nothing from it.
        """
        cfg = self.config
        if version == cfg.estimator_version:
            raise ValueError(
                f"hot_swap requires a new estimator_version (got "
                f"{version!r}, already current); the version bump is what "
                "invalidates the cache and the tier-0 stashes")
        cfg.estimator = estimator
        cfg.estimator_version = version
        if tier0 is _UNSET:
            cfg.tier0 = None
        else:
            if tier0 is not None:
                tier0.version = version
            cfg.tier0 = tier0
        self._hot_swaps += 1

    # -- pool lifecycle ------------------------------------------------
    def onboard(self, world: World, name: str, *, seed: int = 0,
                meta: Optional[PoolModel] = None,
                refresh: bool = False) -> Fingerprint:
        """Training-free: register + one fingerprint pass, no weight update.

        ``refresh=True`` re-fingerprints an already-known model and drops
        its cached predictions (they were computed from the old
        fingerprint).  With a drift monitor attached and replay-buffer
        outcomes recorded for the model, the refresh is synthesized from
        *served traffic* (``FeedbackMonitor.refresh_fingerprint``) instead
        of a world pass — the self-healing path needs no offline dataset —
        and the model's quarantine and detector are cleared.
        """
        monitor = self.monitor
        if refresh and monitor is not None and monitor.can_refresh(name):
            self.registry.add_model(meta if meta is not None
                                    else world.models[name])
            fp = monitor.refresh_fingerprint(name, self.library)
            self.library.add(fp)
            self.cache.invalidate_model(name)
            monitor.clear(name)
            return fp
        fp = self.registry.onboard(world, name, seed=seed, meta=meta,
                                   refresh=refresh)
        if refresh:
            self.cache.invalidate_model(name)
            if monitor is not None:
                monitor.clear(name)
        return fp

    def remove_model(self, name: str) -> None:
        self.registry.remove_model(name)
        self.cache.invalidate_model(name)

    # -- prediction ----------------------------------------------------
    def _empty_pool(self, models: List[str], Q: int) -> PoolPredictions:
        M = len(models)
        k = self.config.k
        return PoolPredictions(
            models, np.zeros((Q, M)), np.zeros((Q, M), int),
            np.zeros((Q, M)), np.zeros((Q, M)), np.zeros((Q, M), bool),
            np.zeros((Q, M)), np.zeros((Q, k)), np.zeros((Q, k), int))

    def _prepare(self, request: RouteRequest, use_cache: bool
                 ) -> "_PredictState":
        """Everything before the estimator: retrieval, cache probe, and the
        serialized prompts for the missing (query, model) pairs."""
        cfg = self.config
        models = (list(request.models) if request.models is not None
                  else self.registry.routable())
        queries = list(request.queries)
        Q, M = len(queries), len(models)
        if Q == 0 or M == 0:            # empty before validation, as predict
            return _PredictState(models, queries, [], np.zeros((Q, cfg.k)),
                                 np.zeros((Q, cfg.k), int),
                                 np.zeros((Q, M), bool), np.zeros((Q, M), int),
                                 np.zeros((Q, M)), np.zeros((Q, M), bool),
                                 np.zeros((Q, M)), np.zeros((Q, M)),
                                 np.zeros((0, 2), int), [], use_cache,
                                 status=np.zeros((Q, M), np.int8))
        for m in models:
            if m not in self.registry:
                raise KeyError(f"model {m!r} is not registered; "
                               "PoolRegistry.add_model/onboard it first")
            if m not in self.library:
                raise KeyError(f"model {m!r} has no fingerprint; "
                               "PoolRegistry.onboard it first")

        embs = request.query_embs
        if embs is None:
            embs = np.stack([q.embedding for q in queries])
        sims, idx = self.retriever.retrieve(embs, cfg.k)

        # -- batched cache probe: one pass per model column ------------
        version = cfg.estimator_version
        qkeys = [query_key(q) for q in queries]
        hit = np.zeros((Q, M), bool)
        y_hat = np.zeros((Q, M), int)
        len_hat = np.zeros((Q, M))
        wf = np.zeros((Q, M), bool)
        p_conf = np.zeros((Q, M))
        prompt_tok = np.zeros((Q, M))
        status = np.full((Q, M), STATUS_OK, np.int8)
        if use_cache:
            for mi, m in enumerate(models):
                col: CachedBatch = self.cache.get_many(qkeys, m, version)
                hit[:, mi] = col.mask
                y_hat[:, mi] = col.y_hat
                len_hat[:, mi] = col.len_hat
                wf[:, mi] = col.well_formed
                p_conf[:, mi] = col.p_conf
                prompt_tok[:, mi] = col.prompt_tokens
                status[:, mi] = np.where(col.mask, col.status, STATUS_OK)

        missing = np.argwhere(~hit)                     # (n, 2) row-major
        prompts: List[List[int]] = []
        feats = None
        if cfg.tier0 is not None and len(missing):
            from repro.models.tier0 import pair_features
            feats = []
        for qi, mi in missing:
            m = models[mi]
            meta = self.registry.meta(m)
            midx = self.registry.index(m)
            fp = self.library.get(m)
            prompts.append(serialization.serialize_prompt(
                meta, midx, self.library.anchor_set, fp,
                sims[qi], idx[qi], queries[qi]))
            if feats is not None:
                feats.append(pair_features(
                    meta, midx, self.library.anchor_set, fp,
                    sims[qi], idx[qi], queries[qi]))
        st = _PredictState(models, queries, qkeys, sims, idx, hit, y_hat,
                           len_hat, wf, p_conf, prompt_tok, missing,
                           prompts, use_cache, status=status)
        if feats is not None:
            self._gate_tier0(st, feats)
        return st

    def _gate_tier0(self, st: "_PredictState", feats: List) -> None:
        """Tier-0 gating stage: one jitted head forward over the missing
        pairs; pairs whose calibrated confidence clears
        ``escalation_threshold`` are answered in place (OK status, zero
        decode overhead, the serialized prompt length for Eq. 24 cost
        accounting) and removed from ``missing``/``prompts`` so they never
        reach the estimator, the scheduler, or the in-flight dedup map.
        The rest escalate unchanged, with their tier-0 rows stashed for
        quarantine/deadline fallback."""
        cfg = self.config
        batch0 = cfg.tier0.predict_features(feats)
        answer = batch0.conf >= cfg.escalation_threshold
        st.tier0_answered = int(answer.sum())
        st.escalated = len(feats) - st.tier0_answered
        keep = np.flatnonzero(~answer)
        st.t0_rows = {int(new_i): (float(batch0.p[i]),
                                   float(batch0.len_hat[i]),
                                   int(batch0.y_hat[i]))
                      for new_i, i in enumerate(keep)}
        if st.tier0_answered == 0:
            return
        taken = np.flatnonzero(answer)
        aq, am = st.missing[taken, 0], st.missing[taken, 1]
        st.y_hat[aq, am] = batch0.y_hat[taken]
        st.len_hat[aq, am] = batch0.len_hat[taken]
        st.wf[aq, am] = True
        st.p_conf[aq, am] = batch0.p[taken]
        plens = np.fromiter((len(st.prompts[i]) for i in taken), int,
                            count=len(taken))
        st.prompt_tok[aq, am] = plens
        if st.use_cache:
            self.cache.put_many(
                [(st.qkeys[qi], st.models[mi], cfg.estimator_version)
                 for qi, mi in st.missing[taken]],
                [CachedPrediction(
                    y_hat=int(batch0.y_hat[i]),
                    len_hat=float(batch0.len_hat[i]),
                    well_formed=True, p_conf=float(batch0.p[i]),
                    pred_tokens=0, prompt_tokens=int(plens[j]),
                    status=STATUS_OK, tier=0)
                 for j, i in enumerate(taken)])
        st.missing = st.missing[keep]
        st.prompts = [st.prompts[i] for i in keep]

    def _fold_tier_stats(self, stats, st: "_PredictState") -> None:
        """Accumulate the per-request gate outcome into the stream's
        ``SchedulerStats`` tier ledger."""
        if self.config.tier0 is None:
            return
        stats.tier0_answered += st.tier0_answered
        stats.escalated += st.escalated
        budget = int(getattr(self.estimator, "max_new_tokens", 0) or 0)
        stats.tier0_decode_tokens_saved += st.tier0_answered * budget

    def _fold_drift_stats(self, stats) -> None:
        """Snapshot the drift ledger into a stream's ``SchedulerStats``.

        Pure snapshot, no accumulation: the monitor owns the monotonic
        counters.  Without a monitor only ``hot_swaps`` is stamped (the
        counter exists monitor or not) and the rest stay at their zero
        defaults, so a detector-off stream's ``as_dict()["drift"]`` block
        matches a detector-on stream that never alarmed on everything but
        the buffer bookkeeping.
        """
        stats.hot_swaps = self._hot_swaps
        m = self.monitor
        if m is None:
            return
        stats.drift_alarms = m.alarms
        stats.models_quarantined = len(m.drifted)
        stats.replay_buffer_len = len(m.buffer)
        p50, p95 = m.residual_percentiles()
        stats.drift_residual_p50 = p50
        stats.drift_residual_p95 = p95

    def _finalize(self, st: "_PredictState", batch, *,
                  put_cache: bool = True) -> PoolPredictions:
        """Scatter fresh estimator rows over the cache-probe columns and
        assemble the ``PoolPredictions`` (identical math for batch and
        stream paths).  ``put_cache=False`` when the caller already wrote
        the entries (the stream path puts per microbatch)."""
        cfg = self.config
        Q, M = len(st.queries), len(st.models)
        if Q == 0 or M == 0:
            return self._empty_pool(st.models, Q)
        if len(batch) != len(st.prompts):
            raise RuntimeError(
                f"estimator returned {len(batch)} predictions for "
                f"{len(st.prompts)} prompts")
        missing = st.missing
        y_hat, len_hat, wf = st.y_hat, st.len_hat, st.wf
        p_conf, prompt_tok = st.p_conf, st.prompt_tok
        overhead = np.zeros((Q, M))
        if len(missing):
            mq, mm = missing[:, 0], missing[:, 1]
            plens = np.fromiter((len(p) for p in st.prompts), int,
                                count=len(st.prompts))
            y_hat[mq, mm] = batch.y_hat
            len_hat[mq, mm] = batch.len_hat
            wf[mq, mm] = batch.well_formed
            p_conf[mq, mm] = batch.p_conf
            prompt_tok[mq, mm] = plens
            if st.status is not None:
                st.status[mq, mm] = batch.status
            # cached pairs spend no new estimator tokens on this call
            overhead[mq, mm] = batch.pred_tokens
            if st.use_cache and put_cache:
                entries = [CachedPrediction(
                    y_hat=int(batch.y_hat[i]),
                    len_hat=float(batch.len_hat[i]),
                    well_formed=bool(batch.well_formed[i]),
                    p_conf=float(batch.p_conf[i]),
                    pred_tokens=int(batch.pred_tokens[i]),
                    prompt_tokens=int(plens[i]),
                    status=int(batch.status[i]))
                    for i in range(len(missing))]
                self.cache.put_many(
                    [(st.qkeys[qi], st.models[mi], cfg.estimator_version)
                     for qi, mi in missing], entries)

        # quarantine stamping: a drifted model's *presented* status drops
        # OK pairs to DRIFTED so policies and reports see the quarantine,
        # while the stored cache entries stay truthful (demote_model
        # rewrote them once at alarm time; post-refresh OK writes heal
        # them).  An empty drifted set touches nothing — detector-on
        # serving stays bit-identical to detector-off without a fault.
        if (self.monitor is not None and self.monitor.drifted
                and st.status is not None):
            for mi, m in enumerate(st.models):
                if m in self.monitor.drifted:
                    col = st.status[:, mi]
                    st.status[:, mi] = np.where(
                        col == STATUS_OK, STATUS_DRIFTED, col)

        lh = np.where(wf, len_hat, FALLBACK_LEN_HAT)
        price_in = np.asarray([self.registry.meta(m).price_in
                               for m in st.models])
        price_out = np.asarray([self.registry.meta(m).price_out
                                for m in st.models])
        # actual serialized prompt length, not a flat constant (Eq. 24)
        cost_hat = (prompt_tok * price_in[None] + lh * price_out[None]) / 1e6
        p_hat = p_conf if cfg.use_confidence else y_hat.astype(float)
        return PoolPredictions(st.models, p_hat, y_hat, lh, cost_hat, wf,
                               overhead, st.sims, st.idx,
                               cache_hits=int(st.hit.sum()),
                               cache_misses=len(missing),
                               status=st.status,
                               tier0_answered=st.tier0_answered,
                               escalated=st.escalated)

    def predict(self, request: RouteRequest, *,
                rng: Optional[jax.Array] = None,
                use_cache: Optional[bool] = None) -> PoolPredictions:
        """Pool-wide pre-hoc estimates; estimator runs on cache misses only.

        The default pool is ``registry.routable()`` — a model staged with
        ``add_model`` but not yet fingerprinted is excluded rather than
        failing the whole batch; naming it in ``request.models`` raises.
        """
        if use_cache is None:
            use_cache = self.config.enable_cache
        st = self._prepare(request, use_cache)
        batch = self._run_estimator(st.prompts, rng)
        return self._finalize(st, batch)

    # -- streaming prediction ------------------------------------------
    def _dispatch_microbatch(self, mb, rng):
        """Launch one microbatch: non-blocking handle for estimators with
        ``dispatch_batch`` (overlapped execution); a finished
        ``ParsedBatch`` for duck-typed object-list estimators."""
        dispatch = getattr(self.estimator, "dispatch_batch", None)
        if dispatch is not None:
            return dispatch(mb.tokens, prompt_lens=mb.lengths, rng=rng)
        return self._run_estimator(mb.tokens, rng)

    def _stream_fill(self, inflight, use_cache, control=None):
        """Parse consumer shared by the stream paths: scatter one parse
        group's rows into every waiting request (duplicates ride the first
        waiter's generation at zero extra tokens) and write the cache per
        group — the moment generations parse, before the owning request
        drains.

        ``pop(key, None)``: a parsed key may have no waiters left — its
        request was already answered degraded (a deadline expiry or an
        abort racing the in-flight decode).  The late full result still
        reaches the cache, healing the provisional degraded entry, and the
        unconditional pop guarantees the dedup map never retains a key
        past its resolution, whichever path resolved it.
        """
        def fill(tags, batch):
            keys, entries = [], []
            for row, key in enumerate(tags):
                waiters = inflight.pop(key, None)
                if control is not None:
                    control.note_resolved(key)
                if waiters:
                    for j, (entry, miss_i) in enumerate(waiters):
                        entry.fill(miss_i, batch, row, shared=j > 0)
                if use_cache:
                    if waiters:                         # true token spend
                        owner, miss_i = waiters[0]
                        n_prompt = len(owner.state.prompts[miss_i])
                    else:                               # late heal
                        n_prompt = (control.prompt_tokens(key)
                                    if control is not None else 0)
                    keys.append(key)
                    entries.append(CachedPrediction(
                        y_hat=int(batch.y_hat[row]),
                        len_hat=float(batch.len_hat[row]),
                        well_formed=bool(batch.well_formed[row]),
                        p_conf=float(batch.p_conf[row]),
                        pred_tokens=int(batch.pred_tokens[row]),
                        prompt_tokens=n_prompt,
                        status=int(batch.status[row])))
            if keys:
                self.cache.put_many(keys, entries)
        return fill

    def _submit_misses(self, st, entry, sched, inflight, use_cache,
                       serial: int, control=None) -> int:
        """Queue a request's missing (query, model) prompts; a pair whose
        key duplicates one still in flight shares that generation instead
        of being scheduled again."""
        for miss_i, prompt in enumerate(st.prompts):
            qi, mi = st.missing[miss_i]
            key = (st.qkeys[qi], st.models[mi], self.config.estimator_version)
            if use_cache and key in inflight:
                inflight[key].append((entry, miss_i))
                continue
            if not use_cache:           # uncached: never share work
                key, serial = ("uncached", serial), serial + 1
            inflight[key] = [(entry, miss_i)]
            if control is not None:
                control.note_submit(key, prompt)
                if st.t0_rows is not None:
                    # versioned stash: a hot_swap mid-stream must not let
                    # degrade() serve a fallback the *old* head computed
                    control.t0_rows[key] = (self.config.estimator_version,
                                            st.t0_rows[miss_i])
            sched.submit(key, prompt)
        return serial

    def predict_stream(self, requests: Iterable[RouteRequest], *,
                       scheduler: Optional["MicrobatchScheduler"] = None,
                       rng: Optional[jax.Array] = None,
                       use_cache: Optional[bool] = None,
                       overlap: bool = True,
                       refill: Optional[bool] = None,
                       segment_len: Optional[int] = None,
                       max_pending: Optional[int] = None
                       ) -> Iterator[PoolPredictions]:
        """Drain an iterator of requests through the continuous-batching
        serve runtime.

        Yields one ``PoolPredictions`` per request, in arrival order, with
        the exact semantics of ``predict``: per-request ``get_many`` cache
        probes, estimator work for the misses only, per-request
        ``put_many`` on completion.  The difference is *how* the estimator
        runs: miss prompts from all in-flight requests are assembled into
        fixed-shape bucket microbatches (see ``serving.scheduler``) — so
        ragged traffic reuses a handful of compiled executables and small
        ticks ride along with large ones — and each microbatch is
        **double-buffer dispatched** through a ``ServeRuntime``
        (``overlap=True``): batch N+1's host assembly (cache probe,
        serialization, packing) runs while N's device decode is in flight,
        and the host blocks only at parse time.  Parses stay in dispatch
        (FIFO) order, so overlap changes when the host blocks, never what
        it observes; ``overlap=False`` restores the fully synchronous
        loop.  Under greedy decoding the yielded predictions match
        ``predict`` on the same queries — bit-for-bit when the microbatch
        shapes match the one-shot batch (the CI smoke gate), token- and
        decision-identical with confidences to f32 ulp otherwise (XLA
        reduction order varies with batch shape).

        The scheduler's deadline/occupancy knobs (``max_queue_age`` /
        ``min_fill``) are honored on every request arrival via ``tick()``:
        a latency-sensitive prompt rides out in a partially-filled bucket
        instead of waiting for a full one.  A request is emitted once all
        its missing pairs are resolved; partially-filled buckets are
        flushed when the input iterator is exhausted, so every submitted
        request is always answered.  A pair whose (query, model)
        duplicates one still in flight (a hot query repeated across ticks,
        probed before the first tick's microbatch parsed into the cache)
        is not scheduled again: it shares the in-flight generation and,
        like a cache hit, spends no new estimator tokens.  Cache writes
        happen per microbatch — the moment a bucket's generations are
        parsed — so later requests hit entries from microbatches parsed
        before they arrived, even while the owning request is still
        FIFO-blocked from emitting.

        ``max_pending`` sets the pipelining depth of the runtime (how many
        dispatched microbatches may be in flight before the oldest is
        block-parsed): ``None`` defaults to ``EngineConfig.max_pending``,
        then to 1 when ``overlap`` else 0.  Depths > 1 interleave batch
        N+1's prefill with batch N's decode — worth measuring on real
        accelerators; on a single shared CPU device two in-flight
        executables contend.

        ``refill=True`` (default ``EngineConfig.refill``) switches to
        **segment-chunked continuous batching**: decode runs in
        ``segment_len``-step scan segments over a fixed slot batch, and
        between segments rows that drained at EOS (or exhausted their
        budget) are parsed from their own window of the decode buffer and
        their slot refilled with the oldest queued prompt
        (``scheduler.pop_one``) — a row that finishes early admits the
        next request instead of idling until the batch retires.  All
        cache/dedup semantics above are preserved; under greedy decoding
        refill-on and refill-off streams make identical routing decisions
        (token-derived fields bit-equal, confidences to f32 ulp).

        Refill-mode latency caveat: while a slot state is live, queued
        prompts are admitted at segment cadence via ``pop_one`` — usually
        *sooner* than a deadline flush — but the scheduler's
        ``max_queue_age``/``min_fill`` knobs and full-bucket emission are
        only consulted between states, so a prompt that cannot ride the
        live state (wider than its slots, or all slots busy) waits up to
        the remaining refill horizon before a new bucket opens.  With
        ``EngineConfig.kv_paged`` the horizon ceiling does not exist: the
        slot cache is block-paged (``serving.kv_pool``), admission gates
        on free pool pages, and a state serves requests indefinitely —
        the wait collapses to "until a slot drains and pages free up".
        """
        from repro.serving.runtime import ServeRuntime
        from repro.serving.scheduler import MicrobatchScheduler
        cfg = self.config
        if use_cache is None:
            use_cache = cfg.enable_cache
        if refill is None:
            refill = cfg.refill
        if cfg.kv_paged and not refill:
            raise ValueError(
                "kv_paged requires the refill serve path (the whole-retire "
                "runtime keeps dense per-microbatch caches) — set "
                "EngineConfig.refill=True or pass refill=True")
        sched = scheduler if scheduler is not None else MicrobatchScheduler()
        if refill:
            yield from self._predict_stream_refill(
                requests, sched, rng=rng, use_cache=use_cache,
                segment_len=(cfg.segment_len if segment_len is None
                             else int(segment_len)))
            return
        if max_pending is None:
            max_pending = cfg.max_pending
        if max_pending is None:
            max_pending = 1 if overlap else 0
        pending: Deque[_StreamEntry] = deque()
        # (query_key, model, version) -> waiters; the first waiter's prompt
        # is the one scheduled, later duplicates ride along
        inflight: Dict[Tuple, List[Tuple[_StreamEntry, int]]] = {}
        control = _StreamControl(self, sched, inflight, use_cache)
        fill = self._stream_fill(inflight, use_cache, control)
        serial = 0                          # unique keys for uncached pairs
        # decode-slot occupancy: whole-retire runs every bucket the full
        # budget; pad rows and post-EOS steps idle (duck-typed estimators
        # have no token budget — counters stay zero)
        budget = int(getattr(self.estimator, "max_new_tokens", 0) or 0)

        def on_parsed(mb, batch):
            batch = control.corrupt(batch)
            fill(mb.tags, batch)
            if budget:
                sched.stats.slot_steps_total += mb.tokens.shape[0] * budget
                sched.stats.slot_steps_active += int(
                    batch.pred_tokens[: mb.n_real].sum())

        def dispatch_fn(mb):
            control.pre_dispatch()
            return self._dispatch_microbatch(mb, rng)

        runtime = ServeRuntime(
            dispatch_fn, on_parsed=on_parsed, max_pending=max_pending,
            on_failed=control.on_failed_mb)

        def drain_completed():
            while pending and pending[0].remaining == 0:
                entry = pending.popleft()
                yield self._finalize(entry.state, entry.parsed(),
                                     put_cache=False)

        with runtime:
            for request in requests:
                st = self._prepare(request, use_cache)
                self._fold_tier_stats(sched.stats, st)
                entry = _StreamEntry(st)
                pending.append(entry)
                serial = self._submit_misses(st, entry, sched, inflight,
                                             use_cache, serial, control)
                runtime.dispatch(sched.tick())
                runtime.poll()              # free parses: device already done
                control.expire()
                yield from drain_completed()
            # shutdown drains until the retry machinery settles: a failed
            # microbatch requeues its rows mid-flush, so flush + parse
            # until both the queue and the pipeline are empty (bounded by
            # max_retries — every key ends parsed or quarantined)
            while len(sched) or len(runtime):
                runtime.dispatch(sched.flush())
                runtime.finish()
                control.expire()
            sched.stats.injected_faults = control.injector.fired
        yield from drain_completed()
        assert not pending, "stream ended with unresolved requests"

    def _predict_stream_refill(self, requests: Iterable[RouteRequest],
                               sched, *, rng, use_cache: bool,
                               segment_len: int
                               ) -> Iterator[PoolPredictions]:
        """Segment-chunked continuous batching (see ``predict_stream``).

        One decode state is live at a time (device work is serialized
        anyway); whole microbatches open a state, and between segments
        drained slots pull single requests off the scheduler queue.  One
        segment advances per request arrival, so admission interleaves
        with traffic; at stream end the loop drains until every slot
        retires.  A queued prompt wider than the live state's slots waits
        for that state to retire and then opens its own.
        """
        from repro.serving.runtime import SlotRuntime
        est = self.estimator
        open_slots = getattr(est, "open_slots", None)
        if open_slots is None:
            raise TypeError(
                "refill streaming requires an estimator with open_slots() "
                f"(ReasoningEstimator); {type(est).__name__} lacks it — "
                "stream with refill=False instead")
        cfg = self.config

        def open_base(tokens, **kw):
            # resolved per state-open, not per stream: a hot_swap between
            # segments binds the *new* estimator's params to the next
            # opened state, while the live state's slots finish on the old
            # params they closed over — the swap lands at a segment
            # boundary with exactly-once and FIFO untouched
            return self.estimator.open_slots(tokens, **kw)

        open_fn = open_base
        if cfg.kv_paged:
            if cfg.refill_horizon is not None:
                raise ValueError(
                    "kv_paged and refill_horizon are mutually exclusive: "
                    "paged admission is gated on free pool pages, not a "
                    "slot horizon")
            from repro.kernels.decode_attention import KernelType
            from repro.serving.kv_pool import KVPool
            kernel = {"xla": KernelType.XLA,
                      "pallas": KernelType.PALLAS}.get(cfg.kv_kernel.lower())
            if kernel is None:
                raise ValueError(f"unknown kv_kernel {cfg.kv_kernel!r} "
                                 "(expected 'xla' or 'pallas')")
            page = int(cfg.kv_page_size)
            shared = (None if cfg.kv_pool_pages is None
                      else KVPool(n_pages=int(cfg.kv_pool_pages),
                                  page_size=page))

            def open_fn(tokens, **kw):
                if shared is not None:
                    pool = shared
                else:
                    # auto-size: the opening bucket's dense worst case —
                    # paged still wins whenever rows finish early or the
                    # run outlives one horizon.  Budget read per open so a
                    # hot-swapped estimator sizes its own pools.
                    budget = int(getattr(self.estimator, "max_new_tokens",
                                         0) or 0)
                    budget_steps = -(-budget // segment_len) * segment_len
                    b, width = np.asarray(tokens).shape
                    pool = KVPool(
                        n_pages=b * (-(-(width + budget_steps) // page)),
                        page_size=page)
                return open_base(tokens, kv_pool=pool, kv_kernel=kernel,
                                 **kw)

        pending: Deque[_StreamEntry] = deque()
        inflight: Dict[Tuple, List[Tuple[_StreamEntry, int]]] = {}
        control = _StreamControl(self, sched, inflight, use_cache)
        fill = self._stream_fill(inflight, use_cache, control)

        def on_parsed(tags, batch):
            fill(tags, control.corrupt(batch))

        runtime = SlotRuntime(open_fn, sched, segment_len=segment_len,
                              on_parsed=on_parsed,
                              horizon=cfg.refill_horizon, rng=rng,
                              injector=control.injector,
                              on_failed=control.on_failed)
        serial = 0

        def drain_completed():
            while pending and pending[0].remaining == 0:
                entry = pending.popleft()
                yield self._finalize(entry.state, entry.parsed(),
                                     put_cache=False)

        for request in requests:
            st = self._prepare(request, use_cache)
            self._fold_tier_stats(sched.stats, st)
            entry = _StreamEntry(st)
            pending.append(entry)
            serial = self._submit_misses(st, entry, sched, inflight,
                                         use_cache, serial, control)
            runtime.pump(final=False)
            control.expire()
            yield from drain_completed()
        runtime.pump(final=True)
        control.expire()
        # deadline expiry between pumps may strand nothing, but a late
        # requeue can: drain until the queue and the slot state settle
        while len(sched) or len(runtime):
            runtime.pump(final=True)
            control.expire()
        sched.stats.injected_faults = control.injector.fired
        yield from drain_completed()
        assert not pending, "stream ended with unresolved requests"

    def serve_stream(self, data: ScopeData, qid_stream: Iterable[Sequence[int]],
                     policy: RoutingPolicy, *,
                     models: Optional[Sequence[str]] = None,
                     scheduler: Optional["MicrobatchScheduler"] = None,
                     rng: Optional[jax.Array] = None,
                     use_cache: Optional[bool] = None,
                     overlap: bool = True,
                     refill: Optional[bool] = None,
                     segment_len: Optional[int] = None,
                     max_pending: Optional[int] = None
                     ) -> Iterator[BatchReport]:
        """Streaming ``serve``: one executed ``BatchReport`` per qid tick.

        ``qid_stream`` yields batches of query ids (one traffic tick each);
        prediction flows through ``predict_stream``'s bucketed scheduler
        (including its ``refill``/``segment_len``/``max_pending`` runtime
        knobs), then each tick is decided by ``policy`` and executed
        against the world exactly like ``serve``.
        """
        pool_models = (list(models) if models is not None
                       else self.registry.routable())
        ticks: Deque[List[int]] = deque()

        def as_requests():
            for qids in qid_stream:
                tick = [int(q) for q in qids]
                ticks.append(tick)
                yield RouteRequest([data.queries[q] for q in tick],
                                   models=pool_models)

        for pool in self.predict_stream(as_requests(), scheduler=scheduler,
                                        rng=rng, use_cache=use_cache,
                                        overlap=overlap, refill=refill,
                                        segment_len=segment_len,
                                        max_pending=max_pending):
            qids = ticks.popleft()
            if not qids:
                yield BatchReport.empty(policy.name, pool_models)
                continue
            decision = policy.decide(pool, self)
            report = self.execute(data, qids, pool, decision, policy.name)
            if scheduler is not None:
                # executed outcomes just landed — snapshot the drift
                # ledger so every yielded tick's stats are current
                self._fold_drift_stats(scheduler.stats)
            yield report

    def _run_estimator(self, prompts, rng: Optional[jax.Array]):
        """Columnar estimator call on token lists or a (b, L) int array;
        object-list estimators (duck-typed stand-ins) are adapted through
        ``ParsedBatch.from_predictions``."""
        from repro.core.estimator import ParsedBatch
        if len(prompts) == 0:
            return ParsedBatch.empty()
        predict_batch = getattr(self.estimator, "predict_batch", None)
        if predict_batch is not None:
            return predict_batch(prompts, rng=rng)
        return ParsedBatch.from_predictions(
            self.estimator.predict(prompts, rng=rng))

    # -- decision math (Eq. 15, shared by policies) --------------------
    def utilities(self, pool: PoolPredictions, alpha: float, *,
                  with_calibration: bool = True) -> np.ndarray:
        """Final decision scores (Eq. 15) for each (query, model)."""
        cfg = self.config
        wc = (utility.w_cal(alpha, w_base=cfg.w_base)
              if with_calibration else 0.0)
        # per-query (row-wise) cost bounds, whole batch at once
        c_norm = utility.normalize_cost(pool.cost_hat, axis=1)
        u_pred = utility.predicted_utility(
            pool.p_hat, c_norm, alpha, gamma_base=cfg.gamma_base,
            beta=cfg.beta)
        if with_calibration and wc > 0.0:
            fps = {m: self.library.get(m) for m in pool.models}
            u_cal = calibration.calibration_utilities_batch(
                fps, pool.models, pool.idx, pool.sims, alpha,
                gamma_base=cfg.gamma_base, beta=cfg.beta)
        else:
            u_cal = np.zeros_like(u_pred)
        return (1.0 - wc) * u_pred + wc * u_cal

    def affine_scores(self, pool: PoolPredictions
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(p_hat, s_hat) for the affine Prop. D.1 search (Eq. 17)."""
        c_norm = utility.normalize_cost(pool.cost_hat, axis=1)
        s_hat = utility.cost_score(c_norm, 1.0,
                                   gamma_base=self.config.gamma_base,
                                   beta=0.0)
        return pool.p_hat, s_hat

    def decide(self, pool: PoolPredictions, policy: RoutingPolicy
               ) -> PolicyDecision:
        return policy.decide(pool, self)

    def _assemble(self, policy_name: str, decision: PolicyDecision,
                  pool: PoolPredictions, query_ids: Sequence[int], *,
                  accuracy: float, total_cost: float, exec_tokens: int,
                  executed: bool, extra_info: Optional[Dict] = None
                  ) -> BatchReport:
        """Shared per-query decision list + batch accounting."""
        choices = np.asarray(decision.choices, int)
        decisions = [
            RouteDecision(query_id=int(q), model=pool.models[int(c)],
                          alpha=decision.alpha,
                          p_hat=float(pool.p_hat[i, c]),
                          cost_hat=float(pool.cost_hat[i, c]),
                          status=("OK" if pool.status is None else
                                  status_name(int(pool.status[i, c]))))
            for i, (q, c) in enumerate(zip(query_ids, choices, strict=True))]
        share = {m: 0 for m in pool.models}
        for d in decisions:
            share[d.model] += 1
        info = dict(decision.info, **(extra_info or {}))
        if pool.status is not None and pool.degraded_fraction > 0.0:
            info["degraded_fraction"] = round(pool.degraded_fraction, 4)
        return BatchReport(
            policy=policy_name, alpha=decision.alpha, decisions=decisions,
            accuracy=accuracy, total_cost=total_cost,
            exec_tokens=exec_tokens,
            overhead_tokens=int(pool.pred_overhead.sum()),
            per_model_share={m: v / len(decisions) for m, v in share.items()},
            cache_hits=pool.cache_hits, cache_misses=pool.cache_misses,
            executed=executed, info=info)

    # -- routing verbs -------------------------------------------------
    def route(self, request: RouteRequest, policy: RoutingPolicy, *,
              rng: Optional[jax.Array] = None,
              use_cache: Optional[bool] = None) -> BatchReport:
        """Decide without executing; accuracy/cost are *expected* values."""
        models = (list(request.models) if request.models is not None
                  else self.registry.routable())
        if len(request.queries) == 0:
            return BatchReport.empty(policy.name, models)
        pool = self.predict(request, rng=rng, use_cache=use_cache)
        decision = policy.decide(pool, self)
        choices = np.asarray(decision.choices, int)
        rows = np.arange(len(choices))
        return self._assemble(
            policy.name, decision, pool, [q.qid for q in request.queries],
            accuracy=float(np.mean(pool.p_hat[rows, choices])),
            total_cost=float(np.sum(pool.cost_hat[rows, choices])),
            exec_tokens=0, executed=False, extra_info={"expected": True})

    def serve(self, data: ScopeData, qids: Sequence[int],
              policy: RoutingPolicy, *, models: Optional[Sequence[str]] = None,
              rng: Optional[jax.Array] = None,
              use_cache: Optional[bool] = None) -> BatchReport:
        """Route and execute against the world; realized accuracy/cost."""
        qids = [int(q) for q in qids]
        pool_models = (list(models) if models is not None
                       else self.registry.routable())
        if not qids:
            return BatchReport.empty(policy.name, pool_models)
        queries = [data.queries[q] for q in qids]
        pool = self.predict(RouteRequest(queries, models=pool_models),
                            rng=rng, use_cache=use_cache)
        decision = policy.decide(pool, self)
        return self.execute(data, qids, pool, decision, policy.name)

    def execute(self, data: ScopeData, qids: Sequence[int],
                pool: PoolPredictions, decision: PolicyDecision,
                policy_name: str = "policy") -> BatchReport:
        """Run the chosen models against the world and account the batch."""
        qids = [int(q) for q in qids]
        if not qids:
            return BatchReport.empty(policy_name, pool.models)
        choices = np.asarray(decision.choices, int)
        monitor = self.monitor
        accs, costs, tokens = [], [], 0
        for i, (q, c) in enumerate(zip(qids, choices, strict=True)):
            model = pool.models[int(c)]
            rec = data.record(q, model)
            # one outcome-observation event per served pair: an armed
            # model_drift fault degrades the *realized* outcome (the
            # deployed model genuinely got worse — accounting sees it too);
            # with no plan this is a dict probe, bit-identical to before
            y, tok_i, cost = self._outcome_injector.corrupt_outcome(
                model, rec.y, rec.tokens, rec.cost)
            accs.append(y)
            costs.append(cost)
            tokens += tok_i
            if monitor is not None:
                from repro.serving.feedback import Outcome
                newly = monitor.observe(Outcome(
                    query_id=query_key(data.queries[q]), model=model,
                    predicted_p=float(pool.p_hat[i, int(c)]),
                    predicted_cost=float(pool.cost_hat[i, int(c)]),
                    observed_y=float(y), observed_cost=float(cost),
                    observed_tokens=int(tok_i),
                    sims=pool.sims[i], idx=pool.idx[i],
                    well_formed=bool(pool.well_formed[i, int(c)])))
                if newly is not None:
                    # new alarm: demote the model's cached predictions so
                    # later probes surface DRIFTED until a refresh heals
                    self.cache.demote_model(newly)
        return self._assemble(
            policy_name, decision, pool, qids,
            accuracy=float(np.mean(accs)), total_cost=float(np.sum(costs)),
            exec_tokens=int(tokens), executed=True)
