"""``ScopeEngine`` — the single public entry point for SCOPE routing.

The engine owns the four components the paper's pipeline needs at serve time
(reasoning estimator, anchor retriever, fingerprint library, model pool) and
exposes the routing surface as four verbs:

  predict  — cache-aware pool-wide pre-hoc estimation (Eq. 5, Eq. 24)
  route    — apply a ``RoutingPolicy`` to a request, report expected metrics
  serve    — route + execute against a ``ScopeData`` world, report realized
  onboard  — training-free pool growth (fingerprint pass, §3.1)

``predict`` consults the ``PredictionCache`` keyed by
``(query_id, model, estimator_version)`` and runs the estimator only for the
missing (query, model) pairs, so onboarding a model onto an already-served
query set costs O(Q) new estimator calls instead of an O(Q x M) recompute.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.api.cache import (
    CachedBatch, CachedPrediction, CacheStats, PredictionCache, query_key)
from repro.api.policy import PolicyDecision, RoutingPolicy
from repro.api.registry import PoolRegistry
from repro.api.types import (
    BatchReport, EngineConfig, RouteDecision, RouteRequest)
from repro.core import calibration, serialization, utility
from repro.core.fingerprint import Fingerprint
from repro.core.router import PoolPredictions
from repro.data.datasets import ScopeData
from repro.data.worldsim import PoolModel, World

FALLBACK_LEN_HAT = 512.0    # tokens charged when the estimate is malformed


class ScopeEngine:
    def __init__(self, config: EngineConfig, registry: PoolRegistry,
                 cache: PredictionCache):
        self.config = config
        self.registry = registry
        self.cache = cache

    # -- construction --------------------------------------------------
    @classmethod
    def build(cls, config: EngineConfig) -> "ScopeEngine":
        """Validate an ``EngineConfig`` and wire the facade."""
        for field in ("estimator", "retriever", "library"):
            if getattr(config, field) is None:
                raise ValueError(f"EngineConfig.{field} is required")
        if config.registry is not None and config.models_meta is not None:
            raise ValueError(
                "pass either EngineConfig.registry or .models_meta, not both")
        registry = config.registry
        if registry is None:
            registry = PoolRegistry(config.library, config.models_meta)
        elif registry.library is not config.library:
            raise ValueError("registry.library and config.library differ")
        return cls(config, registry, PredictionCache(config.cache_capacity))

    # -- owned components ----------------------------------------------
    @property
    def estimator(self):
        return self.config.estimator

    @property
    def retriever(self):
        return self.config.retriever

    @property
    def library(self):
        return self.config.library

    def set_estimator(self, estimator, version: str) -> None:
        """Swap estimator weights; the version bump keys a fresh cache space."""
        self.config.estimator = estimator
        self.config.estimator_version = version

    # -- pool lifecycle ------------------------------------------------
    def onboard(self, world: World, name: str, *, seed: int = 0,
                meta: Optional[PoolModel] = None,
                refresh: bool = False) -> Fingerprint:
        """Training-free: register + one fingerprint pass, no weight update.

        ``refresh=True`` re-fingerprints an already-known model and drops
        its cached predictions (they were computed from the old fingerprint).
        """
        fp = self.registry.onboard(world, name, seed=seed, meta=meta,
                                   refresh=refresh)
        if refresh:
            self.cache.invalidate_model(name)
        return fp

    def remove_model(self, name: str) -> None:
        self.registry.remove_model(name)
        self.cache.invalidate_model(name)

    # -- prediction ----------------------------------------------------
    def predict(self, request: RouteRequest, *,
                rng: Optional[jax.Array] = None,
                use_cache: Optional[bool] = None) -> PoolPredictions:
        """Pool-wide pre-hoc estimates; estimator runs on cache misses only.

        The default pool is ``registry.routable()`` — a model staged with
        ``add_model`` but not yet fingerprinted is excluded rather than
        failing the whole batch; naming it in ``request.models`` raises.
        """
        cfg = self.config
        if use_cache is None:
            use_cache = cfg.enable_cache
        models = (list(request.models) if request.models is not None
                  else self.registry.routable())
        queries = list(request.queries)
        Q, M = len(queries), len(models)
        if Q == 0 or M == 0:
            return PoolPredictions(
                models, np.zeros((Q, M)), np.zeros((Q, M), int),
                np.zeros((Q, M)), np.zeros((Q, M)), np.zeros((Q, M), bool),
                np.zeros((Q, M)), np.zeros((Q, cfg.k)),
                np.zeros((Q, cfg.k), int))
        for m in models:
            if m not in self.registry:
                raise KeyError(f"model {m!r} is not registered; "
                               "PoolRegistry.add_model/onboard it first")
            if m not in self.library:
                raise KeyError(f"model {m!r} has no fingerprint; "
                               "PoolRegistry.onboard it first")

        embs = request.query_embs
        if embs is None:
            embs = np.stack([q.embedding for q in queries])
        sims, idx = self.retriever.retrieve(embs, cfg.k)

        # -- batched cache probe: one pass per model column ------------
        version = cfg.estimator_version
        qkeys = [query_key(q) for q in queries]
        before = self.cache.stats.snapshot()
        hit = np.zeros((Q, M), bool)
        y_hat = np.zeros((Q, M), int)
        len_hat = np.zeros((Q, M))
        wf = np.zeros((Q, M), bool)
        p_conf = np.zeros((Q, M))
        prompt_tok = np.zeros((Q, M))
        if use_cache:
            for mi, m in enumerate(models):
                col: CachedBatch = self.cache.get_many(qkeys, m, version)
                hit[:, mi] = col.mask
                y_hat[:, mi] = col.y_hat
                len_hat[:, mi] = col.len_hat
                wf[:, mi] = col.well_formed
                p_conf[:, mi] = col.p_conf
                prompt_tok[:, mi] = col.prompt_tokens

        # -- estimator pass for the missing pairs ----------------------
        missing = np.argwhere(~hit)                     # (n, 2) row-major
        prompts: List[List[int]] = []
        for qi, mi in missing:
            m = models[mi]
            prompts.append(serialization.serialize_prompt(
                self.registry.meta(m), self.registry.index(m),
                self.library.anchor_set, self.library.get(m),
                sims[qi], idx[qi], queries[qi]))
        batch = self._run_estimator(prompts, rng)
        if len(batch) != len(prompts):
            raise RuntimeError(
                f"estimator returned {len(batch)} predictions for "
                f"{len(prompts)} prompts")

        # -- columnar assembly: scatter fresh rows, no per-pair loops --
        overhead = np.zeros((Q, M))
        if len(missing):
            mq, mm = missing[:, 0], missing[:, 1]
            plens = np.fromiter((len(p) for p in prompts), int,
                                count=len(prompts))
            y_hat[mq, mm] = batch.y_hat
            len_hat[mq, mm] = batch.len_hat
            wf[mq, mm] = batch.well_formed
            p_conf[mq, mm] = batch.p_conf
            prompt_tok[mq, mm] = plens
            # cached pairs spend no new estimator tokens on this call
            overhead[mq, mm] = batch.pred_tokens
            if use_cache:
                entries = [CachedPrediction(
                    y_hat=int(batch.y_hat[i]),
                    len_hat=float(batch.len_hat[i]),
                    well_formed=bool(batch.well_formed[i]),
                    p_conf=float(batch.p_conf[i]),
                    pred_tokens=int(batch.pred_tokens[i]),
                    prompt_tokens=int(plens[i]))
                    for i in range(len(missing))]
                self.cache.put_many(
                    [(qkeys[qi], models[mi], version) for qi, mi in missing],
                    entries)

        lh = np.where(wf, len_hat, FALLBACK_LEN_HAT)
        price_in = np.asarray([self.registry.meta(m).price_in
                               for m in models])
        price_out = np.asarray([self.registry.meta(m).price_out
                                for m in models])
        # actual serialized prompt length, not a flat constant (Eq. 24)
        cost_hat = (prompt_tok * price_in[None] + lh * price_out[None]) / 1e6
        p_hat = p_conf if cfg.use_confidence else y_hat.astype(float)
        if use_cache:
            delta = self.cache.stats.delta(before)
        else:
            delta = CacheStats(misses=len(missing))
        return PoolPredictions(models, p_hat, y_hat, lh, cost_hat, wf,
                               overhead, sims, idx,
                               cache_hits=delta.hits,
                               cache_misses=delta.misses)

    def _run_estimator(self, prompts: List[List[int]],
                       rng: Optional[jax.Array]):
        """Columnar estimator call; object-list estimators (duck-typed
        stand-ins) are adapted through ``ParsedBatch.from_predictions``."""
        from repro.core.estimator import ParsedBatch
        if not prompts:
            return ParsedBatch.empty()
        predict_batch = getattr(self.estimator, "predict_batch", None)
        if predict_batch is not None:
            return predict_batch(prompts, rng=rng)
        return ParsedBatch.from_predictions(
            self.estimator.predict(prompts, rng=rng))

    # -- decision math (Eq. 15, shared by policies) --------------------
    def utilities(self, pool: PoolPredictions, alpha: float, *,
                  with_calibration: bool = True) -> np.ndarray:
        """Final decision scores (Eq. 15) for each (query, model)."""
        cfg = self.config
        wc = (utility.w_cal(alpha, w_base=cfg.w_base)
              if with_calibration else 0.0)
        # per-query (row-wise) cost bounds, whole batch at once
        c_norm = utility.normalize_cost(pool.cost_hat, axis=1)
        u_pred = utility.predicted_utility(
            pool.p_hat, c_norm, alpha, gamma_base=cfg.gamma_base,
            beta=cfg.beta)
        if with_calibration and wc > 0.0:
            fps = {m: self.library.get(m) for m in pool.models}
            u_cal = calibration.calibration_utilities_batch(
                fps, pool.models, pool.idx, pool.sims, alpha,
                gamma_base=cfg.gamma_base, beta=cfg.beta)
        else:
            u_cal = np.zeros_like(u_pred)
        return (1.0 - wc) * u_pred + wc * u_cal

    def affine_scores(self, pool: PoolPredictions
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(p_hat, s_hat) for the affine Prop. D.1 search (Eq. 17)."""
        c_norm = utility.normalize_cost(pool.cost_hat, axis=1)
        s_hat = utility.cost_score(c_norm, 1.0,
                                   gamma_base=self.config.gamma_base,
                                   beta=0.0)
        return pool.p_hat, s_hat

    def decide(self, pool: PoolPredictions, policy: RoutingPolicy
               ) -> PolicyDecision:
        return policy.decide(pool, self)

    def _assemble(self, policy_name: str, decision: PolicyDecision,
                  pool: PoolPredictions, query_ids: Sequence[int], *,
                  accuracy: float, total_cost: float, exec_tokens: int,
                  executed: bool, extra_info: Optional[Dict] = None
                  ) -> BatchReport:
        """Shared per-query decision list + batch accounting."""
        choices = np.asarray(decision.choices, int)
        decisions = [
            RouteDecision(query_id=int(q), model=pool.models[int(c)],
                          alpha=decision.alpha,
                          p_hat=float(pool.p_hat[i, c]),
                          cost_hat=float(pool.cost_hat[i, c]))
            for i, (q, c) in enumerate(zip(query_ids, choices))]
        share = {m: 0 for m in pool.models}
        for d in decisions:
            share[d.model] += 1
        return BatchReport(
            policy=policy_name, alpha=decision.alpha, decisions=decisions,
            accuracy=accuracy, total_cost=total_cost,
            exec_tokens=exec_tokens,
            overhead_tokens=int(pool.pred_overhead.sum()),
            per_model_share={m: v / len(decisions) for m, v in share.items()},
            cache_hits=pool.cache_hits, cache_misses=pool.cache_misses,
            executed=executed, info=dict(decision.info, **(extra_info or {})))

    # -- routing verbs -------------------------------------------------
    def route(self, request: RouteRequest, policy: RoutingPolicy, *,
              rng: Optional[jax.Array] = None,
              use_cache: Optional[bool] = None) -> BatchReport:
        """Decide without executing; accuracy/cost are *expected* values."""
        models = (list(request.models) if request.models is not None
                  else self.registry.routable())
        if len(request.queries) == 0:
            return BatchReport.empty(policy.name, models)
        pool = self.predict(request, rng=rng, use_cache=use_cache)
        decision = policy.decide(pool, self)
        choices = np.asarray(decision.choices, int)
        rows = np.arange(len(choices))
        return self._assemble(
            policy.name, decision, pool, [q.qid for q in request.queries],
            accuracy=float(np.mean(pool.p_hat[rows, choices])),
            total_cost=float(np.sum(pool.cost_hat[rows, choices])),
            exec_tokens=0, executed=False, extra_info={"expected": True})

    def serve(self, data: ScopeData, qids: Sequence[int],
              policy: RoutingPolicy, *, models: Optional[Sequence[str]] = None,
              rng: Optional[jax.Array] = None,
              use_cache: Optional[bool] = None) -> BatchReport:
        """Route and execute against the world; realized accuracy/cost."""
        qids = [int(q) for q in qids]
        pool_models = (list(models) if models is not None
                       else self.registry.routable())
        if not qids:
            return BatchReport.empty(policy.name, pool_models)
        queries = [data.queries[q] for q in qids]
        pool = self.predict(RouteRequest(queries, models=pool_models),
                            rng=rng, use_cache=use_cache)
        decision = policy.decide(pool, self)
        return self.execute(data, qids, pool, decision, policy.name)

    def execute(self, data: ScopeData, qids: Sequence[int],
                pool: PoolPredictions, decision: PolicyDecision,
                policy_name: str = "policy") -> BatchReport:
        """Run the chosen models against the world and account the batch."""
        qids = [int(q) for q in qids]
        if not qids:
            return BatchReport.empty(policy_name, pool.models)
        choices = np.asarray(decision.choices, int)
        accs, costs, tokens = [], [], 0
        for q, c in zip(qids, choices):
            rec = data.record(q, pool.models[int(c)])
            accs.append(rec.y)
            costs.append(rec.cost)
            tokens += rec.tokens
        return self._assemble(
            policy_name, decision, pool, qids,
            accuracy=float(np.mean(accs)), total_cost=float(np.sum(costs)),
            exec_tokens=int(tokens), executed=True)
