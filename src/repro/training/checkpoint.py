"""Pytree checkpointing: flattened key-path .npz archives (no pickle)."""
from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import numpy as np

_SEP = "|"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    return f"x:{p}"


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def load(path: str, template: Any) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path_t, leaf in leaves_t:
        key = _SEP.join(_path_str(p) for p in path_t)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        out.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)
