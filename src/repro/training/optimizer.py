"""Pure-JAX optimizers: AdamW with gradient clipping and LR schedules."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Dict
    nu: Dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"          # cosine | constant


def lr_at(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params
                 ) -> Tuple[Dict, AdamWState]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
