"""Stage 1: SFT via hindsight distillation (SCOPE §4.3).

The (programmatic) teacher is conditioned on realized outcomes (y, l) and
emits a concise grounded rationale + the structured prediction; the student
LM trains with next-token prediction on the generated suffix only.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import serialization
from repro.core.fingerprint import FingerprintLibrary
from repro.core.retrieval import AnchorRetriever
from repro.data.datasets import ScopeData
from repro.data.pipeline import batches, make_lm_batch
from repro.models import model as M
from repro.training.optimizer import (
    AdamWConfig, AdamWState, adamw_init, adamw_update)


def build_sft_dataset(data: ScopeData, library: FingerprintLibrary,
                      retriever: AnchorRetriever, *, k: int = 5,
                      cot: bool = True, max_examples: Optional[int] = None,
                      qids: Optional[Sequence[int]] = None,
                      seed: int = 0) -> Dict[str, np.ndarray]:
    """(query, model) pairs -> serialized prompt + hindsight target."""
    world = data.world
    qids = list(qids if qids is not None else data.train_qids)
    rng = np.random.default_rng(seed)
    model_indices = {m: i for i, m in enumerate(data.models)}

    embs = np.stack([world.embed(data.queries[q]) for q in qids])
    sims, idx = retriever.retrieve(embs, k)

    prompts: List[List[int]] = []
    targets: List[List[int]] = []
    pairs = [(qi, m) for qi in range(len(qids)) for m in data.models]
    rng.shuffle(pairs)
    if max_examples is not None:
        pairs = pairs[:max_examples]
    for qi, m in pairs:
        q = data.queries[qids[qi]]
        rec = data.record(q.qid, m)
        fp = library.get(m)
        p, t = serialization.build_sft_example(
            world.models[m], model_indices[m], library.anchor_set, fp,
            sims[qi], idx[qi], q, rec.y, rec.tokens, cot=cot)
        prompts.append(p)
        targets.append(t)
    max_len = max(len(p) + len(t) for p, t in zip(prompts, targets, strict=True))
    return make_lm_batch(prompts, targets, max_len)


@functools.partial(jax.jit, static_argnums=(1, 4))
def sft_step(params, cfg: ModelConfig, opt_state: AdamWState, batch,
             opt_cfg: AdamWConfig):
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
    params, opt_state = adamw_update(opt_cfg, grads, opt_state, params)
    return params, opt_state, loss, metrics


def train_sft(params, cfg: ModelConfig, dataset: Dict[str, np.ndarray], *,
              steps: int = 300, batch_size: int = 64,
              opt_cfg: Optional[AdamWConfig] = None, seed: int = 0,
              log_every: int = 50, verbose: bool = False):
    opt_cfg = opt_cfg or AdamWConfig(lr=1e-3, warmup_steps=20,
                                     total_steps=steps)
    opt_state = adamw_init(params)
    losses = []
    it = None
    done = 0
    epoch = 0
    while done < steps:
        for batch in batches(dataset, batch_size, seed=seed + epoch):
            params, opt_state, loss, _ = sft_step(params, cfg, opt_state,
                                                  batch, opt_cfg)
            losses.append(float(loss))
            done += 1
            if verbose and done % log_every == 0:
                print(f"  sft step {done}: loss {np.mean(losses[-log_every:]):.4f}")
            if done >= steps:
                break
        epoch += 1
    return params, losses
