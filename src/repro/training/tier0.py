"""Distillation of the tier-0 pre-router head (two-tier routing).

The teacher is the trained reasoning estimator: for each (query, model)
pair we serialize the same prompt the serve path would, run
``predict_batch``, and distill the *parsed* outputs — the calibrated
correctness probability ``p_conf`` as a soft BCE target and the
``len_bucket`` of ``len_hat`` as a masked cross-entropy target (malformed
teacher rows supervise only the correctness head).  After training, the
correctness logit is temperature-scaled on a held-out split (grid-search
NLL) so ``max(p, 1-p)`` is a real escalation signal, not a raw margin.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import serialization
from repro.core.fingerprint import FingerprintLibrary
from repro.core.retrieval import AnchorRetriever
from repro.data import tokenizer as tok
from repro.data.datasets import ScopeData
from repro.models import tier0 as T0
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

LEN_LOSS_WEIGHT = 0.5


def build_tier0_dataset(data: ScopeData, library: FingerprintLibrary,
                        retriever: AnchorRetriever, estimator, *,
                        k: int = 5, qids: Optional[Sequence[int]] = None,
                        max_pairs: Optional[int] = None,
                        rng: Optional[jax.Array] = None,
                        seed: int = 0) -> Dict[str, np.ndarray]:
    """Teacher-labelled feature set over (train query, model) pairs.

    Returns columnar arrays: the head inputs (``qf``/``af``/``mf``/``mid``,
    see ``models.tier0.pair_features``) plus the distillation targets —
    ``q`` (teacher ``p_conf``), ``len_lb`` (teacher length bucket) and
    ``wf`` (teacher row parsed well-formed; gates the length loss).
    """
    world = data.world
    qids = list(qids if qids is not None else data.train_qids)
    shuffle = np.random.default_rng(seed)
    model_indices = {m: i for i, m in enumerate(data.models)}

    embs = np.stack([world.embed(data.queries[q]) for q in qids])
    sims, idx = retriever.retrieve(embs, k)

    pairs = [(qi, m) for qi in range(len(qids)) for m in data.models]
    shuffle.shuffle(pairs)
    if max_pairs is not None:
        pairs = pairs[:max_pairs]

    prompts, feats = [], []
    for qi, m in pairs:
        q = data.queries[qids[qi]]
        fp = library.get(m)
        args = (world.models[m], model_indices[m], library.anchor_set, fp,
                sims[qi], idx[qi], q)
        prompts.append(serialization.serialize_prompt(*args))
        feats.append(T0.pair_features(*args))

    batch = estimator.predict_batch(prompts, rng=rng)
    return {
        "qf": np.stack([f[0] for f in feats]),
        "af": np.stack([f[1] for f in feats]),
        "mf": np.stack([f[2] for f in feats]),
        "mid": np.asarray([f[3] for f in feats], np.int32),
        "q": np.asarray(batch.p_conf, np.float32),
        "len_lb": np.asarray([tok.len_bucket(t) for t in batch.len_hat],
                             np.int32),
        "wf": np.asarray(batch.well_formed, bool),
    }


def _tier0_loss(params, batch):
    p_logit, len_logits = T0.tier0_forward(
        params, batch["qf"], batch["af"], batch["mf"], batch["mid"])
    q = batch["q"]
    # soft-label BCE: softplus(x) - q*x == -[q log s(x) + (1-q) log(1-s(x))]
    bce = jnp.mean(jax.nn.softplus(p_logit) - q * p_logit)
    logp = jax.nn.log_softmax(len_logits, axis=-1)
    picked = jnp.take_along_axis(logp, batch["len_lb"][:, None],
                                 axis=-1)[:, 0]
    mask = batch["wf"].astype(jnp.float32)
    ce = -jnp.sum(picked * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return bce + LEN_LOSS_WEIGHT * ce


@functools.partial(jax.jit, static_argnums=(3,))
def tier0_step(params, opt_state, batch, opt_cfg: AdamWConfig):
    loss, grads = jax.value_and_grad(_tier0_loss)(params, batch)
    params, opt_state = adamw_update(opt_cfg, grads, opt_state, params)
    return params, opt_state, loss


def fit_temperature(p_logit: np.ndarray, q: np.ndarray,
                    temps: Optional[np.ndarray] = None) -> float:
    """Grid-search the calibration temperature minimizing held-out BCE
    NLL of ``sigmoid(p_logit / T)`` against the teacher's ``q``."""
    if len(p_logit) == 0:
        return 1.0
    if temps is None:
        temps = np.geomspace(0.25, 4.0, 25)
    x = np.asarray(p_logit, np.float64)[None, :] / \
        np.asarray(temps, np.float64)[:, None]
    qq = np.asarray(q, np.float64)[None, :]
    nll = np.mean(np.logaddexp(0.0, x) - qq * x, axis=1)
    return float(temps[int(np.argmin(nll))])


def recalibrate_tier0(head: T0.Tier0Head, p_pred: np.ndarray,
                      y_obs: np.ndarray) -> T0.Tier0Head:
    """Re-temper a trained head against *observed* outcomes (the drift
    hot-swap path: the replay buffer holds the head's served probabilities
    and what the world actually returned).

    The head's raw logit is recovered by inverting its current
    calibration, ``raw = T * logit(p)``, then ``fit_temperature`` re-fits
    on the observed labels — no weight update, parameters are shared with
    the input head (``with_temperature`` keeps the pytree, so the swap
    stages no new executables).
    """
    p = np.clip(np.asarray(p_pred, np.float64), 1e-6, 1.0 - 1e-6)
    raw = head.temperature * np.log(p / (1.0 - p))
    return head.with_temperature(
        fit_temperature(raw, np.asarray(y_obs, np.float64)))


@dataclasses.dataclass
class DistillReport:
    losses: list
    temperature: float
    n_train: int
    n_val: int


def train_tier0(dataset: Dict[str, np.ndarray], *,
                cfg: T0.Tier0Config = T0.Tier0Config(),
                steps: int = 300, batch_size: int = 256,
                opt_cfg: Optional[AdamWConfig] = None, seed: int = 0,
                val_frac: float = 0.1) -> Tuple[T0.Tier0Head, DistillReport]:
    """Fit the head on ``dataset`` and temperature-calibrate on a held-out
    tail split.  Minibatches are sampled with replacement at a fixed
    ``batch_size`` so every step reuses one compiled executable."""
    n = len(dataset["q"])
    if n == 0:
        raise ValueError("empty tier-0 dataset")
    n_val = min(max(1, int(n * val_frac)), n - 1) if n > 1 else 0
    n_train = n - n_val
    train = {k: v[:n_train] for k, v in dataset.items()}
    val = {k: v[n_train:] for k, v in dataset.items()}

    params = T0.init_tier0(jax.random.PRNGKey(seed), cfg)
    opt_cfg = opt_cfg or AdamWConfig(lr=3e-3, warmup_steps=20,
                                     total_steps=steps)
    opt_state = adamw_init(params)
    shuffle = np.random.default_rng(seed)
    bs = min(batch_size, n_train)
    losses = []
    for _ in range(steps):
        take = shuffle.integers(0, n_train, size=bs)
        mb = {k: v[take] for k, v in train.items()}
        params, opt_state, loss = tier0_step(params, opt_state, mb, opt_cfg)
        losses.append(float(loss))

    head = T0.Tier0Head(params, cfg)
    if n_val:
        logit, _ = head.forward_raw(val["qf"], val["af"], val["mf"],
                                    val["mid"])
        head = head.with_temperature(fit_temperature(logit, val["q"]))
    return head, DistillReport(losses=losses, temperature=head.temperature,
                               n_train=n_train, n_val=n_val)


def distill_tier0(data: ScopeData, library: FingerprintLibrary,
                  retriever: AnchorRetriever, estimator, *,
                  k: int = 5, qids: Optional[Sequence[int]] = None,
                  max_pairs: Optional[int] = None,
                  cfg: T0.Tier0Config = T0.Tier0Config(),
                  steps: int = 300, batch_size: int = 256,
                  opt_cfg: Optional[AdamWConfig] = None,
                  seed: int = 0) -> T0.Tier0Head:
    """End-to-end: teacher labels from the reasoning estimator, head fit,
    temperature calibration — returns an engine-ready ``Tier0Head``."""
    dataset = build_tier0_dataset(
        data, library, retriever, estimator, k=k, qids=qids,
        max_pairs=max_pairs, seed=seed)
    head, _ = train_tier0(dataset, cfg=cfg, steps=steps,
                          batch_size=batch_size, opt_cfg=opt_cfg, seed=seed)
    return head
