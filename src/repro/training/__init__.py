"""Training substrate: AdamW, SFT (hindsight distillation), GRPO,
checkpointing."""
from repro.training import checkpoint, grpo, optimizer, sft  # noqa: F401
