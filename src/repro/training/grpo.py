"""Stage 2: GRPO alignment (SCOPE §4.3, Eq. 6; GRPO per Shao et al. 2024).

Per task (query, model): sample a group of G rollouts from the current
policy, score them with the gated composite reward (format gate x
(R_corr + R_token with adaptive tolerance)), normalize advantages within
the group, and apply a token-level PPO-clip policy gradient with a k3 KL
penalty toward the SFT reference policy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import rewards as rw
from repro.core import serialization
from repro.core.fingerprint import FingerprintLibrary
from repro.core.retrieval import AnchorRetriever
from repro.data import tokenizer as tok
from repro.data.datasets import ScopeData
from repro.models import model as M
from repro.serving import sampler
from repro.training.optimizer import (
    AdamWConfig, AdamWState, adamw_init, adamw_update)


@dataclasses.dataclass
class GRPOConfig:
    group_size: int = 4
    tasks_per_step: int = 16
    temperature: float = 1.0
    clip_eps: float = 0.2
    kl_coef: float = 0.02
    max_new_tokens: int = 12
    inner_epochs: int = 1


# ---------------------------------------------------------------------------
# Token-level log-probs of a generated suffix
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnums=(1,))
def sequence_logprobs(params, cfg: ModelConfig, tokens, gen_mask):
    """tokens: (B, L) prompt+generation; gen_mask marks generated positions.
    Returns per-position logp of tokens[t] for masked t (shifted)."""
    logits, _ = M.forward_train(params, cfg, {"tokens": tokens})
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    # position t-1 predicts token t
    tgt = tokens[:, 1:]
    lp = jnp.take_along_axis(logp[:, :-1], tgt[..., None], axis=-1)[..., 0]
    mask = gen_mask[:, 1:].astype(jnp.float32)
    return lp * mask, mask


def grpo_loss(params, cfg: ModelConfig, batch, clip_eps: float,
              kl_coef: float):
    lp, mask = sequence_logprobs(params, cfg, batch["tokens"],
                                 batch["gen_mask"])
    old_lp = batch["old_logp"]
    ref_lp = batch["ref_logp"]
    adv = batch["adv"][:, None]

    ratio = jnp.exp(lp - old_lp)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * adv
    pg = -jnp.minimum(unclipped, clipped)

    # k3 KL estimator toward the reference policy
    delta = ref_lp - lp
    kl = jnp.exp(delta) - delta - 1.0

    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum((pg + kl_coef * kl) * mask) / denom
    return loss, {"pg": jnp.sum(pg * mask) / denom,
                  "kl": jnp.sum(kl * mask) / denom}


@functools.partial(jax.jit, static_argnums=(1, 4, 5, 6))
def grpo_step(params, cfg: ModelConfig, opt_state, batch,
              opt_cfg: AdamWConfig, clip_eps: float, kl_coef: float):
    (loss, metrics), grads = jax.value_and_grad(
        grpo_loss, has_aux=True)(params, cfg, batch, clip_eps, kl_coef)
    params, opt_state = adamw_update(opt_cfg, grads, opt_state, params)
    return params, opt_state, loss, metrics


# ---------------------------------------------------------------------------
# Rollout + training loop
# ---------------------------------------------------------------------------
class GRPOTrainer:
    def __init__(self, cfg: ModelConfig, params, data: ScopeData,
                 library: FingerprintLibrary, retriever: AnchorRetriever, *,
                 gcfg: Optional[GRPOConfig] = None,
                 opt_cfg: Optional[AdamWConfig] = None,
                 k: int = 5, cot: bool = True, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.ref_params = jax.tree.map(jnp.copy, params)
        self.data = data
        self.library = library
        self.retriever = retriever
        self.gcfg = gcfg or GRPOConfig()
        self.opt_cfg = opt_cfg or AdamWConfig(lr=2e-4, warmup_steps=10,
                                              total_steps=1000)
        self.opt_state = adamw_init(params)
        self.k = k
        self.cot = cot
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.PRNGKey(seed)
        self.model_indices = {m: i for i, m in enumerate(data.models)}
        self.reward_history: List[float] = []

    # ------------------------------------------------------------------
    def _sample_tasks(self, n: int):
        qids = self.rng.choice(self.data.train_qids, size=n)
        models = self.rng.choice(self.data.models, size=n)
        return list(zip(qids.tolist(), models.tolist(), strict=True))

    def _build_prompts(self, tasks):
        world = self.data.world
        embs = np.stack([world.embed(self.data.queries[q]) for q, _ in tasks])
        sims, idx = self.retriever.retrieve(embs, self.k)
        prompts, gts = [], []
        for t, (qid, m) in enumerate(tasks):
            q = self.data.queries[qid]
            rec = self.data.record(qid, m)
            fp = self.library.get(m)
            prompts.append(serialization.serialize_prompt(
                world.models[m], self.model_indices[m],
                self.library.anchor_set, fp, sims[t], idx[t], q))
            gts.append((rec.y, rec.tokens))
        return prompts, gts

    # ------------------------------------------------------------------
    def rollout_step(self) -> Dict:
        g = self.gcfg.group_size
        tasks = self._sample_tasks(self.gcfg.tasks_per_step)
        prompts, gts = self._build_prompts(tasks)
        lp_len = len(prompts[0])

        # tile each prompt G times → one batched generation pass
        tiled = np.repeat(np.asarray(prompts, np.int32), g, axis=0)
        self.key, sub = jax.random.split(self.key)
        gen, _ = sampler.generate(
            self.params, self.cfg, tiled,
            max_new_tokens=self.gcfg.max_new_tokens,
            temperature=self.gcfg.temperature, rng=sub)

        B = len(tiled)
        L = lp_len + self.gcfg.max_new_tokens
        tokens = np.concatenate([tiled, gen], axis=1)
        gen_mask = np.zeros((B, L), np.float32)
        rewards = np.zeros(B, np.float32)
        well_formed = np.zeros(B, bool)
        for i in range(B):
            y_gt, len_gt = gts[i // g]
            toks = [int(t) for t in gen[i]]
            parsed = tok.parse_prediction(toks)
            well_formed[i] = bool(parsed.get("well_formed", False))
            rewards[i] = rw.grpo_reward(parsed, y_gt, len_gt)
            # mask: generated positions up to & including EOS (or all)
            upto = toks.index(tok.EOS) + 1 if tok.EOS in toks else len(toks)
            gen_mask[i, lp_len: lp_len + upto] = 1.0

        # group-normalized advantages
        r = rewards.reshape(-1, g)
        adv = (r - r.mean(axis=1, keepdims=True)) / (r.std(axis=1, keepdims=True) + 1e-6)
        adv = adv.reshape(-1)

        jt = jnp.asarray(tokens)
        jm = jnp.asarray(gen_mask)
        old_lp, _ = sequence_logprobs(self.params, self.cfg, jt, jm)
        ref_lp, _ = sequence_logprobs(self.ref_params, self.cfg, jt, jm)
        batch = {"tokens": jt, "gen_mask": jm,
                 "old_logp": jax.lax.stop_gradient(old_lp),
                 "ref_logp": jax.lax.stop_gradient(ref_lp),
                 "adv": jnp.asarray(adv)}

        for _ in range(self.gcfg.inner_epochs):
            self.params, self.opt_state, loss, metrics = grpo_step(
                self.params, self.cfg, self.opt_state, batch, self.opt_cfg,
                self.gcfg.clip_eps, self.gcfg.kl_coef)
        mean_r = float(rewards.mean())
        self.reward_history.append(mean_r)
        # the actual gate pass rate — NOT np.mean(rewards > 0), which
        # miscounts well-formed rollouts whose composite reward is zero
        return {"reward": mean_r, "loss": float(loss),
                "kl": float(metrics["kl"]),
                "format_rate": float(np.mean(well_formed))}

    def train(self, steps: int, *, verbose: bool = False,
              log_every: int = 10) -> List[float]:
        for s in range(steps):
            info = self.rollout_step()
            if verbose and (s + 1) % log_every == 0:
                print(f"  grpo step {s+1}: reward {info['reward']:.3f} "
                      f"kl {info['kl']:.4f}")
        return self.reward_history
