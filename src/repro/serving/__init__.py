"""Serving substrate: KV-cache sampler, batched engine, microbatch
scheduler, and the continuous-batching serve runtime.

The routing entry point is ``repro.api.ScopeEngine``; ``scheduler`` turns
ragged request streams into fixed-shape bucket microbatches (with
deadline/occupancy flushing), ``runtime.ServeRuntime`` double-buffers
their dispatch so host assembly overlaps device decode, and
``runtime.SlotRuntime`` chunks decode into scan segments and refills
drained-at-EOS slots from the queue mid-batch.
"""
from repro.serving import engine, runtime, sampler, scheduler  # noqa: F401
