"""Serving substrate: KV-cache sampler, batched engine, microbatch scheduler.

The routing entry point is ``repro.api.ScopeEngine``; ``scheduler`` turns
ragged request streams into fixed-shape bucket microbatches for the fused
serve hot path.
"""
from repro.serving import engine, sampler, scheduler  # noqa: F401
