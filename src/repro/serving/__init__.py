"""Serving substrate: KV-cache sampler, batched engine, router service.

The routing entry point is ``repro.api.ScopeEngine``; ``router_service``
keeps the legacy ``RouterService`` shim on top of it.
"""
from repro.serving import engine, router_service, sampler  # noqa: F401
