"""Serving substrate: KV-cache sampler, batched engine, microbatch
scheduler, and the continuous-batching serve runtime.

The routing entry point is ``repro.api.ScopeEngine``; ``scheduler`` turns
ragged request streams into fixed-shape bucket microbatches (with
deadline/occupancy flushing) and ``runtime.ServeRuntime`` double-buffers
their dispatch so host assembly overlaps device decode.
"""
from repro.serving import engine, runtime, sampler, scheduler  # noqa: F401
