"""Serving substrate: KV-cache sampler, batched engine, router service."""
from repro.serving import engine, sampler  # noqa: F401
