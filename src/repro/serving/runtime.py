"""Double-buffered microbatch execution for the streaming serve path.

``ServeRuntime`` separates *dispatch* (launch a microbatch's device work)
from *parse* (block on the results and hand them to the consumer), so host
assembly of microbatch N+1 — cache probes, prompt serialization, scheduler
packing — runs while N's prefill + decode scan is still in flight on the
device.  ``jax`` dispatch is asynchronous; the only forced host sync is
``np.asarray`` at parse time, which the runtime defers until either

  * capacity: ``max_pending`` batches are already in flight (the oldest is
    parsed to make room — ``max_pending=1`` is classic double buffering,
    ``max_pending=0`` is the synchronous pre-runtime behavior), or
  * opportunity: ``poll()`` parses any batch whose device buffers report
    ready (``jax.Array.is_ready``), keeping time-to-first-decision low, or
  * shutdown: ``finish()`` drains everything.

Parses always happen in dispatch (FIFO) order, so consumers observe the
exact event order of the synchronous loop — overlap changes *when* the
host blocks, never *what* it sees.

The runtime is estimator-agnostic: a dispatch function returning an object
with ``is_ready()``/``parse()`` (e.g. ``ReasoningEstimator.dispatch_batch``
handles) runs overlapped; one returning a finished ``ParsedBatch`` directly
(duck-typed test estimators) degrades to the synchronous path.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Deque, Iterable, Tuple

from repro.serving.scheduler import Microbatch


def _is_ready(handle: Any) -> bool:
    probe = getattr(handle, "is_ready", None)
    return True if probe is None else bool(probe())


def _parse(handle: Any) -> Any:
    parse = getattr(handle, "parse", None)
    return handle if parse is None else parse()


@dataclasses.dataclass
class RuntimeStats:
    dispatched: int = 0
    parsed: int = 0
    overlapped: int = 0      # parses that found the device already done
    max_in_flight: int = 0

    def as_dict(self):
        return dataclasses.asdict(self)


class ServeRuntime:
    """FIFO dispatch/parse pipeline over microbatches.

    ``dispatch_fn(mb)`` launches one microbatch and returns a handle (or a
    finished result); ``on_parsed(mb, result)`` consumes each parsed batch
    in dispatch order.
    """

    def __init__(self, dispatch_fn: Callable[[Microbatch], Any], *,
                 on_parsed: Callable[[Microbatch, Any], None],
                 max_pending: int = 1):
        if max_pending < 0:
            raise ValueError(f"max_pending must be >= 0, got {max_pending}")
        self._dispatch_fn = dispatch_fn
        self._on_parsed = on_parsed
        self.max_pending = max_pending
        self._inflight: Deque[Tuple[Microbatch, Any]] = deque()
        self.stats = RuntimeStats()

    def __len__(self) -> int:
        return len(self._inflight)

    def _parse_oldest(self) -> None:
        mb, handle = self._inflight.popleft()
        self.stats.overlapped += int(_is_ready(handle))
        self.stats.parsed += 1
        self._on_parsed(mb, _parse(handle))

    def dispatch(self, batches: Iterable[Microbatch]) -> None:
        """Launch each microbatch, blocking only when over capacity.

        Capacity is enforced **before** the new launch: with
        ``max_pending=1`` the oldest batch is parsed (blocking until its
        device work retires) and only then is the next one dispatched, so
        at most one executable runs at a time — the overlap is host
        assembly vs device decode, never two executables contending for
        the same compute.  ``max_pending=0`` parses immediately after
        dispatch (fully synchronous).
        """
        for mb in batches:
            while self._inflight and len(self._inflight) >= self.max_pending:
                self._parse_oldest()
            handle = self._dispatch_fn(mb)
            self._inflight.append((mb, handle))
            self.stats.dispatched += 1
            self.stats.max_in_flight = max(self.stats.max_in_flight,
                                           len(self._inflight))
            while len(self._inflight) > self.max_pending:
                self._parse_oldest()

    def poll(self) -> int:
        """Parse every leading in-flight batch whose device work is done
        (non-blocking); returns the number parsed."""
        n = 0
        while self._inflight and _is_ready(self._inflight[0][1]):
            self._parse_oldest()
            n += 1
        return n

    def finish(self) -> None:
        """Block-parse everything still in flight (stream shutdown)."""
        while self._inflight:
            self._parse_oldest()
