"""Microbatch execution runtimes for the streaming serve path.

``ServeRuntime`` separates *dispatch* (launch a microbatch's device work)
from *parse* (block on the results and hand them to the consumer), so host
assembly of microbatch N+1 — cache probes, prompt serialization, scheduler
packing — runs while N's prefill + decode scan is still in flight on the
device.  ``jax`` dispatch is asynchronous; the only forced host sync is
``np.asarray`` at parse time, which the runtime defers until either

  * capacity: ``max_pending`` batches are already in flight (the oldest is
    parsed to make room — ``max_pending=1`` is classic double buffering,
    ``max_pending=0`` is the synchronous pre-runtime behavior, and depths
    > 1 interleave batch N+1's prefill with batch N's decode, which pays
    on accelerators where the two phases occupy different units), or
  * opportunity: ``poll()`` parses any batch whose device buffers report
    ready (``jax.Array.is_ready``), keeping time-to-first-decision low, or
  * shutdown: ``finish()`` drains everything.

Parses always happen in dispatch (FIFO) order, so consumers observe the
exact event order of the synchronous loop — overlap changes *when* the
host blocks, never *what* it sees.

The runtime is estimator-agnostic: a dispatch function returning an object
with ``is_ready()``/``parse()`` (e.g. ``ReasoningEstimator.dispatch_batch``
handles) runs overlapped; one returning a finished ``ParsedBatch`` directly
(duck-typed test estimators) degrades to the synchronous path.

``SlotRuntime`` is the segment-chunked counterpart: instead of retiring
microbatches whole, it drives a live decode-slot state
(``ReasoningEstimator.open_slots`` -> ``SlotRun``) in fixed scan segments
and refills drained-at-EOS slots mid-batch from the scheduler queue.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Deque, Iterable, Optional, Tuple

from repro.serving.scheduler import Microbatch


def _is_ready(handle: Any) -> bool:
    probe = getattr(handle, "is_ready", None)
    return True if probe is None else bool(probe())


def _parse(handle: Any) -> Any:
    parse = getattr(handle, "parse", None)
    return handle if parse is None else parse()


@dataclasses.dataclass
class RuntimeStats:
    dispatched: int = 0
    parsed: int = 0
    overlapped: int = 0      # parses that found the device already done
    max_in_flight: int = 0
    failed: int = 0          # microbatches routed to on_failed

    def as_dict(self):
        return dataclasses.asdict(self)


class ServeRuntime:
    """FIFO dispatch/parse pipeline over microbatches.

    ``dispatch_fn(mb)`` launches one microbatch and returns a handle (or a
    finished result); ``on_parsed(mb, result)`` consumes each parsed batch
    in dispatch order.

    ``on_failed(mb, exc)``, when given, receives any microbatch whose
    dispatch or parse raised instead of the exception propagating — the
    engine's retry path requeues the batch's rows.  Without it every
    exception stays loud (the pre-fault-tolerance behavior).  The runtime
    is also a context manager: on clean exit it drains (``finish``), on
    error it ``abort``s, so an exception mid-stream can never leak an
    in-flight executable into the next stream.
    """

    def __init__(self, dispatch_fn: Callable[[Microbatch], Any], *,
                 on_parsed: Callable[[Microbatch, Any], None],
                 max_pending: int = 1,
                 on_failed: Optional[Callable[[Microbatch, Exception],
                                              None]] = None):
        if max_pending < 0:
            raise ValueError(f"max_pending must be >= 0, got {max_pending}")
        self._dispatch_fn = dispatch_fn
        self._on_parsed = on_parsed
        self._on_failed = on_failed
        self.max_pending = max_pending
        self._inflight: Deque[Tuple[Microbatch, Any]] = deque()
        self.stats = RuntimeStats()

    def __len__(self) -> int:
        return len(self._inflight)

    def _parse_oldest(self) -> None:
        mb, handle = self._inflight.popleft()
        ready = _is_ready(handle)
        try:
            result = _parse(handle)
        except Exception as exc:
            if self._on_failed is None:
                raise
            self.stats.failed += 1
            self._on_failed(mb, exc)
            return
        self.stats.overlapped += int(ready)
        self.stats.parsed += 1
        self._on_parsed(mb, result)

    def dispatch(self, batches: Iterable[Microbatch]) -> None:
        """Launch each microbatch, blocking only when over capacity.

        Capacity is enforced **before** the new launch: with
        ``max_pending=1`` the oldest batch is parsed (blocking until its
        device work retires) and only then is the next one dispatched, so
        at most one executable runs at a time — the overlap is host
        assembly vs device decode, never two executables contending for
        the same compute.  ``max_pending=0`` parses immediately after
        dispatch (fully synchronous).
        """
        for mb in batches:
            while self._inflight and len(self._inflight) >= self.max_pending:
                self._parse_oldest()
            try:
                handle = self._dispatch_fn(mb)
            except Exception as exc:
                if self._on_failed is None:
                    raise
                self.stats.failed += 1
                self._on_failed(mb, exc)
                continue
            self._inflight.append((mb, handle))
            self.stats.dispatched += 1
            self.stats.max_in_flight = max(self.stats.max_in_flight,
                                           len(self._inflight))
            while len(self._inflight) > self.max_pending:
                self._parse_oldest()

    def poll(self) -> int:
        """Parse every leading in-flight batch whose device work is done
        (non-blocking); returns the number parsed."""
        n = 0
        while self._inflight and _is_ready(self._inflight[0][1]):
            self._parse_oldest()
            n += 1
        return n

    def finish(self) -> None:
        """Block-parse everything still in flight (stream shutdown)."""
        while self._inflight:
            self._parse_oldest()

    def abort(self) -> int:
        """Drop every in-flight handle without parsing (error shutdown);
        returns how many were dropped.  The device work completes on its
        own and its buffers are released — nothing double-buffered
        survives into the caller's next stream."""
        n = len(self._inflight)
        self._inflight.clear()
        return n

    def close(self, *, drain: bool = True) -> None:
        """Shut the pipeline down: drain (parse) what is in flight, or
        abort it.  If draining itself raises, the remainder is aborted
        before the exception propagates, so close() never leaks handles."""
        if not drain:
            self.abort()
            return
        try:
            self.finish()
        except Exception:
            self.abort()
            raise

    def __enter__(self) -> "ServeRuntime":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close(drain=exc_type is None)
        return False


class SlotRuntime:
    """Segment-chunked continuous batching over decode slots.

    The refill counterpart of ``ServeRuntime``: one live slot state at a
    time (device work is serialized on one executable anyway).  Whole
    scheduler microbatches *open* a state via ``open_slots``; between scan
    segments, rows that drained at EOS (or exhausted their budget) hand
    their parse group to ``on_parsed`` and their slot admits the oldest
    queued prompt (``scheduler.pop_one``) — a row that finishes early
    serves the next request instead of idling until the batch retires.

    ``pump(final=False)`` advances **at most one segment** — the engine
    calls it per request arrival, so admission interleaves with traffic;
    ``pump(final=True)`` flushes the scheduler and drains until every slot
    retires.  A queued prompt wider than the live state's slots is never
    force-fit: it waits for that state to retire and then opens (or joins)
    its own microbatch.  Retired runs fold their decode-slot occupancy
    counters into ``scheduler.stats``.
    """

    def __init__(self, open_slots: Callable[..., Any], scheduler, *,
                 segment_len: int, on_parsed: Callable[[list, Any], None],
                 horizon: Optional[int] = None, rng: Any = None,
                 kv_pool: Any = None, kv_kernel: Any = None,
                 injector: Any = None,
                 on_failed: Optional[Callable[[list, Optional[Exception]],
                                              None]] = None):
        self._open_slots = open_slots
        self._sched = scheduler
        self._segment_len = int(segment_len)
        self._on_parsed = on_parsed
        self._horizon = horizon
        self._rng = rng
        self._kv_pool = kv_pool
        self._kv_kernel = kv_kernel
        self._injector = injector
        self._on_failed = on_failed
        self._open_queue: Deque[Microbatch] = deque()
        self._run: Any = None

    def __len__(self) -> int:
        """Requests currently occupying slots or awaiting a free state."""
        live = self._run.n_live if self._run is not None else 0
        return live + sum(mb.n_real for mb in self._open_queue)

    def _admit(self, run) -> None:
        """Pop queued prompts into the run's free slots (as many as fit).

        ``can_admit`` is re-checked per item — each paged admission draws
        down the pool, so the first one can succeed and the next defer.  A
        boundary that leaves a free slot idle while the queue holds work is
        *counted*, not silently swallowed: the deferral shows up in
        ``SchedulerStats`` under the resource it waited on (pool pages in
        paged mode, the slot horizon in dense mode).
        """
        items = []
        for _ in run.free_rows():
            if not run.can_admit():
                if self._sched.peek_one(run.width):
                    stats = self._sched.stats
                    if run.deferral_reason == "pages":
                        stats.admissions_deferred_on_pages += 1
                    else:
                        stats.admissions_deferred_on_horizon += 1
                break
            item = self._sched.pop_one(run.width)
            if item is None:
                break
            items.append(item)
        run.admit(items)

    def _fail_row(self, run, row: Optional[int]) -> None:
        """Row-level failure (KV pool exhaustion, real or injected): fail
        the row out of the state and route it to the retry path.  Without
        an ``on_failed`` route the loud pre-fault behavior is preserved —
        the stream still dies rather than silently dropping a request."""
        if row is None:
            return
        if self._on_failed is None:
            raise RuntimeError(
                f"kv pool exhausted for slot row {row} and no failure "
                "route is configured")
        failed = run.fail_row(row)
        if failed is not None:
            self._sched.stats.kv_exhausted_rows += 1
            self._on_failed([failed], None)

    def _launch(self, run) -> None:
        """Launch the next segment, first applying the boundary's fault
        checks: injected pool/segment faults and real page starvation
        (rows decoding past their reserved budget under a drained pool)
        fail at row or state granularity instead of inside the sampler."""
        inj = self._injector
        if inj is not None:
            inj.tick("stall")
            spec = inj.tick("pool")
            if spec is not None and run.paged:
                self._fail_row(run, run.pick_live_row(int(spec.arg)))
            spec = inj.tick("segment")
            if spec is not None:
                from repro.serving.faults import InjectedFault
                raise InjectedFault(
                    f"injected segment fault (event {spec.index})")
        for row in run.starved_rows():
            self._fail_row(run, row)
        if not run.finished:
            run.launch()

    def _recover(self, run, completed, exc: Exception) -> None:
        """Segment failure: deliver what completed before the fault, tear
        the state down, and hand the live rows to the retry path."""
        if self._on_failed is None:
            raise exc
        if completed:
            # rows sync() freed before the fault decoded fully — they
            # parse and deliver normally (exactly-once: they are not in
            # the abort set)
            self._on_parsed(*run.parse_completed(completed))
        failed = run.abort()
        run.account(self._sched.stats)
        self._run = None
        self._on_failed(failed, exc)

    def pump(self, final: bool = False) -> None:
        while True:
            if self._run is None:
                self._open_queue.extend(
                    self._sched.flush() if final else self._sched.tick())
                if not self._open_queue:
                    return
                mb = self._open_queue.popleft()
                kw = {}
                if self._kv_pool is not None:
                    kw = {"kv_pool": self._kv_pool,
                          "kv_kernel": self._kv_kernel}
                self._run = self._open_slots(
                    mb.tokens, lengths=mb.lengths, tags=mb.tags,
                    segment_len=self._segment_len, horizon=self._horizon,
                    rng=self._rng, **kw)
                # a partially-filled opening bucket's pad rows are free
                # slots: refill them before the first segment launches
                self._admit(self._run)
            run = self._run
            # launch the first segment of a fresh state, sync the
            # in-flight one, refill the slots it drained, and launch the
            # next segment BEFORE parsing — the host assembles results
            # (window parse, cache writes, request completion) while the
            # device decodes ahead
            completed = []
            try:
                if not run.in_flight:
                    self._launch(run)
                if run.in_flight:
                    completed = run.sync()
            except Exception as exc:
                self._recover(run, completed, exc)
                continue
            self._admit(run)
            try:
                if not run.finished:
                    self._launch(run)
            except Exception as exc:
                self._recover(run, completed, exc)
                continue
            if completed:
                self._on_parsed(*run.parse_completed(completed))
            if run.finished:
                run.account(self._sched.stats)
                self._run = None
                continue                # maybe open the next state
            if not final:
                return                  # one segment per arrival
