"""Deterministic fault injection for the serve stack (chaos testing).

A ``FaultPlan`` is an explicit list of ``FaultSpec``s, each naming a
*site* (which serve boundary) and an *event index* (the n-th time that
boundary is crossed).  A ``FaultInjector`` — one per stream — counts the
boundary crossings and fires the matching specs, so a plan replays
identically on identical traffic: no RNG is consulted at serve time.

Sites and their real boundaries:

  dispatch  — a whole-retire microbatch launch (``ServeRuntime``) raises
              ``InjectedFault`` instead of dispatching
  segment   — a slot-state segment launch (``SlotRuntime``) raises; the
              whole live state is torn down and its rows requeued
  parse     — a parse group returns garbage: every row is scrambled to a
              malformed generation (``well_formed=False``, ``p_conf=0.5``)
              and flows through the normal malformed-estimate machinery
  pool      — simulated ``KVPool`` exhaustion: the ``arg``-th live row of
              the current paged slot state takes a row-level failure at
              the segment boundary (pages released, row requeued)
  stall     — the injector's ``stall_offset`` clock jumps forward ``arg``
              seconds; only the engine's SLO-deadline check consults the
              offset, so queue-age statistics are unperturbed
  model_drift — the named ``model``'s observed outcomes are perturbed
              *persistently* from event index ``index`` on: once the spec
              fires, every later ``corrupt_outcome`` for that model forces
              the realized correctness to 0 and inflates the realized cost
              by ``1 + arg``.  Events are outcome observations (the
              engine's ``execute`` boundary), so "drifts at tick T" is
              "drifts at the K-th served query".  This is what the drift
              detector (``serving.feedback``) is tested against — a
              deployed model silently degrading mid-stream.

The **no-op default** (``FaultPlan.none()`` or no plan at all) must not
perturb the serve path: ``tick`` is a dict probe returning ``None`` and
``corrupt_parse`` returns the batch unchanged, so control flow, RNG
consumption, and every array shape are bit-identical to a build without
this module — the chaos smoke asserts exactly that.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

# model_drift is last: FaultPlan.seeded draws per site in tuple order, so
# appending keeps every older seeded plan's specs bit-identical
SITES = ("dispatch", "segment", "parse", "pool", "stall", "model_drift")


class InjectedFault(RuntimeError):
    """Raised at a serve boundary on behalf of a ``FaultSpec``."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned failure: the ``index``-th event at ``site`` fires.

    ``arg`` is site-specific: stall seconds for ``stall``, the live-row
    selector for ``pool``, the relative cost inflation for
    ``model_drift``, unused elsewhere.  ``model`` names the pool model a
    ``model_drift`` spec degrades (required there, unused elsewhere).
    """
    site: str
    index: int
    arg: float = 0.0
    model: str = ""

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} "
                             f"(expected one of {SITES})")
        if self.index < 0:
            raise ValueError(f"fault index must be >= 0, got {self.index}")
        if self.site == "model_drift" and not self.model:
            raise ValueError("model_drift specs must name a model")


class FaultPlan:
    """An immutable set of ``FaultSpec``s, indexed by (site, event index).

    At most one spec per (site, index) — later duplicates are rejected so
    a plan reads back exactly as written.
    """

    def __init__(self, specs: Iterable[FaultSpec] = ()):
        self._by_site: Dict[str, Dict[int, FaultSpec]] = {}
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        for spec in self.specs:
            site = self._by_site.setdefault(spec.site, {})
            if spec.index in site:
                raise ValueError(
                    f"duplicate fault at ({spec.site!r}, {spec.index})")
            site[spec.index] = spec

    @classmethod
    def none(cls) -> "FaultPlan":
        """The asserted-no-op default: nothing ever fires."""
        return cls()

    @classmethod
    def seeded(cls, seed: int, *, n_events: int = 64,
               rates: Optional[Dict[str, float]] = None,
               stall_s: float = 0.0) -> "FaultPlan":
        """Bernoulli plan: each of the first ``n_events`` events at a site
        fires with that site's rate.  Deterministic in ``seed`` — the draw
        happens here, never at serve time."""
        if (rates or {}).get("model_drift"):
            raise ValueError(
                "model_drift cannot be rate-drawn (a spec must name the "
                "drifting model); add FaultSpec('model_drift', K, "
                "model=...) to the plan explicitly")
        rng = np.random.default_rng(seed)  # scopelint: allow[serve-time-nondeterminism] -- build-time plan draw, deterministic in seed; serve time only replays it
        specs = []
        for site in SITES:                      # fixed draw order
            rate = float((rates or {}).get(site, 0.0))
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"rate for {site!r} must be in [0, 1], "
                                 f"got {rate}")
            hits = rng.random(n_events) < rate
            for i in np.flatnonzero(hits):
                arg = stall_s if site == "stall" else float(i)
                specs.append(FaultSpec(site, int(i), arg))
        return cls(specs)

    def get(self, site: str, index: int) -> Optional[FaultSpec]:
        return self._by_site.get(site, {}).get(index)

    def __bool__(self) -> bool:
        return bool(self.specs)

    def __repr__(self) -> str:
        return f"FaultPlan({list(self.specs)!r})"


class FaultInjector:
    """Per-stream event counters over a ``FaultPlan``.

    ``tick(site)`` advances that site's event counter and returns the
    firing spec (or ``None``); ``raise_if(site)`` is the raising variant
    for the sites whose failure mode is an exception.  ``stall_offset``
    accumulates the seconds injected by fired ``stall`` specs — the
    engine's deadline clock adds it to the scheduler's real clock.
    """

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan if plan is not None else FaultPlan.none()
        self.counts: Dict[str, int] = {site: 0 for site in SITES}
        self.fired = 0
        self.stall_offset = 0.0
        # model -> cost inflation arg; set when a model_drift spec fires
        # and persistent from then on (the deployed model stays degraded
        # until the pool heals it out of band)
        self.drift_active: Dict[str, float] = {}

    def tick(self, site: str) -> Optional[FaultSpec]:
        i = self.counts[site]
        self.counts[site] = i + 1
        spec = self.plan.get(site, i)
        if spec is not None:
            self.fired += 1
            if site == "stall":
                self.stall_offset += float(spec.arg)
            elif site == "model_drift":
                self.drift_active[spec.model] = float(spec.arg)
        return spec

    def raise_if(self, site: str) -> None:
        spec = self.tick(site)
        if spec is not None:
            raise InjectedFault(f"injected {site} fault (event {spec.index})")

    def corrupt_outcome(self, model: str, y, tokens: int, cost: float
                        ) -> Tuple[float, int, float]:
        """One outcome-observation event: tick the ``model_drift`` counter
        (arming any spec whose index this event reaches) and, if drift is
        active for ``model``, degrade the observation — correctness forced
        to 0, cost inflated by ``1 + arg``.  With no plan this is a dict
        probe and an untouched return: bit-identical to no injector."""
        self.tick("model_drift")
        arg = self.drift_active.get(model)
        if arg is None:
            return y, tokens, cost
        return 0.0, tokens, float(cost) * (1.0 + arg)

    def corrupt_parse(self, batch):
        """One parse event: if the matching spec fires, scramble every row
        of the group to a malformed estimate.  The garbage flows through
        the normal malformed-prediction machinery (``well_formed=False``
        charges the pessimistic length fallback) — tokens were genuinely
        spent, so ``pred_tokens`` is kept."""
        spec = self.tick("parse")
        if spec is None or len(batch) == 0:
            return batch
        n = len(batch)
        return dataclasses.replace(
            batch,
            y_hat=np.zeros(n, int),
            len_hat=np.zeros(n, np.float64),
            well_formed=np.zeros(n, bool),
            p_conf=np.full(n, 0.5, np.float64),
            rationale_len=np.zeros(n, int))
