"""Block-paged KV-cache pool: fixed-size pages, per-row page tables,
free-list allocation (no page sharing in v1).

Dense decode allocates every slot's worst case up front — KV memory is
O(slots x horizon) even when most rows drain at EOS after a handful of
tokens.  The pool converts that to O(live tokens): KV storage is a flat
array of ``n_pages`` fixed-size pages plus one **trash page**, and each
decode row owns a page table mapping its logical page index to a physical
page.  Pages are allocated on demand as positions advance (prompt pages at
admission, decode pages per segment) and released when the row retires at
EOS/parse, so a drained slot's memory is immediately reusable by the next
queued prompt — slot admission checks free pages, not remaining horizon.

Layout per attention layer-stack cache leaf:

  dense  k/v : (count, b, hkv, S, hd)                 S = max_len slots
  paged  k/v : (count, n_pages + 1, hkv, page, hd)    physical pages

A *page id* spans **all** layers: allocating page p grants the row
``page_size`` token slots in every layer's storage at physical index p.
Physical index ``n_pages`` is the trash page: unallocated table entries
and retired rows point there, so done rows keep scatter-decoding PAD
harmlessly (their writes land in trash, their reads are masked or
discarded) — exactly mirroring the dense path's discarded free-slot rows.

Deadlock freedom: ``admit_row`` *reserves* the row's worst-case page count
up front (``ceil(min(len + budget, kv_cap) / page)``) and draws the
physical pages down from that reservation as decode advances, so a row
admitted is a row that can always finish — mid-decode allocation can
never fail.  ``available()`` is what is left for *new* admissions.

The pool itself is host-side accounting (free list, reservations, page
counters); the device storage lives in the ``DecodeState`` it backs, like
the dense caches.  ``PagedKV`` is the per-state attachment pairing the
pool with one decode batch's page tables.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.decode_attention import KernelType


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def kv_bytes_per_token(cfg: ModelConfig) -> int:
    """KV bytes one cached token costs across every attention layer."""
    from repro.models import transformer as tf
    from repro.models.common import dtype_of

    itemsize = np.dtype(dtype_of(cfg.dtype)).itemsize
    per_layer = 2 * cfg.num_kv_heads * cfg.resolved_head_dim * itemsize
    layers = sum(1 for k in cfg.layer_kinds() if tf._is_attn(k))
    return per_layer * layers


def check_paged_support(cfg: ModelConfig) -> None:
    """Paged v1 covers plain-GQA attention stacks only.

    Every layer must be a full-window GQA attention block: MLA latents,
    SSM/conv states and encoder cross caches have no paged layout yet,
    and windowed ring buffers already cap their own memory at O(window).
    Loud failure beats silently decoding from the wrong cache lines.
    """
    from repro.models import transformer as tf
    from repro.models.attention import resolve_window

    if cfg.is_encoder_decoder:
        raise ValueError(
            f"paged KV requires a decoder-only model: {cfg.name!r} carries "
            "encoder cross caches")
    for kind in cfg.layer_kinds():
        kk = "attn" if kind == "shared_attn" else kind
        if not tf._is_attn(kk) or tf._is_mla(kk):
            raise ValueError(
                "paged KV requires an attention-only GQA backbone: "
                f"{cfg.name!r} has a {kind!r} layer (SSM/MLA states have "
                "no paged layout)")
        if resolve_window(cfg, kk) > 0:
            raise ValueError(
                "paged KV does not support sliding-window layers: "
                f"{cfg.name!r} layer kind {kind!r} resolves a window — "
                "ring buffers already bound their memory at O(window)")


class PagedSpec(NamedTuple):
    """Static (hashable) half of the paged layout, closed into the jitted
    decode executables; the page table itself is a traced argument."""
    page_size: int
    kv_cap: int                     # per-row logical capacity in tokens
    kernel: KernelType


class KVPool:
    """Free-list page allocator with reservation accounting.

    Host-side only.  ``reserved`` counts pages promised to admitted rows
    but not yet physically allocated; ``available()`` is what a *new*
    admission may claim.  Counters (``pages_in_use``/``pages_peak``/
    ``live_tokens``/``tokens_peak``) are updated at every alloc/free so
    benches read them instead of recomputing occupancy.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError(
                f"need n_pages >= 1 and page_size >= 1, got "
                f"{n_pages}/{page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self._free: List[int] = list(range(self.n_pages))
        self.reserved = 0
        self.pages_peak = 0
        self.live_tokens = 0
        self.tokens_peak = 0

    # -- allocation -------------------------------------------------------
    @property
    def trash_page(self) -> int:
        return self.n_pages

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def available(self) -> int:
        """Pages a fresh admission may still reserve."""
        return len(self._free) - self.reserved

    def alloc(self, n: int, *, from_reserved: int = 0) -> List[int]:
        if from_reserved > self.reserved:
            raise RuntimeError(
                f"drawing {from_reserved} pages from a reservation of "
                f"{self.reserved}")
        if n > len(self._free) - (self.reserved - from_reserved):
            raise RuntimeError(
                f"page pool exhausted: want {n}, have {len(self._free)} "
                f"free of which {self.reserved - from_reserved} reserved")
        ids = [self._free.pop() for _ in range(n)]
        self.reserved -= from_reserved
        self.pages_peak = max(self.pages_peak, self.pages_in_use)
        return ids

    def free(self, ids: List[int]) -> None:
        for p in ids:
            if not (0 <= p < self.n_pages):
                raise RuntimeError(f"freeing invalid page id {p}")
            if p in self._free:
                raise RuntimeError(f"double free of page {p}")
        self._free.extend(ids)

    def reserve(self, n: int) -> None:
        if n > self.available():
            raise RuntimeError(
                f"cannot reserve {n} pages, only {self.available()} "
                "available")
        self.reserved += n

    def unreserve(self, n: int) -> None:
        if n > self.reserved:
            raise RuntimeError(
                f"releasing reservation of {n} > {self.reserved}")
        self.reserved -= n

    # -- token accounting -------------------------------------------------
    def add_live_tokens(self, n: int) -> None:
        self.live_tokens += int(n)
        self.tokens_peak = max(self.tokens_peak, self.live_tokens)

    def drop_live_tokens(self, n: int) -> None:
        self.live_tokens -= int(n)

    @property
    def fragmentation(self) -> float:
        """Fraction of in-use page slots not holding a live token
        (tail-of-page internal fragmentation; v1 never shares pages)."""
        cap = self.pages_in_use * self.page_size
        if cap == 0:
            return 0.0
        return max(0.0, cap - self.live_tokens) / cap

    def attach(self, batch: int, *, kv_cap: int, budget_steps: int,
               kernel: KernelType = KernelType.XLA) -> "PagedKV":
        return PagedKV(self, batch, kv_cap=kv_cap,
                       budget_steps=budget_steps, kernel=kernel)


@dataclasses.dataclass
class PagedKV:
    """One decode batch's page tables over a shared ``KVPool``.

    ``table`` is the host mirror, shape (b, W) int32 with W =
    ceil(kv_cap / page_size); unallocated entries hold the trash page.
    ``row_high[i]`` upper-bounds row i's next write position, advanced per
    segment by ``ensure`` — the paged replacement for the dense
    ``used``/``max_len`` ceiling, per row instead of per batch.
    """
    pool: KVPool
    batch: int
    kv_cap: int
    budget_steps: int
    kernel: KernelType = KernelType.XLA

    def __post_init__(self):
        self.page_size = self.pool.page_size
        self.table_width = _ceil_div(self.kv_cap, self.page_size)
        self.table = np.full((self.batch, self.table_width),
                             self.pool.trash_page, np.int32)
        self.row_pages: List[List[int]] = [[] for _ in range(self.batch)]
        self.row_reserved = [0] * self.batch
        self.row_high = np.zeros((self.batch,), np.int64)
        self.row_live = np.zeros((self.batch,), bool)
        # rows admitted ahead of their refill launch (reservation already
        # taken); ``decode_segment`` consumes the flag instead of
        # re-admitting
        self.row_preadmitted = np.zeros((self.batch,), bool)
        self.spec = PagedSpec(self.page_size, int(self.kv_cap), self.kernel)

    # -- admission --------------------------------------------------------
    def row_need(self, true_len: int) -> int:
        """Worst-case pages a row admitted at ``true_len`` can touch."""
        return _ceil_div(min(true_len + self.budget_steps, self.kv_cap),
                         self.page_size)

    def can_admit(self, true_len: int) -> bool:
        return self.pool.available() >= self.row_need(true_len)

    def admit_row(self, row: int, true_len: int) -> None:
        """Reserve the row's worst case and allocate its prompt pages."""
        if self.row_live[row]:
            raise RuntimeError(f"row {row} already admitted")
        if not (1 <= true_len <= self.kv_cap):
            raise ValueError(
                f"prompt of {true_len} tokens outside [1, {self.kv_cap}]")
        need = self.row_need(true_len)
        if need > self.pool.n_pages:
            raise ValueError(
                f"kv pool of {self.pool.n_pages} pages "
                f"(page_size={self.page_size}) is too small to admit a "
                f"single full-budget row: a {true_len}-token prompt with "
                f"{self.budget_steps} decode steps needs {need} pages — "
                "raise kv_pool_pages or kv_page_size")
        if not self.can_admit(true_len):
            raise RuntimeError(
                f"admission of a {true_len}-token row needs {need} pages, "
                f"pool has {self.pool.available()} — check can_admit first")
        n_prompt = _ceil_div(true_len, self.page_size)
        self.pool.reserve(need)
        ids = self.pool.alloc(n_prompt, from_reserved=n_prompt)
        self.table[row, :n_prompt] = ids
        self.row_pages[row] = list(ids)
        self.row_reserved[row] = need - n_prompt
        self.row_high[row] = true_len
        self.row_live[row] = True
        self.pool.add_live_tokens(true_len)

    def retire_row(self, row: int) -> None:
        """Release a row's pages and reservation; its table entries fall
        back to the trash page so any still-running PAD decode of that slot
        scatters harmlessly.  Must run before the pages are re-admitted —
        the serve loop orders sync (retire) before admit before launch."""
        if not self.row_live[row]:
            return
        self.pool.free(self.row_pages[row])
        self.pool.unreserve(self.row_reserved[row])
        self.pool.drop_live_tokens(int(self.row_high[row]))
        self.table[row, :] = self.pool.trash_page
        self.row_pages[row] = []
        self.row_reserved[row] = 0
        self.row_high[row] = 0
        self.row_live[row] = False
        self.row_preadmitted[row] = False

    def pre_admit(self, row: int, true_len: int) -> None:
        """Retire + admit a row ahead of its refill launch.

        The serve loop admits several rows at one segment boundary before
        any of them launches; taking each row's reservation immediately
        keeps ``can_admit()`` truthful for the admissions that follow.
        ``decode_segment`` consumes ``row_preadmitted`` instead of
        re-admitting."""
        self.retire_row(row)
        self.admit_row(row, true_len)
        self.row_preadmitted[row] = True

    # -- per-segment growth ----------------------------------------------
    def check_steps(self, steps: int) -> None:
        """Per-row capacity guard (replaces the dense used/max_len check):
        every live row must fit ``steps`` more writes under ``kv_cap``."""
        if self.row_live.any():
            high = int(self.row_high[self.row_live].max())
            if high + steps > self.kv_cap:
                raise ValueError(
                    f"segment of {steps} steps overruns a paged row: "
                    f"{high} of {self.kv_cap} token capacity used")

    def starved_rows(self, steps: int) -> List[int]:
        """Live rows whose share of the next ``ensure(steps)`` would raise
        on true pool exhaustion — a dry run of ``ensure``'s allocation
        order with no side effects.

        Within its reserved budget a row can never starve (admission took
        its worst case up front), so this only names rows decoding *past*
        their budget under a drained pool.  The serve runtime fails those
        rows at the segment boundary (pages released, row requeued)
        instead of letting ``ensure`` kill the whole stream.
        """
        free = len(self.pool._free)
        reserved = self.pool.reserved
        out = []
        for row in range(self.batch):
            if not self.row_live[row]:
                continue
            target = min(int(self.row_high[row]) + steps, self.kv_cap)
            need = _ceil_div(target, self.page_size) - len(self.row_pages[row])
            if need <= 0:
                continue
            from_res = min(need, self.row_reserved[row])
            if need > free - (reserved - from_res):
                out.append(row)
                continue
            free -= need
            reserved -= from_res
        return out

    def ensure(self, steps: int) -> None:
        """Allocate the pages ``steps`` more decode writes need and
        advance ``row_high``.

        Pages come from the row's reservation first — a row is
        *guaranteed* its ``budget_steps`` of decode, so within budget this
        can never fail.  A row legally decoded past its own budget (a
        short row under a wide ``kv_cap``, plain ``decode_segment`` use)
        draws best-effort from the unreserved free pool and raises only
        on true exhaustion."""
        for row in range(self.batch):
            if not self.row_live[row]:
                continue
            target = min(int(self.row_high[row]) + steps, self.kv_cap)
            need = _ceil_div(target, self.page_size) - len(self.row_pages[row])
            if need > 0:
                from_res = min(need, self.row_reserved[row])
                ids = self.pool.alloc(need, from_reserved=from_res)
                start = len(self.row_pages[row])
                self.table[row, start:start + need] = ids
                self.row_pages[row].extend(ids)
                self.row_reserved[row] -= from_res
            self.pool.add_live_tokens(target - int(self.row_high[row]))
            self.row_high[row] = target

    # -- device views -----------------------------------------------------
    def device_table(self):
        import jax.numpy as jnp
        return jnp.asarray(self.table)

    def prompt_page_ids(self, mask: np.ndarray, n_pages_row: int
                        ) -> np.ndarray:
        """(b, n_pages_row) scatter destinations for refill prompt page
        blocks: admitted rows' freshly allocated prompt pages where the
        mask is set, the trash page elsewhere (so non-refilled rows' live
        pages are never touched by the fused scatter)."""
        ids = np.where(np.asarray(mask, bool)[:, None],
                       self.table[:, :n_pages_row],
                       np.int32(self.pool.trash_page))
        return ids.astype(np.int32)
