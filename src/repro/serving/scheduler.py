"""Streaming microbatch scheduler: ragged traffic -> fixed-shape batches.

The fused serve hot path (``sampler._prefill`` / ``sampler._scan_decode``)
compiles one XLA executable per input shape.  Live traffic is ragged — per
tick the number of (query, model) prompts varies — so feeding raw request
batches to the estimator recompiles constantly.  ``MicrobatchScheduler``
quantizes the traffic onto a small fixed grid of (batch, prompt-len)
shapes:

  * the **batch axis** is padded up to a configured set of bucket sizes
    with all-PAD rows.  Prefill and the decode scan are row-independent
    (attention, sampling, and the EOS mask never mix rows), so under
    greedy decoding the real rows are **bit-identical** to an unpadded
    run — pad rows are simply dropped on the way out;
  * the **prompt-len axis** is exact-fit by default (SCOPE's structured
    serialization produces constant-length prompts per pool, so each
    distinct length is its own bucket).  A fixed ``prompt_lens`` grid may
    be configured to cap executable count under genuinely ragged lengths:
    prompts are right-padded with PAD up to the bucket boundary and each
    ``Microbatch`` carries the true per-row ``lengths``, which the sampler
    threads through decode as per-row positions + valid-length masks — a
    sub-bucket row reproduces the unpadded run's *token stream* exactly
    and its decision logits to f32 ulp (the attention reductions span the
    bucket width, so last-bit logit equality across widths is not a
    representable goal).  Exactness holds for attention backbones;
    SSM/conv prefill states consume pad tokens, so keep exact-fit there.

**Continuous flushing.**  ``ready()`` pops full microbatches eagerly at
the largest batch bucket; ``tick()`` additionally applies the latency
knobs — ``max_queue_age`` (emit a partial bucket rather than hold a
request past its deadline; checked against an injectable monotonic
``clock``) and ``min_fill`` (emit once a queue covers that fraction of the
largest bucket, trading pad waste for latency); ``flush()`` drains
everything left into a greedy largest-fit bucket decomposition at stream
end.  ``SchedulerStats`` tracks bucket occupancy, pad waste, queue-age
percentiles, and the compiled-executable counts of the fused decode path.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import (
    Any, Callable, Deque, Dict, Iterator, List, Optional, Sequence, Tuple)

import numpy as np

from repro.data.tokenizer import PAD

# bounded reservoir of per-prompt queue ages (seconds) for the percentiles
MAX_QUEUE_AGE_SAMPLES = 65536


def decode_compile_counts() -> Dict[str, int]:
    """Compiled-executable counts of the fused serve path.

    Reads ``sampler.COMPILE_COUNTS`` — explicit counters incremented inside
    the traced bodies of ``_prefill`` / ``_scan_decode``, i.e. exactly once
    per compiled (shape, dtype, static-arg) combination.  No jit internals
    are sniffed, so the CI "0 recompiles after warmup" gate cannot silently
    degrade.  The counters are process-global and monotonic; callers
    interested in the cost of a traffic window should diff two snapshots.
    """
    from repro.models import tier0
    from repro.serving import sampler
    return {"prefill": int(sampler.COMPILE_COUNTS["prefill"]),
            "scan_decode": int(sampler.COMPILE_COUNTS["scan_decode"]),
            "refill_scan_decode":
                int(sampler.COMPILE_COUNTS["refill_scan_decode"]),
            "paged_prefill": int(sampler.COMPILE_COUNTS["paged_prefill"]),
            "paged_scan_decode":
                int(sampler.COMPILE_COUNTS["paged_scan_decode"]),
            "paged_refill_prefill":
                int(sampler.COMPILE_COUNTS["paged_refill_prefill"]),
            "paged_refill_scan_decode":
                int(sampler.COMPILE_COUNTS["paged_refill_scan_decode"]),
            "tier0": int(tier0.COMPILE_COUNTS["tier0"])}


@dataclasses.dataclass(frozen=True)
class BucketConfig:
    """The fixed (batch, prompt-len) shape grid.

    ``batch_sizes`` must be sorted ascending; traffic is assembled into the
    largest size and flushed into a greedy largest-fit decomposition.
    ``prompt_lens`` empty means exact-fit: every distinct arriving length is
    its own bucket (no length padding, bit-identical results).
    """
    batch_sizes: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)
    prompt_lens: Tuple[int, ...] = ()

    def __post_init__(self):
        if not self.batch_sizes:
            raise ValueError("batch_sizes must be non-empty")
        bs = tuple(sorted({int(b) for b in self.batch_sizes}))
        if bs[0] <= 0:
            raise ValueError(f"batch sizes must be positive, got {bs}")
        object.__setattr__(self, "batch_sizes", bs)
        object.__setattr__(self, "prompt_lens",
                           tuple(sorted({int(x) for x in self.prompt_lens})))

    @property
    def max_batch(self) -> int:
        return self.batch_sizes[-1]

    def batch_bucket(self, n: int) -> int:
        """Smallest configured batch size >= n (n must fit the grid)."""
        for b in self.batch_sizes:
            if b >= n:
                return b
        raise ValueError(f"batch {n} exceeds the largest bucket "
                         f"{self.max_batch}")

    def len_bucket(self, length: int) -> int:
        """Smallest configured prompt-len >= length; exact-fit otherwise."""
        for ell in self.prompt_lens:
            if ell >= length:
                return ell
        return int(length)          # exact-fit (incl. overflow of the grid)


@dataclasses.dataclass
class SchedulerStats:
    submitted: int = 0              # real prompts accepted
    emitted: int = 0                # real prompts shipped in microbatches
    microbatches: int = 0
    partial_microbatches: int = 0   # emitted below the full bucket batch
    flushes: int = 0                # flush() calls that emitted something
    deadline_flushes: int = 0       # queue drains forced by max_queue_age
    fill_flushes: int = 0           # emissions triggered by min_fill
    pad_rows: int = 0               # all-PAD filler rows
    pad_tokens: int = 0             # PAD tokens added (rows + length padding)
    real_tokens: int = 0
    # decode-slot accounting (continuous batching): how many slot-steps the
    # decode executables ran, how many of them decoded a live request's
    # tokens, and how much of that came from mid-batch refills.  The engine
    # folds these in at parse/retire time for both the whole-retire and the
    # segment-chunked refill paths, so the bench occupancy comparison reads
    # one counter pair instead of recomputing.
    slots_refilled: int = 0         # requests popped into an open slot
    refill_steps_saved: int = 0     # active decode steps served by refilled
    #                                 rows — whole-retire would have idled
    #                                 those slot-steps at PAD
    slot_steps_total: int = 0       # batch x decode-steps actually run
    slot_steps_active: int = 0      # of those, steps holding a live request
    # paged-KV accounting (segment granularity, folded in by
    # SlotRun.account / SlotRuntime._admit).  pages_in_use / kv_live_tokens
    # are gauges (last retire's snapshot); the peaks are monotonic maxima.
    # kv_peak_tokens is also set on the dense path (batch x max_len per
    # run), so paged-vs-dense KV footprints compare through one counter.
    kv_page_size: int = 0
    pages_in_use: int = 0
    pages_peak: int = 0
    kv_live_tokens: int = 0
    kv_peak_tokens: int = 0
    admissions_deferred_on_pages: int = 0    # boundaries that idled a free
    #                                          slot waiting for pool pages
    admissions_deferred_on_horizon: int = 0  # dense counterpart (remaining
    #                                          horizon below one budget)
    # fault tolerance (bounded retry / quarantine / SLO deadlines /
    # degraded answers).  ``submitted`` counts each prompt once, so
    # exactly-once accounting reads: every submitted prompt ends either
    # parsed (OK) or degraded/failed — ``requeued`` re-emissions never
    # re-submit.  ``degraded``/``failed_pairs`` count *prompts* (in-flight
    # dedup keys), not the waiter fan-out behind them.
    retries: int = 0                # failure events routed into retry
    requeued: int = 0               # rows put back in the queue
    quarantined: int = 0            # prompts that exhausted max_retries
    deadline_expired: int = 0       # prompts answered past their deadline
    degraded: int = 0               # prompts answered from retrieval priors
    failed_pairs: int = 0           # prompts answered FAILED (no fallback)
    injected_faults: int = 0        # FaultInjector events that fired
    kv_exhausted_rows: int = 0      # rows failed by KV pool exhaustion
    # two-tier routing ledger (folded in by the engine per request, before
    # submission): ``tier0_answered`` pairs were served by the pre-router
    # head and never entered this scheduler; ``escalated`` pairs continued
    # into the decode path (and are the only ones counted in
    # ``submitted``).  ``tier0_fallbacks`` counts quarantined/expired
    # escalations answered from their stashed tier-0 row instead of the
    # retrieval prior; ``tier0_decode_tokens_saved`` is the decode budget
    # the answered pairs never spent.
    tier0_answered: int = 0
    escalated: int = 0
    tier0_fallbacks: int = 0
    tier0_decode_tokens_saved: int = 0
    # drift ledger (folded in by the engine from its FeedbackMonitor when
    # EngineConfig.drift_detect is on): snapshots, not increments —
    # ``drift_alarms`` is the monitor's monotonic alarm count,
    # ``models_quarantined`` the currently-drifted model count,
    # ``hot_swaps`` the engine's lifetime estimator swaps,
    # ``replay_buffer_len`` the outcome ledger's current size, and the
    # residual percentiles summarize |predicted_p - observed_y| over the
    # buffer.  All stay zero with the detector off, so detector-on and
    # detector-off stats differ only inside the ``drift`` block.
    drift_alarms: int = 0
    models_quarantined: int = 0
    hot_swaps: int = 0
    replay_buffer_len: int = 0
    drift_residual_p50: float = 0.0
    drift_residual_p95: float = 0.0
    occupancy: Dict[Tuple[int, int], int] = dataclasses.field(
        default_factory=dict)       # (batch, len) bucket -> microbatch count
    queue_ages: Deque[float] = dataclasses.field(
        default_factory=lambda: deque(maxlen=MAX_QUEUE_AGE_SAMPLES))

    @property
    def pad_fraction(self) -> float:
        total = self.real_tokens + self.pad_tokens
        return self.pad_tokens / total if total else 0.0

    @property
    def slot_occupancy(self) -> float:
        """Fraction of decode slot-steps that served a live request."""
        return (self.slot_steps_active / self.slot_steps_total
                if self.slot_steps_total else 0.0)

    @property
    def page_fragmentation(self) -> float:
        """Fraction of peak-allocated page capacity that never held a live
        token — intra-page waste from partial last pages plus reserved-but-
        unwritten budget headroom.  0.0 when no paged run has retired."""
        cap = self.pages_peak * self.kv_page_size
        return 1.0 - self.kv_peak_tokens / cap if cap else 0.0

    @property
    def degraded_fraction(self) -> float:
        """Fraction of submitted prompts answered without a full estimator
        decode (degraded from retrieval priors or failed outright)."""
        if not self.submitted:
            return 0.0
        return (self.degraded + self.failed_pairs) / self.submitted

    @property
    def escalation_rate(self) -> float:
        """Fraction of tier-0-gated pairs that escalated to the reasoning
        decode.  1.0 when no tier-0 head gated anything (every pair paid
        the decode)."""
        gated = self.tier0_answered + self.escalated
        return self.escalated / gated if gated else 1.0

    def queue_age_percentiles(self) -> Dict[str, float]:
        """Seconds spent queued, per emitted prompt (p50/p95/max)."""
        if not self.queue_ages:
            return {"p50": 0.0, "p95": 0.0, "max": 0.0}
        a = np.asarray(self.queue_ages, np.float64)
        return {"p50": float(np.percentile(a, 50)),
                "p95": float(np.percentile(a, 95)),
                "max": float(a.max())}

    def as_dict(self) -> Dict[str, Any]:
        ages = self.queue_age_percentiles()
        return {"submitted": self.submitted, "emitted": self.emitted,
                "microbatches": self.microbatches,
                "partial_microbatches": self.partial_microbatches,
                "flushes": self.flushes,
                "deadline_flushes": self.deadline_flushes,
                "fill_flushes": self.fill_flushes,
                "pad_rows": self.pad_rows,
                "pad_fraction": round(self.pad_fraction, 4),
                "slots_refilled": self.slots_refilled,
                "refill_steps_saved": self.refill_steps_saved,
                "slot_steps": {"total": self.slot_steps_total,
                               "active": self.slot_steps_active},
                "slot_occupancy": round(self.slot_occupancy, 4),
                "kv_pages": {"page_size": self.kv_page_size,
                             "in_use": self.pages_in_use,
                             "peak": self.pages_peak,
                             "live_tokens": self.kv_live_tokens,
                             "peak_tokens": self.kv_peak_tokens,
                             "fragmentation":
                                 round(self.page_fragmentation, 4),
                             "deferred_on_pages":
                                 self.admissions_deferred_on_pages,
                             "deferred_on_horizon":
                                 self.admissions_deferred_on_horizon},
                "faults": {"retries": self.retries,
                           "requeued": self.requeued,
                           "quarantined": self.quarantined,
                           "deadline_expired": self.deadline_expired,
                           "degraded": self.degraded,
                           "failed": self.failed_pairs,
                           "injected": self.injected_faults,
                           "kv_exhausted_rows": self.kv_exhausted_rows,
                           "degraded_fraction":
                               round(self.degraded_fraction, 4)},
                "tiers": {"tier0_answered": self.tier0_answered,
                          "escalated": self.escalated,
                          "escalation_rate": round(self.escalation_rate, 4),
                          "tier0_fallbacks": self.tier0_fallbacks,
                          "decode_tokens_saved":
                              self.tier0_decode_tokens_saved},
                "drift": {"alarms": self.drift_alarms,
                          "models_quarantined": self.models_quarantined,
                          "hot_swaps": self.hot_swaps,
                          "replay_buffer_len": self.replay_buffer_len,
                          "residual_p50":
                              round(self.drift_residual_p50, 4),
                          "residual_p95":
                              round(self.drift_residual_p95, 4)},
                "queue_age_ms": {k: round(v * 1e3, 3)
                                 for k, v in ages.items()},
                "buckets": {f"{b}x{l}": c
                            for (b, l), c in sorted(self.occupancy.items())},
                "compile_counts": decode_compile_counts()}


@dataclasses.dataclass
class Microbatch:
    """One fixed-shape unit of work: (bucket_batch, bucket_len) tokens.

    Rows [0, n_real) carry real prompts (right-padded to ``bucket[1]`` when
    a length grid is configured); rows [n_real, bucket[0]) are all-PAD
    filler.  ``tags`` parallels the real rows; ``lengths`` gives every
    row's true prompt length (pad rows report the full bucket length), for
    the sampler's per-row positions / valid-length masks.
    """
    tokens: np.ndarray              # (bucket_batch, bucket_len) int32
    tags: List[Any]
    lengths: np.ndarray             # (bucket_batch,) int32 true lengths
    bucket: Tuple[int, int]

    @property
    def n_real(self) -> int:
        return len(self.tags)


@dataclasses.dataclass
class _Pending:
    tag: Any
    prompt: List[int]
    t_submit: float


class MicrobatchScheduler:
    """Request queue + microbatch assembler over a ``BucketConfig`` grid.

    ``submit`` enqueues one prompt under an opaque tag; ``ready`` pops
    full largest-bucket microbatches; ``tick`` adds deadline/occupancy
    flushing (``max_queue_age`` seconds / ``min_fill`` fraction of the
    largest bucket, on the injectable monotonic ``clock``); ``flush``
    drains everything left.  The scheduler is shape bookkeeping only —
    executing a ``Microbatch`` (and discarding its pad rows) is the
    caller's job.
    """

    def __init__(self, config: Optional[BucketConfig] = None, *,
                 max_queue_age: Optional[float] = None,
                 min_fill: float = 0.0,
                 clock: Callable[[], float] = time.monotonic):
        if max_queue_age is not None and max_queue_age < 0:
            raise ValueError(f"max_queue_age must be >= 0, "
                             f"got {max_queue_age}")
        if not 0.0 <= min_fill <= 1.0:
            raise ValueError(f"min_fill must be in [0, 1], got {min_fill}")
        self.config = config or BucketConfig()
        self.max_queue_age = max_queue_age
        self.min_fill = float(min_fill)
        self.stats = SchedulerStats()
        self._clock = clock
        # per len-bucket FIFO; OrderedDict keeps drain order deterministic
        self._queues: "OrderedDict[int, List[_Pending]]" = OrderedDict()

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def oldest_age(self) -> float:
        """Age (s) of the oldest queued prompt; 0.0 when empty."""
        oldest = min((q[0].t_submit for q in self._queues.values() if q),
                     default=None)
        return 0.0 if oldest is None else self._clock() - oldest

    def now(self) -> float:
        """The scheduler's monotonic clock — the time base for queue ages
        and (in the engine) SLO deadlines, so tests inject one clock."""
        return self._clock()

    def submit(self, tag: Any, prompt: Sequence[int]) -> None:
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt")
        ell = self.config.len_bucket(len(prompt))
        self._queues.setdefault(ell, []).append(
            _Pending(tag, prompt, self._clock()))
        self.stats.submitted += 1

    def requeue(self, tag: Any, prompt: Sequence[int]) -> None:
        """Re-enqueue a failed row at the back of its length class.

        Accounted under ``requeued``, never ``submitted`` — the prompt was
        already counted once at ``submit``, so exactly-once accounting
        (every submitted prompt is answered exactly once) survives any
        number of retries.  Re-enqueueing at the back keeps per-class FIFO
        exact for rows that never fail; a retried row re-enters behind
        the prompts that arrived while it was in flight.
        """
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt")
        ell = self.config.len_bucket(len(prompt))
        self._queues.setdefault(ell, []).append(
            _Pending(tag, prompt, self._clock()))
        self.stats.requeued += 1

    def cancel(self, tag: Any) -> Optional[List[int]]:
        """Remove one queued prompt by tag (SLO expiry of a row that never
        reached the device); returns its prompt, or ``None`` if the tag is
        not queued (already emitted, or unknown)."""
        for q in self._queues.values():
            for i, it in enumerate(q):
                if it.tag == tag:
                    del q[i]
                    return it.prompt
        return None

    # -- assembly ------------------------------------------------------
    def _emit(self, ell: int, items: List[_Pending]) -> Microbatch:
        bb = self.config.batch_bucket(len(items))
        tokens = np.full((bb, ell), PAD, np.int32)
        lengths = np.full((bb,), ell, np.int32)
        for i, it in enumerate(items):
            tokens[i, : len(it.prompt)] = it.prompt
            lengths[i] = len(it.prompt)
        now = self._clock()
        st = self.stats
        st.emitted += len(items)
        st.microbatches += 1
        st.partial_microbatches += int(len(items) < bb)
        st.pad_rows += bb - len(items)
        real = sum(len(it.prompt) for it in items)
        st.real_tokens += real
        st.pad_tokens += bb * ell - real
        st.queue_ages.extend(now - it.t_submit for it in items)
        key = (bb, ell)
        st.occupancy[key] = st.occupancy.get(key, 0) + 1
        return Microbatch(tokens, [it.tag for it in items], lengths, key)

    def _largest_fit(self, n: int) -> int:
        """Largest configured batch size <= n, else n (padded up on emit)."""
        for b in reversed(self.config.batch_sizes):
            if b <= n:
                return b
        return n

    def pop_one(self, width: Optional[int] = None
                ) -> Optional[Tuple[Any, List[int], int]]:
        """Pop the single oldest queued prompt that fits an open decode
        slot of ``width`` tokens; ``None`` when nothing fits.

        This is the scheduler's unit of **mid-batch refill**: between
        decode segments the engine pulls one request per drained slot
        instead of waiting for a whole bucket.  Only queue fronts are
        taken, so per-length-class FIFO order is preserved, and the
        globally oldest fitting prompt wins across classes.  Returns
        ``(tag, prompt, length)``; emission stats (queue age, real/pad
        tokens, ``slots_refilled``) are accounted as a one-row emission.
        """
        best_ell = None
        for ell, q in self._queues.items():
            if not q or (width is not None and len(q[0].prompt) > width):
                continue
            if (best_ell is None
                    or q[0].t_submit < self._queues[best_ell][0].t_submit):
                best_ell = ell
        if best_ell is None:
            return None
        it = self._queues[best_ell].pop(0)
        st = self.stats
        st.emitted += 1
        st.slots_refilled += 1
        st.real_tokens += len(it.prompt)
        if width is not None:
            st.pad_tokens += width - len(it.prompt)
        st.queue_ages.append(self._clock() - it.t_submit)
        return it.tag, it.prompt, len(it.prompt)

    def peek_one(self, width: Optional[int] = None) -> bool:
        """Whether ``pop_one(width)`` would return a prompt — a
        non-destructive probe so the serve runtime can tell an idle queue
        apart from an admission deferred on capacity (and count only the
        latter)."""
        return any(q and (width is None or len(q[0].prompt) <= width)
                   for q in self._queues.values())

    def ready(self) -> List[Microbatch]:
        """Pop every full largest-bucket microbatch currently assembled."""
        out = []
        full = self.config.max_batch
        for ell, q in self._queues.items():
            while len(q) >= full:
                out.append(self._emit(ell, q[:full]))
                del q[:full]
        return out

    def tick(self) -> List[Microbatch]:
        """``ready()`` plus deadline/occupancy flushing.

        A queue whose **oldest** prompt has waited ``max_queue_age`` is
        drained front-first until the remainder is younger than the
        deadline (partially-filled buckets allowed); a queue holding at
        least ``min_fill * max_batch`` prompts emits largest-fit
        microbatches down to that threshold.  With both knobs unset this
        is exactly ``ready()``.

        The deadline is **tick-granular**: it is only checked when
        ``tick()`` runs (the engine calls it per request arrival), so the
        realized age bound is ``max_queue_age`` plus the caller's
        inter-tick time — including any microbatch execution its drain
        loop blocks on.
        """
        out = self.ready()
        if self.max_queue_age is None and self.min_fill <= 0.0:
            return out
        now = self._clock()
        fill_n = self.min_fill * self.config.max_batch
        for ell, q in self._queues.items():
            while q:
                expired = (self.max_queue_age is not None
                           and now - q[0].t_submit >= self.max_queue_age)
                filled = self.min_fill > 0.0 and len(q) >= fill_n
                if not (expired or filled):
                    break
                take = self._largest_fit(len(q))
                out.append(self._emit(ell, q[:take]))
                del q[:take]
                st = self.stats
                st.deadline_flushes += int(expired)
                st.fill_flushes += int(filled and not expired)
        return out

    def flush(self) -> List[Microbatch]:
        """Drain the remainder: greedy largest-fit bucket decomposition."""
        out = self.ready()
        for ell, q in self._queues.items():
            while q:
                take = self._largest_fit(len(q))
                out.append(self._emit(ell, q[:take]))
                del q[:take]
        self._queues.clear()
        if out:
            self.stats.flushes += 1
        return out

    def drain(self) -> Iterator[Microbatch]:
        """ready() + flush() as one iterator (single-shot workloads)."""
        yield from self.flush()
