"""Streaming microbatch scheduler: ragged traffic -> fixed-shape batches.

The fused serve hot path (``sampler._prefill`` / ``sampler._scan_decode``)
compiles one XLA executable per input shape.  Live traffic is ragged — per
tick the number of (query, model) prompts varies — so feeding raw request
batches to the estimator recompiles constantly.  ``MicrobatchScheduler``
quantizes the traffic onto a small fixed grid of (batch, prompt-len)
shapes:

  * the **batch axis** is padded up to a configured set of bucket sizes
    with all-PAD rows.  Prefill and the decode scan are row-independent
    (attention, sampling, and the EOS mask never mix rows), so under
    greedy decoding the real rows are **bit-identical** to an unpadded
    run — pad rows are simply dropped on the way out;
  * the **prompt-len axis** is exact-fit by default (SCOPE's structured
    serialization produces constant-length prompts per pool, so each
    distinct length is its own bucket).  A fixed ``prompt_lens`` grid may
    be configured to cap executable count under genuinely ragged lengths:
    prompts are then right-padded with PAD up to the bucket boundary,
    which matches the ``ServingEngine`` padding semantic (decode continues
    from the padded position; sub-bucket rows are no longer bit-identical
    to an unpadded run, so keep exact-fit where parity matters).

``ready()`` pops full microbatches eagerly at the largest batch bucket;
``flush()`` drains the remainder into a greedy largest-fit bucket
decomposition.  ``SchedulerStats`` tracks bucket occupancy, pad waste, and
the compiled-executable counts of the fused decode path.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.tokenizer import PAD


def decode_compile_counts() -> Dict[str, int]:
    """Compiled-executable counts of the fused serve path.

    Reads the jit caches of ``sampler._prefill`` / ``sampler._scan_decode``
    — one entry per (shape, sharding) the serve path has compiled.  The
    counters are process-global and monotonic; callers interested in the
    cost of a traffic window should diff two snapshots.
    """
    from repro.serving import sampler
    out = {}
    for name, fn in (("prefill", sampler._prefill),
                     ("scan_decode", sampler._scan_decode)):
        try:
            out[name] = int(fn._cache_size())
        except Exception:           # jit internals moved — degrade gracefully
            out[name] = -1
    return out


@dataclasses.dataclass(frozen=True)
class BucketConfig:
    """The fixed (batch, prompt-len) shape grid.

    ``batch_sizes`` must be sorted ascending; traffic is assembled into the
    largest size and flushed into a greedy largest-fit decomposition.
    ``prompt_lens`` empty means exact-fit: every distinct arriving length is
    its own bucket (no length padding, bit-identical results).
    """
    batch_sizes: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)
    prompt_lens: Tuple[int, ...] = ()

    def __post_init__(self):
        if not self.batch_sizes:
            raise ValueError("batch_sizes must be non-empty")
        bs = tuple(sorted(set(int(b) for b in self.batch_sizes)))
        if bs[0] <= 0:
            raise ValueError(f"batch sizes must be positive, got {bs}")
        object.__setattr__(self, "batch_sizes", bs)
        object.__setattr__(self, "prompt_lens",
                           tuple(sorted(set(int(x) for x in self.prompt_lens))))

    @property
    def max_batch(self) -> int:
        return self.batch_sizes[-1]

    def batch_bucket(self, n: int) -> int:
        """Smallest configured batch size >= n (n must fit the grid)."""
        for b in self.batch_sizes:
            if b >= n:
                return b
        raise ValueError(f"batch {n} exceeds the largest bucket "
                         f"{self.max_batch}")

    def len_bucket(self, length: int) -> int:
        """Smallest configured prompt-len >= length; exact-fit otherwise."""
        for ell in self.prompt_lens:
            if ell >= length:
                return ell
        return int(length)          # exact-fit (incl. overflow of the grid)


@dataclasses.dataclass
class SchedulerStats:
    submitted: int = 0              # real prompts accepted
    emitted: int = 0                # real prompts shipped in microbatches
    microbatches: int = 0
    flushes: int = 0                # flush() calls that emitted something
    pad_rows: int = 0               # all-PAD filler rows
    pad_tokens: int = 0             # PAD tokens added (rows + length padding)
    real_tokens: int = 0
    occupancy: Dict[Tuple[int, int], int] = dataclasses.field(
        default_factory=dict)       # (batch, len) bucket -> microbatch count

    @property
    def pad_fraction(self) -> float:
        total = self.real_tokens + self.pad_tokens
        return self.pad_tokens / total if total else 0.0

    def as_dict(self) -> Dict[str, Any]:
        return {"submitted": self.submitted, "emitted": self.emitted,
                "microbatches": self.microbatches, "flushes": self.flushes,
                "pad_rows": self.pad_rows,
                "pad_fraction": round(self.pad_fraction, 4),
                "buckets": {f"{b}x{l}": c
                            for (b, l), c in sorted(self.occupancy.items())},
                "compile_counts": decode_compile_counts()}


@dataclasses.dataclass
class Microbatch:
    """One fixed-shape unit of work: (bucket_batch, bucket_len) tokens.

    Rows [0, n_real) carry real prompts (right-padded to ``bucket[1]`` when
    a length grid is configured); rows [n_real, bucket[0]) are all-PAD
    filler.  ``tags`` parallels the real rows.
    """
    tokens: np.ndarray              # (bucket_batch, bucket_len) int32
    tags: List[Any]
    bucket: Tuple[int, int]

    @property
    def n_real(self) -> int:
        return len(self.tags)


@dataclasses.dataclass
class _Pending:
    tag: Any
    prompt: List[int]


class MicrobatchScheduler:
    """Request queue + microbatch assembler over a ``BucketConfig`` grid.

    ``submit`` enqueues one prompt under an opaque tag; ``ready`` pops
    full largest-bucket microbatches; ``flush`` drains everything left.
    The scheduler is shape bookkeeping only — executing a ``Microbatch``
    (and discarding its pad rows) is the caller's job.
    """

    def __init__(self, config: Optional[BucketConfig] = None):
        self.config = config or BucketConfig()
        self.stats = SchedulerStats()
        # per len-bucket FIFO; OrderedDict keeps drain order deterministic
        self._queues: "OrderedDict[int, List[_Pending]]" = OrderedDict()

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def submit(self, tag: Any, prompt: Sequence[int]) -> None:
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt")
        ell = self.config.len_bucket(len(prompt))
        self._queues.setdefault(ell, []).append(_Pending(tag, prompt))
        self.stats.submitted += 1

    # -- assembly ------------------------------------------------------
    def _emit(self, ell: int, items: List[_Pending]) -> Microbatch:
        bb = self.config.batch_bucket(len(items))
        tokens = np.full((bb, ell), PAD, np.int32)
        for i, it in enumerate(items):
            tokens[i, : len(it.prompt)] = it.prompt
        st = self.stats
        st.emitted += len(items)
        st.microbatches += 1
        st.pad_rows += bb - len(items)
        real = sum(len(it.prompt) for it in items)
        st.real_tokens += real
        st.pad_tokens += bb * ell - real
        key = (bb, ell)
        st.occupancy[key] = st.occupancy.get(key, 0) + 1
        return Microbatch(tokens, [it.tag for it in items], key)

    def ready(self) -> List[Microbatch]:
        """Pop every full largest-bucket microbatch currently assembled."""
        out = []
        full = self.config.max_batch
        for ell, q in self._queues.items():
            while len(q) >= full:
                out.append(self._emit(ell, q[:full]))
                del q[:full]
        return out

    def flush(self) -> List[Microbatch]:
        """Drain the remainder: greedy largest-fit bucket decomposition."""
        out = self.ready()
        for ell, q in self._queues.items():
            while q:
                take = len(q)
                for b in reversed(self.config.batch_sizes):
                    if b <= len(q):
                        take = b
                        break
                out.append(self._emit(ell, q[:take]))
                del q[:take]
        self._queues.clear()
        if out:
            self.stats.flushes += 1
        return out

    def drain(self) -> Iterator[Microbatch]:
        """ready() + flush() as one iterator (single-shot workloads)."""
        yield from self.flush()
