"""End-to-end routing service: SCOPE decision + (simulated) execution.

``RouterService`` is a thin legacy shim over ``repro.api.ScopeEngine``: the
``alpha`` / ``budget`` kwargs map onto ``FixedAlphaPolicy`` /
``SetBudgetPolicy`` and execution/accounting live in ``ScopeEngine.execute``
(Eq. 24 overhead included).  New code should call the engine directly and
pass a ``RoutingPolicy``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.router import PoolPredictions, ScopeRouter
from repro.data.datasets import ScopeData


@dataclasses.dataclass
class ServiceReport:
    choices: np.ndarray
    alpha: float
    accuracy: float
    total_cost: float
    exec_tokens: int
    overhead_tokens: int
    per_model_share: Dict[str, float]

    @classmethod
    def empty(cls, models: Sequence[str],
              alpha: Optional[float] = None) -> "ServiceReport":
        """Explicit zero-query report: no NaNs, no divisions by zero."""
        return cls(choices=np.zeros(0, int),
                   alpha=float(alpha) if alpha is not None else 0.0,
                   accuracy=0.0, total_cost=0.0, exec_tokens=0,
                   overhead_tokens=0,
                   per_model_share={m: 0.0 for m in models})


class RouterService:
    def __init__(self, router: ScopeRouter, data: ScopeData,
                 models: Sequence[str]):
        self.router = router
        self.data = data
        self.models = list(models)

    def serve(self, qids: Sequence[int], *, alpha: Optional[float] = None,
              budget: Optional[float] = None,
              pool: Optional[PoolPredictions] = None) -> ServiceReport:
        from repro.api import FixedAlphaPolicy, RouteRequest, SetBudgetPolicy
        if len(qids) == 0:
            return ServiceReport.empty(self.models, alpha)
        if budget is not None:
            policy = SetBudgetPolicy(budget)
        else:
            assert alpha is not None
            policy = FixedAlphaPolicy(alpha)
        engine = self.router.engine
        if pool is None:
            queries = [self.data.queries[int(q)] for q in qids]
            pool = engine.predict(RouteRequest(queries, models=self.models))
        decision = engine.decide(pool, policy)
        rep = engine.execute(self.data, qids, pool, decision, policy.name)
        return ServiceReport(
            choices=np.asarray(decision.choices, int),
            alpha=float(decision.alpha),
            accuracy=rep.accuracy, total_cost=rep.total_cost,
            exec_tokens=rep.exec_tokens,
            overhead_tokens=rep.overhead_tokens,
            per_model_share=rep.per_model_share)
