"""End-to-end routing service: SCOPE decision + (simulated) execution.

Routes each query with the SCOPE router, "executes" the chosen pool model
against the world (standing in for the API call), and accounts tokens/$ —
including the estimator's own prediction overhead (Eq. 24).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.router import PoolPredictions, ScopeRouter
from repro.data.datasets import ScopeData
from repro.data.worldsim import Query


@dataclasses.dataclass
class ServiceReport:
    choices: np.ndarray
    alpha: float
    accuracy: float
    total_cost: float
    exec_tokens: int
    overhead_tokens: int
    per_model_share: Dict[str, float]


class RouterService:
    def __init__(self, router: ScopeRouter, data: ScopeData,
                 models: Sequence[str]):
        self.router = router
        self.data = data
        self.models = list(models)

    def serve(self, qids: Sequence[int], *, alpha: Optional[float] = None,
              budget: Optional[float] = None,
              pool: Optional[PoolPredictions] = None) -> ServiceReport:
        queries = [self.data.queries[int(q)] for q in qids]
        if pool is None:
            pool = self.router.predict_pool(queries, self.models)
        if budget is not None:
            alpha, choices, _ = self.router.route_with_budget(pool, budget)
        else:
            assert alpha is not None
            choices = self.router.route(pool, alpha)

        accs, costs, tokens = [], [], 0
        share = {m: 0 for m in self.models}
        for q, c in zip(qids, choices):
            rec = self.data.record(int(q), self.models[int(c)])
            accs.append(rec.y)
            costs.append(rec.cost)
            tokens += rec.tokens
            share[self.models[int(c)]] += 1
        return ServiceReport(
            choices=choices, alpha=float(alpha),
            accuracy=float(np.mean(accs)), total_cost=float(np.sum(costs)),
            exec_tokens=tokens,
            overhead_tokens=int(pool.pred_overhead.sum()),
            per_model_share={m: v / len(qids) for m, v in share.items()})
