"""KV-cache generation: batch prefill + one fused jitted decode scan.

Prompts in SCOPE's structured serialization have constant length, so the
batch prefill is a single full forward.  Decode is a single jitted
``jax.lax.scan`` over the new-token axis: sampling (greedy or temperature,
for GRPO rollouts) happens on device, an EOS done-mask is carried across
steps, and only what the estimator consumes crosses back to the host —
generated token ids plus the YES/NO logit pair at each step.  The full
``(b, T, V)`` logits stack never leaves the device (~V/2x less host
transfer than the legacy per-token dispatch loop).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import EOS, NO, PAD, YES
from repro.models import model as M

# decision-logit channel order: [:, :, 0] = YES, [:, :, 1] = NO
DECISION_TOKENS = (YES, NO)


@functools.partial(jax.jit, static_argnums=(1,))
def _prefill(params, cfg: ModelConfig, tokens):
    return M.prefill(params, cfg, {"tokens": tokens})


# Explicit seq-axis contract for decode caches, keyed by leaf name.  The
# axis index includes the leading layer-stack dim the segment scan adds:
#   k / v  : (L, b, kv_heads, S, head_dim)  -> axis 3
#   c_kv   : (L, b, S, kv_lora_rank)        -> axis 2
#   k_rope : (L, b, S, qk_rope_head_dim)    -> axis 2
# Everything else (mamba conv/ssm states, ck/cv encoder cross caches) has no
# decode-time sequence axis and must never be grown, whatever its shape.
CACHE_SEQ_AXIS = {"k": 3, "v": 3, "c_kv": 2, "k_rope": 2}


def _leaf_name(path) -> str:
    entry = path[-1]
    if hasattr(entry, "key"):
        return str(entry.key)
    return str(entry)


def _pad_caches(caches, max_len: int, prompt_len: int):
    """Grow prefill caches (seq = prompt_len) to decode capacity.

    The sequence axis comes from the cache *structure* (leaf name ->
    ``CACHE_SEQ_AXIS``), never from sniffing shapes: a head count, conv
    width, or SSM state dim that happens to equal ``prompt_len`` must not
    be padded — growing the wrong axis silently corrupts decode.
    """
    def grow(path, leaf):
        ax = CACHE_SEQ_AXIS.get(_leaf_name(path))
        if ax is None:
            return leaf
        if leaf.shape[ax] != prompt_len:
            raise ValueError(
                f"cache leaf {_leaf_name(path)!r} has seq axis "
                f"{leaf.shape[ax]} != prompt_len {prompt_len} "
                f"(shape {leaf.shape})")
        widths = [(0, 0)] * leaf.ndim
        widths[ax] = (0, max_len - prompt_len)
        return jnp.pad(leaf, widths)

    return jax.tree_util.tree_map_with_path(grow, caches)


# no donate_argnums on the caches: XLA reports the KV buffers as unusable
# donations for a scan carry (they are not jit outputs), so donating would
# only emit a warning per call without saving the copy
@functools.partial(jax.jit, static_argnums=(1, 5, 6, 7))
def _scan_decode(params, cfg: ModelConfig, last_logits, caches, key,
                 max_new_tokens: int, temperature: float, stop_at_eos: bool,
                 prompt_len):
    """One fused decode: sample -> emit (token, YES/NO logits) -> step.

    Carries (last_logits, caches, done, key) across ``max_new_tokens`` scan
    steps; per-step outputs are the sampled token ids (b,) and the decision
    logit pair (b, 2).  Nothing of size V escapes the scan.
    """
    b = last_logits.shape[0]
    dec_ix = jnp.asarray(DECISION_TOKENS, jnp.int32)

    def step(carry, t):
        logits, kv, done, k = carry
        if temperature > 0.0:
            k, sub = jax.random.split(k)
            nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = jnp.where(done, PAD, nxt).astype(jnp.int32)
        dec = logits[:, dec_ix]                          # (b, 2)
        if stop_at_eos:
            done = done | (nxt == EOS)
        new_logits, kv = M.decode_step(params, cfg, nxt[:, None], kv,
                                       prompt_len + t)
        new_logits = new_logits[:, 0].astype(jnp.float32)
        return (new_logits, kv, done, k), (nxt, dec)

    init = (last_logits, caches, jnp.zeros((b,), bool), key)
    _, (gen, dec_logits) = jax.lax.scan(step, init,
                                        jnp.arange(max_new_tokens))
    return gen.T, dec_logits.transpose(1, 0, 2)          # (b, T), (b, T, 2)


def generate(params, cfg: ModelConfig, prompts: np.ndarray, *,
             max_new_tokens: int = 12, temperature: float = 0.0,
             rng: Optional[jax.Array] = None, stop_at_eos: bool = True
             ) -> Tuple[np.ndarray, np.ndarray]:
    """prompts: (b, Lp) int32, constant length.  Returns
    (generated (b, T) int32, decision_logits (b, T, 2) float32) where the
    last axis is the (YES, NO) logit pair at each step — the only slice of
    the vocab distribution the estimator reads."""
    prompts = jnp.asarray(prompts, jnp.int32)
    b, lp = prompts.shape
    max_len = lp + max_new_tokens

    logits, caches = _prefill(params, cfg, prompts)
    caches = _pad_caches(caches, max_len, lp)
    last_logits = logits[:, -1].astype(jnp.float32)

    key = rng if rng is not None else jax.random.PRNGKey(0)
    gen, dec = _scan_decode(params, cfg, last_logits, caches, key,
                            int(max_new_tokens), float(temperature),
                            bool(stop_at_eos), lp)
    return np.asarray(gen), np.asarray(dec)
