"""KV-cache generation: batch prefill + fused jitted decode scan segments.

Decode is organised around an explicit ``DecodeState`` (caches, per-row
positions, done-mask, carried sampling key) so the serve runtime can run
decode in **chunked scan segments** and refill a drained-at-EOS slot with a
freshly prefilled prompt between segments (continuous batching) instead of
idling the slot until the batch finishes:

  state = prefill_state(params, cfg, prompts, max_new_tokens=12)
  state, gen, dec = decode_segment(params, cfg, state, 4)
  state = refill_slot(params, cfg, state, row=2, prompt=new_prompt)
  state, gen2, dec2 = decode_segment(params, cfg, state, 4)

Positions are **per row**: rows at different sequence offsets (ragged
prompt lengths under a bucket grid, refilled slots mid-decode) share one
compiled decode executable, and sub-bucket rows reproduce an unpadded run
exactly — attention masks each row at its own valid length and RoPE rotates
at each row's own position.  (Exactness holds for attention backbones;
SSM/conv states consume right-pad tokens during prefill, so keep exact-fit
lengths for those.)

Each scan segment samples on device (greedy or temperature), carries an
EOS done-mask, and only what the estimator consumes crosses back to the
host — generated token ids plus the YES/NO logit pair at each step.  The
full ``(b, T, V)`` logits stack never leaves the device.

``COMPILE_COUNTS`` counts executable builds explicitly (incremented inside
the traced bodies, once per compilation) — the serve path's "0 recompiles
after warmup" gate reads it instead of sniffing jit internals.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import Counter
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import EOS, NO, PAD, YES
from repro.models import model as M

# decision-logit channel order: [:, :, 0] = YES, [:, :, 1] = NO
DECISION_TOKENS = (YES, NO)

# Explicit compile-count instrumentation: the jitted bodies below increment
# these counters at trace time, which happens exactly once per compiled
# (shape, dtype, static-arg) combination.  Process-global and monotonic —
# diff two snapshots to count the compiles of a traffic window.
COMPILE_COUNTS: "Counter[str]" = Counter()


@functools.partial(jax.jit, static_argnums=(1,))
def _prefill(params, cfg: ModelConfig, tokens):
    COMPILE_COUNTS["prefill"] += 1          # traced once per compilation
    return M.prefill(params, cfg, {"tokens": tokens})


@jax.jit
def _gather_last(logits, lens):
    """Per-row last *valid* prompt logits: logits[i, lens[i] - 1]."""
    idx = (lens - 1).astype(jnp.int32)[:, None, None]
    return jnp.take_along_axis(logits, idx, axis=1)[:, 0].astype(jnp.float32)


# Explicit seq-axis contract for decode caches, keyed by leaf name.  The
# axis index includes the leading layer-stack dim the segment scan adds:
#   k / v  : (L, b, kv_heads, S, head_dim)  -> axis 3
#   c_kv   : (L, b, S, kv_lora_rank)        -> axis 2
#   k_rope : (L, b, S, qk_rope_head_dim)    -> axis 2
# Everything else (mamba conv/ssm states, ck/cv encoder cross caches) has no
# decode-time sequence axis and must never be grown, whatever its shape.
CACHE_SEQ_AXIS = {"k": 3, "v": 3, "c_kv": 2, "k_rope": 2}

# every decode-cache leaf carries batch on axis 1 (behind the layer stack);
# ``refill_slot`` relies on this to scatter one prefilled row into place
CACHE_BATCH_AXIS = 1


def _leaf_name(path) -> str:
    entry = path[-1]
    if hasattr(entry, "key"):
        return str(entry.key)
    return str(entry)


def _pad_caches(caches, max_len: int, prompt_len: int):
    """Grow prefill caches (seq = prompt_len) to decode capacity.

    The sequence axis comes from the cache *structure* (leaf name ->
    ``CACHE_SEQ_AXIS``), never from sniffing shapes: a head count, conv
    width, or SSM state dim that happens to equal ``prompt_len`` must not
    be padded — growing the wrong axis silently corrupts decode.
    """
    def grow(path, leaf):
        ax = CACHE_SEQ_AXIS.get(_leaf_name(path))
        if ax is None:
            return leaf
        if leaf.shape[ax] != prompt_len:
            raise ValueError(
                f"cache leaf {_leaf_name(path)!r} has seq axis "
                f"{leaf.shape[ax]} != prompt_len {prompt_len} "
                f"(shape {leaf.shape})")
        widths = [(0, 0)] * leaf.ndim
        widths[ax] = (0, max_len - prompt_len)
        return jnp.pad(leaf, widths)

    return jax.tree_util.tree_map_with_path(grow, caches)


# no donate_argnums on the caches: XLA reports the KV buffers as unusable
# donations for a scan carry (they are not jit outputs), so donating would
# only emit a warning per call without saving the copy
@functools.partial(jax.jit, static_argnums=(1, 5, 6, 7))
def _scan_decode(params, cfg: ModelConfig, last_logits, caches, key,
                 steps: int, temperature: float, stop_at_eos: bool,
                 positions, done):
    """One fused decode segment: sample -> emit (token, YES/NO) -> step.

    Carries (last_logits, caches, done, key) across ``steps`` scan steps;
    ``positions`` is the per-row (b,) count of tokens already cached at
    segment start, so row i's token at segment step t lands at absolute
    position ``positions[i] + t``.  Per-step outputs are the sampled token
    ids (b,) and the decision logit pair (b, 2).  Nothing of size V escapes
    the scan.  Returns the full carry so segments can be chained.
    """
    COMPILE_COUNTS["scan_decode"] += 1      # traced once per compilation
    dec_ix = jnp.asarray(DECISION_TOKENS, jnp.int32)

    def step(carry, t):
        logits, kv, dn, k = carry
        if temperature > 0.0:
            k, sub = jax.random.split(k)
            nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = jnp.where(dn, PAD, nxt).astype(jnp.int32)
        dec = logits[:, dec_ix]                          # (b, 2)
        if stop_at_eos:
            dn = dn | (nxt == EOS)
        new_logits, kv = M.decode_step(params, cfg, nxt[:, None], kv,
                                       positions + t)
        new_logits = new_logits[:, 0].astype(jnp.float32)
        return (new_logits, kv, dn, k), (nxt, dec)

    init = (last_logits, caches, done, key)
    (last, kv, done, key), (gen, dec) = jax.lax.scan(step, init,
                                                     jnp.arange(steps))
    # (b, T), (b, T, 2), + carry for the next segment
    return gen.T, dec.transpose(1, 0, 2), last, kv, done, key


# ---------------------------------------------------------------------------
# DecodeState: explicit decode carry between scan segments
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DecodeState:
    """Decode carry between scan segments (slot-based continuous batching).

    ``positions[i]`` counts the tokens already in row i's cache; ``done``
    marks rows that emitted EOS (they keep decoding PAD at zero semantic
    cost until refilled or the batch retires).  ``used`` is a host-side
    upper bound on cache occupancy, checked against ``max_len`` before a
    segment runs off the end of the allocation.
    """
    caches: Any
    last_logits: jax.Array          # (b, V) float32
    positions: jax.Array            # (b,) int32
    done: jax.Array                 # (b,) bool
    key: Optional[jax.Array]        # carried sampling key (None = greedy)
    max_len: int                    # per-row cache capacity (slots)
    used: int                       # host upper bound of max(positions)

    @property
    def batch(self) -> int:
        return int(self.last_logits.shape[0])


def prefill_state(params, cfg: ModelConfig, prompts, *,
                  max_new_tokens: int, prompt_lens=None,
                  rng: Optional[jax.Array] = None) -> DecodeState:
    """Batch prefill into a ``DecodeState`` sized for ``max_new_tokens``.

    ``prompts``: (b, L) int32, right-padded.  ``prompt_lens`` (b,) gives
    each row's true length; row i then decodes from position
    ``prompt_lens[i]`` with attention masked at its own valid length, so a
    sub-bucket row reproduces the unpadded run exactly (attention
    backbones).  ``None`` means every row is exactly L long.
    """
    prompts = jnp.asarray(prompts, jnp.int32)
    b, lp = prompts.shape
    max_len = lp + int(max_new_tokens)
    logits, caches = _prefill(params, cfg, prompts)
    caches = _pad_caches(caches, max_len, lp)
    if prompt_lens is None:
        last = logits[:, -1].astype(jnp.float32)
        positions = jnp.full((b,), lp, jnp.int32)
    else:
        lens = np.asarray(prompt_lens, np.int64).reshape(-1)
        if lens.shape != (b,):
            raise ValueError(f"prompt_lens shape {lens.shape} != ({b},)")
        if lens.min() < 1 or lens.max() > lp:
            raise ValueError(
                f"prompt_lens must lie in [1, {lp}], got "
                f"[{lens.min()}, {lens.max()}]")
        if lens.min() < lp and cfg.has_ssm():
            # SSM/conv prefill has no per-row masking: the recurrent state
            # consumes right-pad tokens, silently corrupting sub-bucket
            # rows.  Loud failure beats wrong routing decisions.
            raise ValueError(
                "ragged prompt_lens require an attention-only backbone: "
                f"{cfg.name!r} has SSM/conv layers whose prefill state "
                "consumes right-pad tokens — use exact-fit lengths "
                "(BucketConfig(prompt_lens=()))")
        positions = jnp.asarray(lens, jnp.int32)
        last = _gather_last(logits, positions)
    return DecodeState(caches, last, positions,
                       done=jnp.zeros((b,), bool), key=rng,
                       max_len=max_len, used=lp)


def decode_segment(params, cfg: ModelConfig, state: DecodeState, steps: int,
                   *, temperature: float = 0.0, stop_at_eos: bool = True
                   ) -> Tuple[DecodeState, jax.Array, jax.Array]:
    """Run ``steps`` decode steps; returns (state, gen (b, T), dec (b, T, 2)).

    ``gen``/``dec`` are device arrays — the caller decides when to block
    (``np.asarray``), which is what lets the serve runtime overlap host
    assembly with device decode.  Chaining segments is bit-identical to one
    segment of the summed length (the scan body is unchanged and the
    sampling key is carried).
    """
    steps = int(steps)
    if steps <= 0:
        raise ValueError(f"steps must be positive, got {steps}")
    if state.used + steps > state.max_len:
        raise ValueError(
            f"segment of {steps} steps overruns the cache: "
            f"{state.used} used of {state.max_len} slots")
    if temperature > 0.0 and state.key is None:
        raise ValueError(
            "stochastic decoding (temperature > 0) requires an explicit "
            "rng key — the old PRNGKey(0) fallback made every call sample "
            "the identical key stream")
    key = state.key if state.key is not None else jax.random.PRNGKey(0)
    gen, dec, last, caches, done, key = _scan_decode(
        params, cfg, state.last_logits, state.caches, key, steps,
        float(temperature), bool(stop_at_eos), state.positions, state.done)
    new = DecodeState(caches, last, state.positions + steps, done,
                      key if state.key is not None else None,
                      state.max_len, state.used + steps)
    return new, gen, dec


def refill_slot(params, cfg: ModelConfig, state: DecodeState, row: int,
                prompt: Sequence[int]) -> DecodeState:
    """Admit a new prompt into slot ``row`` between decode segments.

    Prefills the prompt alone, scatters its caches into the batch state at
    ``row`` (every decode-cache leaf carries batch on ``CACHE_BATCH_AXIS``),
    and resets the row's position/done/logits — the other rows are
    untouched, so the refilled batch keeps decoding them bit-identically.
    Pad ``prompt`` to a warmed bucket length to avoid a fresh prefill
    executable.
    """
    arr = np.asarray(prompt, np.int32).reshape(1, -1)
    lp = arr.shape[1]
    if not 0 <= row < state.batch:
        raise ValueError(f"row {row} out of range [0, {state.batch})")
    if lp >= state.max_len:
        raise ValueError(
            f"refill prompt of {lp} tokens leaves no decode room in a "
            f"{state.max_len}-slot cache")
    logits, caches = _prefill(params, cfg, jnp.asarray(arr))
    caches = _pad_caches(caches, state.max_len, lp)
    merged = jax.tree.map(
        lambda full, one: full.at[:, row].set(one[:, 0].astype(full.dtype)),
        state.caches, caches)
    return dataclasses.replace(
        state,
        caches=merged,
        last_logits=state.last_logits.at[row].set(
            logits[0, -1].astype(jnp.float32)),
        positions=state.positions.at[row].set(lp),
        done=state.done.at[row].set(False),
        used=max(state.used, lp))


# ---------------------------------------------------------------------------
# One-shot generation (prefill + a single decode segment)
# ---------------------------------------------------------------------------
def generate_async(params, cfg: ModelConfig, prompts, *,
                   max_new_tokens: int = 12, temperature: float = 0.0,
                   rng: Optional[jax.Array] = None, stop_at_eos: bool = True,
                   prompt_lens=None) -> Tuple[jax.Array, jax.Array]:
    """``generate`` without the host sync: returns device arrays so the
    caller can keep assembling the next microbatch while this one decodes
    (double-buffered dispatch blocks only at parse time)."""
    if temperature > 0.0 and rng is None:
        raise ValueError(
            "generate(temperature > 0) requires an explicit rng key — the "
            "old PRNGKey(0) fallback made every stochastic call sample the "
            "identical key stream; pass rng=jax.random.PRNGKey(...) "
            "(greedy decoding stays deterministic without one)")
    state = prefill_state(params, cfg, prompts,
                          max_new_tokens=max_new_tokens,
                          prompt_lens=prompt_lens, rng=rng)
    _, gen, dec = decode_segment(params, cfg, state, max_new_tokens,
                                 temperature=temperature,
                                 stop_at_eos=stop_at_eos)
    return gen, dec


def generate(params, cfg: ModelConfig, prompts: np.ndarray, *,
             max_new_tokens: int = 12, temperature: float = 0.0,
             rng: Optional[jax.Array] = None, stop_at_eos: bool = True,
             prompt_lens=None) -> Tuple[np.ndarray, np.ndarray]:
    """prompts: (b, Lp) int32, right-padded; ``prompt_lens`` (b,) marks
    each row's true length (None = all exactly Lp).  Returns
    (generated (b, T) int32, decision_logits (b, T, 2) float32) where the
    last axis is the (YES, NO) logit pair at each step — the only slice of
    the vocab distribution the estimator reads."""
    gen, dec = generate_async(params, cfg, prompts,
                              max_new_tokens=max_new_tokens,
                              temperature=temperature, rng=rng,
                              stop_at_eos=stop_at_eos,
                              prompt_lens=prompt_lens)
    return np.asarray(gen), np.asarray(dec)
