"""KV-cache generation: batch prefill + fused jitted decode scan segments.

Decode is organised around an explicit ``DecodeState`` (caches, per-row
positions, done-mask, carried sampling key) so the serve runtime can run
decode in **chunked scan segments** and refill a drained-at-EOS slot with a
freshly prefilled prompt between segments (continuous batching) instead of
idling the slot until the batch finishes:

  state = prefill_state(params, cfg, prompts, max_new_tokens=12)
  state, gen, dec = decode_segment(params, cfg, state, 4)
  state = refill_slot(params, cfg, state, row=2, prompt=new_prompt)
  state, gen2, dec2 = decode_segment(params, cfg, state, 4)

``refill_slots`` is the batched form the serve runtime uses: every slot
drained at one segment boundary refills with a single prefill call,
padded to the warmed (b, L) executable shape with true per-prompt
lengths.

Positions are **per row**: rows at different sequence offsets (ragged
prompt lengths under a bucket grid, refilled slots mid-decode) share one
compiled decode executable, and sub-bucket rows reproduce an unpadded run
exactly — attention masks each row at its own valid length and RoPE rotates
at each row's own position.  (Exactness holds for attention backbones;
SSM/conv states consume right-pad tokens during prefill, so keep exact-fit
lengths for those.)

Each scan segment samples on device (greedy or temperature), carries an
EOS done-mask, and only what the estimator consumes crosses back to the
host — generated token ids plus the YES/NO logit pair at each step.  The
full ``(b, T, V)`` logits stack never leaves the device.

``COMPILE_COUNTS`` counts executable builds explicitly (incremented inside
the traced bodies, once per compilation) — the serve path's "0 recompiles
after warmup" gate reads it instead of sniffing jit internals.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import Counter
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import EOS, NO, PAD, YES
from repro.models import model as M
from repro.serving.kv_pool import (KVPool, PagedKV, _ceil_div,
                                   check_paged_support)
from repro.kernels.decode_attention import KernelType

# decision-logit channel order: [:, :, 0] = YES, [:, :, 1] = NO
DECISION_TOKENS = (YES, NO)

# Explicit compile-count instrumentation: the jitted bodies below increment
# these counters at trace time, which happens exactly once per compiled
# (shape, dtype, static-arg) combination.  Process-global and monotonic —
# diff two snapshots to count the compiles of a traffic window.
COMPILE_COUNTS: "Counter[str]" = Counter()


@functools.partial(jax.jit, static_argnums=(1,))
def _prefill(params, cfg: ModelConfig, tokens):
    COMPILE_COUNTS["prefill"] += 1          # traced once per compilation
    return M.prefill(params, cfg, {"tokens": tokens})


@jax.jit
def _gather_last(logits, lens):
    """Per-row last *valid* prompt logits: logits[i, lens[i] - 1]."""
    idx = (lens - 1).astype(jnp.int32)[:, None, None]
    return jnp.take_along_axis(logits, idx, axis=1)[:, 0].astype(jnp.float32)


# Explicit seq-axis contract for decode caches, keyed by leaf name.  The
# axis index includes the leading layer-stack dim the segment scan adds:
#   k / v  : (L, b, kv_heads, S, head_dim)  -> axis 3
#   c_kv   : (L, b, S, kv_lora_rank)        -> axis 2
#   k_rope : (L, b, S, qk_rope_head_dim)    -> axis 2
# Everything else (mamba conv/ssm states, ck/cv encoder cross caches) has no
# decode-time sequence axis and must never be grown, whatever its shape.
CACHE_SEQ_AXIS = {"k": 3, "v": 3, "c_kv": 2, "k_rope": 2}

# every decode-cache leaf carries batch on axis 1 (behind the layer stack);
# ``refill_slot`` relies on this to scatter one prefilled row into place
CACHE_BATCH_AXIS = 1


def _leaf_name(path) -> str:
    entry = path[-1]
    if hasattr(entry, "key"):
        return str(entry.key)
    return str(entry)


def _pad_caches(caches, max_len: int, prompt_len: int):
    """Grow prefill caches (seq = prompt_len) to decode capacity.

    The sequence axis comes from the cache *structure* (leaf name ->
    ``CACHE_SEQ_AXIS``), never from sniffing shapes: a head count, conv
    width, or SSM state dim that happens to equal ``prompt_len`` must not
    be padded — growing the wrong axis silently corrupts decode.
    """
    def grow(path, leaf):
        ax = CACHE_SEQ_AXIS.get(_leaf_name(path))
        if ax is None:
            return leaf
        if leaf.shape[ax] != prompt_len:
            raise ValueError(
                f"cache leaf {_leaf_name(path)!r} has seq axis "
                f"{leaf.shape[ax]} != prompt_len {prompt_len} "
                f"(shape {leaf.shape})")
        widths = [(0, 0)] * leaf.ndim
        widths[ax] = (0, max_len - prompt_len)
        return jnp.pad(leaf, widths)

    return jax.tree_util.tree_map_with_path(grow, caches)


def _run_scan(params, cfg: ModelConfig, last_logits, caches, key,
              steps: int, temperature: float, stop_at_eos: bool,
              positions, done, paged=None):
    """Traced scan body shared by ``_scan_decode`` / ``_refill_scan_decode``
    and their paged twins: sample -> emit (token, YES/NO) -> step, for
    ``steps`` steps.  ``paged`` = (PagedSpec, page table) reroutes the KV
    writes/reads through the block-paged layout; the sampling math is
    byte-for-byte the same code path."""
    dec_ix = jnp.asarray(DECISION_TOKENS, jnp.int32)

    def step(carry, t):
        logits, kv, dn, k = carry
        if temperature > 0.0:
            k, sub = jax.random.split(k)
            nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = jnp.where(dn, PAD, nxt).astype(jnp.int32)
        dec = logits[:, dec_ix]                          # (b, 2)
        if stop_at_eos:
            dn = dn | (nxt == EOS)
        new_logits, kv = M.decode_step(params, cfg, nxt[:, None], kv,
                                       positions + t, paged=paged)
        new_logits = new_logits[:, 0].astype(jnp.float32)
        return (new_logits, kv, dn, k), (nxt, dec)

    init = (last_logits, caches, done, key)
    (last, kv, done, key), (gen, dec) = jax.lax.scan(step, init,
                                                     jnp.arange(steps))
    # (b, T), (b, T, 2), + carry for the next segment
    return gen.T, dec.transpose(1, 0, 2), last, kv, done, key


# no donate_argnums on the caches: XLA reports the KV buffers as unusable
# donations for a scan carry (they are not jit outputs), so donating would
# only emit a warning per call without saving the copy
@functools.partial(jax.jit, static_argnums=(1, 5, 6, 7))
def _scan_decode(params, cfg: ModelConfig, last_logits, caches, key,
                 steps: int, temperature: float, stop_at_eos: bool,
                 positions, done):
    """One fused decode segment.

    ``positions`` is the per-row (b,) count of tokens already cached at
    segment start, so row i's token at segment step t lands at absolute
    position ``positions[i] + t``.  Per-step outputs are the sampled token
    ids (b,) and the decision logit pair (b, 2).  Nothing of size V escapes
    the scan.  Returns the full carry so segments can be chained.
    """
    COMPILE_COUNTS["scan_decode"] += 1      # traced once per compilation
    return _run_scan(params, cfg, last_logits, caches, key, steps,
                     temperature, stop_at_eos, positions, done)


def _grow_to(path, leaf, ref):
    """Pad a prefill cache leaf's seq axis up to ``ref``'s (traced-safe)."""
    ax = CACHE_SEQ_AXIS.get(_leaf_name(path))
    if ax is None:
        return leaf
    widths = [(0, 0)] * leaf.ndim
    widths[ax] = (0, ref.shape[ax] - leaf.shape[ax])
    return jnp.pad(leaf, widths)


def _check_refill_lens(cfg: ModelConfig, state: "DecodeState", width: int,
                       lens: np.ndarray) -> None:
    """Shared refill-prompt guards (fused and unfused paths must accept
    exactly the same inputs): true lengths in [1, width], attention-only
    backbones when padded, and decode room left in the slot cache."""
    if lens.min() < 1 or lens.max() > width:
        raise ValueError(
            f"prompt_lens must lie in [1, {width}], got "
            f"[{lens.min()}, {lens.max()}]")
    if lens.min() < width and cfg.has_ssm():
        raise ValueError(
            "padded refill requires an attention-only backbone: "
            f"{cfg.name!r} has SSM/conv layers whose prefill state consumes "
            "right-pad tokens — refill at the exact prompt length instead")
    if lens.max() >= state.max_len or width > state.max_len:
        raise ValueError(
            f"refill prompt of {lens.max()} tokens (padded to {width}) "
            f"leaves no decode room in a {state.max_len}-slot cache")


@functools.partial(jax.jit, static_argnums=(1, 5, 6, 7))
def _refill_scan_decode(params, cfg: ModelConfig, last_logits, caches, key,
                        steps: int, temperature: float, stop_at_eos: bool,
                        positions, done, refill_mask, refill_prompts,
                        refill_lens):
    """``_scan_decode`` with slot refill fused into the same executable.

    ``refill_prompts`` is a **slot-aligned** (b, W) token matrix: row i
    replaces slot i's request iff ``refill_mask[i]``; ``refill_lens`` (b,)
    gives each refill prompt's true length (ignored where the mask is
    False).  The prompts are prefilled, their caches grown to decode
    capacity and merged under the mask, and the masked rows' position /
    done / last-logits reset — then the segment scan runs.  One executable
    launch admits every slot drained at a boundary *and* decodes the next
    segment; the per-row math is identical to a separate
    ``refill_slots`` + ``_scan_decode`` pair (asserted bit-exactly in the
    tests), the fusion only removes per-boundary launch overhead.
    """
    COMPILE_COUNTS["refill_scan_decode"] += 1   # traced once per compile
    logits, new_caches = M.prefill(params, cfg, {"tokens": refill_prompts})
    new_caches = jax.tree_util.tree_map_with_path(_grow_to, new_caches,
                                                  caches)

    def merge(old, new):
        shape = [1] * old.ndim
        shape[CACHE_BATCH_AXIS] = old.shape[CACHE_BATCH_AXIS]
        return jnp.where(refill_mask.reshape(shape), new.astype(old.dtype),
                         old)

    caches = jax.tree.map(merge, caches, new_caches)
    idx = (refill_lens - 1).astype(jnp.int32)[:, None, None]
    last_new = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
    last_logits = jnp.where(refill_mask[:, None],
                            last_new.astype(jnp.float32), last_logits)
    positions = jnp.where(refill_mask, refill_lens.astype(jnp.int32),
                          positions)
    done = jnp.where(refill_mask, False, done)
    out = _run_scan(params, cfg, last_logits, caches, key, steps,
                    temperature, stop_at_eos, positions, done)
    return out + (positions,)


# ---------------------------------------------------------------------------
# Paged twins: prefill-scatter + decode over the block-paged KV layout
# ---------------------------------------------------------------------------
def _paged_leaf_scatter(leaf, storage, page_ids, page_size: int):
    """Scatter a dense prefill leaf (count, b, hkv, L, hd) into paged
    storage (count, n_pages + 1, hkv, page_size, hd) at the flattened
    (b * ceil(L / page_size),) physical destinations ``page_ids``.

    Pad/filler blocks all target the trash page; their writes collide
    there in nondeterministic order, which is unobservable — trash reads
    are always masked to exact-zero probability or belong to discarded
    rows — so the scatter must not claim unique indices.
    """
    count, b, hkv, L, hd = leaf.shape
    npg = page_ids.shape[0] // b
    pad = npg * page_size - L
    if pad:
        leaf = jnp.pad(leaf, [(0, 0), (0, 0), (0, 0), (0, pad), (0, 0)])
    blocks = leaf.reshape(count, b, hkv, npg, page_size, hd)
    blocks = blocks.transpose(0, 1, 3, 2, 4, 5).reshape(
        count, b * npg, hkv, page_size, hd)
    return storage.at[:, page_ids].set(blocks.astype(storage.dtype))


def _scatter_prefill_caches(caches, storage_of, page_ids, page_size: int):
    """Tree-map the page scatter over the k/v cache leaves.

    ``check_paged_support`` guarantees every decode-cache leaf is a GQA
    k/v pair, so anything else here is a bug, not a user error.
    """
    def scatter(path, leaf):
        name = _leaf_name(path)
        if name not in ("k", "v"):
            raise AssertionError(
                f"paged scatter hit non-GQA cache leaf {name!r}")
        return _paged_leaf_scatter(leaf, storage_of(path, leaf), page_ids,
                                   page_size)

    return jax.tree_util.tree_map_with_path(scatter, caches)


@functools.partial(jax.jit, static_argnums=(1, 3, 4))
def _paged_prefill(params, cfg: ModelConfig, tokens, n_pages_total: int,
                   page_size: int, page_ids):
    """Prefill + scatter into **fresh** paged storage.

    ``page_ids`` (b * npg,) maps each row's prompt page blocks to the
    physical pages its table owns (trash for inactive rows / pad blocks).
    Storage is (count, n_pages_total, hkv, page_size, hd) per leaf with
    the trash page at index n_pages_total - 1.
    """
    COMPILE_COUNTS["paged_prefill"] += 1    # traced once per compilation
    logits, caches = M.prefill(params, cfg, {"tokens": tokens})

    def storage_of(path, leaf):
        count, _, hkv, _, hd = leaf.shape
        return jnp.zeros((count, n_pages_total, hkv, page_size, hd),
                         leaf.dtype)

    return logits, _scatter_prefill_caches(caches, storage_of, page_ids,
                                           page_size)


@functools.partial(jax.jit, static_argnums=(1, 3))
def _paged_refill_prefill(params, cfg: ModelConfig, tokens, page_size: int,
                          page_ids, caches):
    """Prefill + scatter into **existing** paged storage (unfused refill).

    Refilled rows' destinations are freshly allocated pages and everything
    else targets trash, so live rows' pages are untouched — the paged
    analogue of the dense per-row cache merge.
    """
    COMPILE_COUNTS["paged_refill_prefill"] += 1
    logits, new = M.prefill(params, cfg, {"tokens": tokens})

    flat_cache = {}

    def name_leaf(path, leaf):
        flat_cache[jax.tree_util.keystr(path)] = leaf
        return leaf

    jax.tree_util.tree_map_with_path(name_leaf, caches)

    def storage_of(path, leaf):
        return flat_cache[jax.tree_util.keystr(path)]

    return logits, _scatter_prefill_caches(new, storage_of, page_ids,
                                           page_size)


@functools.partial(jax.jit, static_argnums=(1, 5, 6, 7, 8))
def _paged_scan_decode(params, cfg: ModelConfig, last_logits, caches, key,
                       steps: int, temperature: float, stop_at_eos: bool,
                       spec, table, positions, done):
    """``_scan_decode`` over the paged layout.  ``spec`` (static) carries
    page_size / kv_cap / kernel; ``table`` is the traced (b, W) page
    table pushed fresh each segment — its shape is constant per batch, so
    table updates never recompile."""
    COMPILE_COUNTS["paged_scan_decode"] += 1
    return _run_scan(params, cfg, last_logits, caches, key, steps,
                     temperature, stop_at_eos, positions, done,
                     paged=(spec, table))


@functools.partial(jax.jit, static_argnums=(1, 5, 6, 7, 8))
def _paged_refill_scan_decode(params, cfg: ModelConfig, last_logits, caches,
                              key, steps: int, temperature: float,
                              stop_at_eos: bool, spec, table, positions,
                              done, refill_mask, refill_prompts,
                              refill_lens, refill_page_ids):
    """``_refill_scan_decode`` over the paged layout: prefill the refill
    prompts, scatter their page blocks into the pool storage (masked-out
    rows scatter to trash), reset the masked rows, then run the segment."""
    COMPILE_COUNTS["paged_refill_scan_decode"] += 1
    logits, new = M.prefill(params, cfg, {"tokens": refill_prompts})

    flat_cache = {}

    def name_leaf(path, leaf):
        flat_cache[jax.tree_util.keystr(path)] = leaf
        return leaf

    jax.tree_util.tree_map_with_path(name_leaf, caches)
    caches = _scatter_prefill_caches(
        new, lambda path, leaf: flat_cache[jax.tree_util.keystr(path)],
        refill_page_ids, spec.page_size)

    idx = (refill_lens - 1).astype(jnp.int32)[:, None, None]
    last_new = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
    last_logits = jnp.where(refill_mask[:, None],
                            last_new.astype(jnp.float32), last_logits)
    positions = jnp.where(refill_mask, refill_lens.astype(jnp.int32),
                          positions)
    done = jnp.where(refill_mask, False, done)
    out = _run_scan(params, cfg, last_logits, caches, key, steps,
                    temperature, stop_at_eos, positions, done,
                    paged=(spec, table))
    return out + (positions,)


# ---------------------------------------------------------------------------
# DecodeState: explicit decode carry between scan segments
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DecodeState:
    """Decode carry between scan segments (slot-based continuous batching).

    ``positions[i]`` counts the tokens already in row i's cache; ``done``
    marks rows that emitted EOS (they keep decoding PAD at zero semantic
    cost until refilled or the batch retires).  ``used`` is a host-side
    upper bound on cache occupancy, checked against ``max_len`` before a
    segment runs off the end of the allocation.

    ``paged`` (a ``kv_pool.PagedKV``) switches the caches to the
    block-paged layout: ``max_len`` then equals the paged ``kv_cap`` and
    the batch-wide ``used`` guard is replaced by the attachment's per-row
    ``row_high`` bound — a drained row's pages return to the pool instead
    of idling until the whole batch retires.
    """
    caches: Any
    last_logits: jax.Array          # (b, V) float32
    positions: jax.Array            # (b,) int32
    done: jax.Array                 # (b,) bool
    key: Optional[jax.Array]        # carried sampling key (None = greedy)
    max_len: int                    # per-row cache capacity (slots)
    used: int                       # host upper bound of max(positions)
    paged: Optional[PagedKV] = None

    @property
    def batch(self) -> int:
        return int(self.last_logits.shape[0])


def prefill_state(params, cfg: ModelConfig, prompts, *,
                  max_new_tokens: int, prompt_lens=None,
                  rng: Optional[jax.Array] = None,
                  kv_pool: Optional[KVPool] = None,
                  kv_kernel: KernelType = KernelType.XLA,
                  kv_active=None) -> DecodeState:
    """Batch prefill into a ``DecodeState`` sized for ``max_new_tokens``.

    ``prompts``: (b, L) int32, right-padded.  ``prompt_lens`` (b,) gives
    each row's true length; row i then decodes from position
    ``prompt_lens[i]`` with attention masked at its own valid length, so a
    sub-bucket row reproduces the unpadded run exactly (attention
    backbones).  ``None`` means every row is exactly L long.

    ``kv_pool`` backs the state with the block-paged KV layout instead of
    a dense O(b x max_len) allocation: each admitted row reserves its own
    worst case (``len + max_new_tokens`` tokens, page-rounded) and pages
    materialize only as positions advance.  ``kv_active`` (b,) bool marks
    the rows to admit (None = all); inactive rows own no pages — their
    tables point at the trash page and their decoded tokens are garbage
    to discard, exactly like a dense free slot.  ``kv_kernel`` selects the
    paged attention implementation (``KernelType.XLA`` is bit-identical
    to dense; PALLAS is the TPU kernel, interpreted on CPU).
    """
    prompts = jnp.asarray(prompts, jnp.int32)
    b, lp = prompts.shape
    max_len = lp + int(max_new_tokens)
    if prompt_lens is None:
        lens = None
    else:
        lens = np.asarray(prompt_lens, np.int64).reshape(-1)
        if lens.shape != (b,):
            raise ValueError(f"prompt_lens shape {lens.shape} != ({b},)")
        if lens.min() < 1 or lens.max() > lp:
            raise ValueError(
                f"prompt_lens must lie in [1, {lp}], got "
                f"[{lens.min()}, {lens.max()}]")
        if lens.min() < lp and cfg.has_ssm():
            # SSM/conv prefill has no per-row masking: the recurrent state
            # consumes right-pad tokens, silently corrupting sub-bucket
            # rows.  Loud failure beats wrong routing decisions.
            raise ValueError(
                "ragged prompt_lens require an attention-only backbone: "
                f"{cfg.name!r} has SSM/conv layers whose prefill state "
                "consumes right-pad tokens — use exact-fit lengths "
                "(BucketConfig(prompt_lens=()))")

    paged = None
    if kv_pool is not None:
        check_paged_support(cfg)
        if kv_pool.page_size > max_len:
            raise ValueError(
                f"kv_page_size {kv_pool.page_size} exceeds the row "
                f"capacity {max_len} — a page would never fill")
        paged = kv_pool.attach(b, kv_cap=max_len,
                               budget_steps=int(max_new_tokens),
                               kernel=kv_kernel)
        active = (np.ones((b,), bool) if kv_active is None
                  else np.asarray(kv_active, bool).reshape(-1))
        if active.shape != (b,):
            raise ValueError(f"kv_active shape {active.shape} != ({b},)")
        row_lens = np.full((b,), lp, np.int64) if lens is None else lens
        for i in np.flatnonzero(active):
            paged.admit_row(int(i), int(row_lens[i]))
        npg = _ceil_div(lp, paged.page_size)
        ids = jnp.asarray(paged.prompt_page_ids(active, npg).reshape(-1))
        logits, caches = _paged_prefill(params, cfg, prompts,
                                        kv_pool.n_pages + 1,
                                        paged.page_size, ids)
    else:
        logits, caches = _prefill(params, cfg, prompts)
        caches = _pad_caches(caches, max_len, lp)

    if lens is None:
        last = logits[:, -1].astype(jnp.float32)
        positions = jnp.full((b,), lp, jnp.int32)
    else:
        positions = jnp.asarray(lens, jnp.int32)
        last = _gather_last(logits, positions)
    return DecodeState(caches, last, positions,
                       done=jnp.zeros((b,), bool), key=rng,
                       max_len=max_len, used=lp, paged=paged)


def decode_segment(params, cfg: ModelConfig, state: DecodeState, steps: int,
                   *, temperature: float = 0.0, stop_at_eos: bool = True,
                   refill: Optional[Tuple] = None
                   ) -> Tuple[DecodeState, jax.Array, jax.Array]:
    """Run ``steps`` decode steps; returns (state, gen (b, T), dec (b, T, 2)).

    ``gen``/``dec`` are device arrays — the caller decides when to block
    (``np.asarray``), which is what lets the serve runtime overlap host
    assembly with device decode.  Chaining segments is bit-identical to one
    segment of the summed length (the scan body is unchanged and the
    sampling key is carried).

    ``refill`` = (mask (b,), prompts (b, W), prompt_lens (b,)) admits new
    requests into the masked slots **in the same executable launch**: the
    slot-aligned prompts are prefilled (right-padded to width W, true
    lengths in ``prompt_lens``) and the masked rows reset to decode from
    their own prompt before the segment runs — bit-identical to
    ``refill_slots`` followed by a plain segment, minus the per-boundary
    launch overhead.  The same attention-backbone restriction applies to
    padded refill prompts.
    """
    steps = int(steps)
    pg = state.paged
    if steps <= 0:
        raise ValueError(f"steps must be positive, got {steps}")
    if pg is not None:
        # per-row bound: a paged batch has no shared horizon, each live
        # row just needs `steps` more slots under its own kv_cap.  With a
        # refill the guard runs again after the drained rows are retired
        # and re-admitted below.
        if refill is None:
            pg.check_steps(steps)
    elif state.used + steps > state.max_len:
        raise ValueError(
            f"segment of {steps} steps overruns the cache: "
            f"{state.used} used of {state.max_len} slots")
    if temperature > 0.0 and state.key is None:
        raise ValueError(
            "stochastic decoding (temperature > 0) requires an explicit "
            "rng key — the old PRNGKey(0) fallback made every call sample "
            "the identical key stream")
    # scopelint: allow[serve-time-nondeterminism] -- greedy placeholder: temperature > 0 without a carried key raises above, so this key is never sampled from
    key = state.key if state.key is not None else jax.random.PRNGKey(0)
    if refill is None:
        if pg is not None:
            pg.ensure(steps)
            gen, dec, last, caches, done, key = _paged_scan_decode(
                params, cfg, state.last_logits, state.caches, key, steps,
                float(temperature), bool(stop_at_eos), pg.spec,
                pg.device_table(), state.positions, state.done)
        else:
            gen, dec, last, caches, done, key = _scan_decode(
                params, cfg, state.last_logits, state.caches, key, steps,
                float(temperature), bool(stop_at_eos), state.positions,
                state.done)
        positions = state.positions
        used = state.used
    else:
        mask, prompts, lens = refill
        mask = np.asarray(mask, bool).reshape(-1)
        prompts = np.asarray(prompts, np.int32)
        b = state.batch
        if mask.shape != (b,) or prompts.ndim != 2 or prompts.shape[0] != b:
            raise ValueError(
                f"refill mask/prompts must be ({b},)/({b}, W), got "
                f"{mask.shape}/{prompts.shape}")
        width = prompts.shape[1]
        lens = (np.full((b,), width, np.int64) if lens is None
                else np.asarray(lens, np.int64).reshape(-1))
        if lens.shape != (b,):
            raise ValueError(f"prompt_lens shape {lens.shape} != ({b},)")
        if not mask.any():
            raise ValueError("refill mask selects no rows — pass "
                             "refill=None for a plain segment")
        _check_refill_lens(cfg, state, width, lens[mask])
        mlens = lens[mask]
        lens = np.where(mask, lens, 1)      # unmasked rows: any valid index
        if pg is not None:
            # host-side admission before the launch: release whatever the
            # refilled slots still hold (no-op if the serve loop retired
            # them at sync), then allocate their prompt pages
            for i in np.flatnonzero(mask):
                if pg.row_preadmitted[i]:
                    pg.row_preadmitted[i] = False   # reserved at admit time
                else:
                    pg.retire_row(int(i))
                    pg.admit_row(int(i), int(lens[i]))
            pg.check_steps(steps)
            npg = _ceil_div(width, pg.page_size)
            ids = jnp.asarray(pg.prompt_page_ids(mask, npg).reshape(-1))
            pg.ensure(steps)
            (gen, dec, last, caches, done, key,
             positions) = _paged_refill_scan_decode(
                params, cfg, state.last_logits, state.caches, key, steps,
                float(temperature), bool(stop_at_eos), pg.spec,
                pg.device_table(), state.positions, state.done,
                jnp.asarray(mask), jnp.asarray(prompts),
                jnp.asarray(lens, jnp.int32), ids)
        else:
            gen, dec, last, caches, done, key, positions = \
                _refill_scan_decode(
                    params, cfg, state.last_logits, state.caches, key, steps,
                    float(temperature), bool(stop_at_eos), state.positions,
                    state.done, jnp.asarray(mask), jnp.asarray(prompts),
                    jnp.asarray(lens, jnp.int32))
        used = max(state.used, int(mlens.max()))
    new = DecodeState(caches, last, positions + steps, done,
                      key if state.key is not None else None,
                      state.max_len, used + steps, paged=pg)
    return new, gen, dec


def refill_slots(params, cfg: ModelConfig, state: DecodeState,
                 rows: Sequence[int], prompts, *,
                 prompt_lens: Optional[Sequence[int]] = None) -> DecodeState:
    """Admit new prompts into slots ``rows`` between decode segments.

    ``prompts`` is a (p, W) int token matrix with p >= r = len(rows): the
    first r rows are the refilled prompts (right-padded to a common width
    W), trailing rows are all-PAD filler so the matrix can match a warmed
    prefill shape — the slot batch's own (b, L) is always warm, so a refill
    boundary costs **one** executable launch however many slots drain
    together.  ``prompt_lens`` gives each refilled prompt's true length
    (None = exactly W).  Each prompt's caches are scattered
    into the batch state at its row (every decode-cache leaf carries batch
    on ``CACHE_BATCH_AXIS``) and the row's position/done/logits reset —
    the other rows are untouched, so the refilled batch keeps decoding
    them bit-identically.  A refilled row decodes from its true length
    with attention masked there, so pad garbage in the cache tail is never
    attended (attention backbones only — SSM prefill state consumes the
    pads, exactly as in ``prefill_state``).
    """
    arr = np.asarray(prompts, np.int32)
    if arr.ndim != 2:
        raise ValueError(f"prompts must be (p, W), got {arr.shape}")
    p, width = arr.shape
    rows = np.asarray(rows, np.int32).reshape(-1)
    r = rows.shape[0]
    if r > p:
        raise ValueError(f"{r} rows for only {p} prompts")
    if r == 0:
        return state
    if len({int(x) for x in rows}) != r:
        raise ValueError(f"duplicate refill rows: {rows.tolist()}")
    if rows.min() < 0 or rows.max() >= state.batch:
        raise ValueError(
            f"rows {rows.tolist()} out of range [0, {state.batch})")
    lens = (np.full((r,), width, np.int64) if prompt_lens is None
            else np.asarray(prompt_lens, np.int64).reshape(-1))
    if lens.shape != (r,):
        raise ValueError(f"prompt_lens shape {lens.shape} != ({r},)")
    _check_refill_lens(cfg, state, width, lens)
    ridx = jnp.asarray(rows)
    if state.paged is not None:
        pg = state.paged
        for j, row in enumerate(rows):
            if pg.row_preadmitted[row]:
                pg.row_preadmitted[row] = False   # reserved at admit time
            else:
                pg.retire_row(int(row))
                pg.admit_row(int(row), int(lens[j]))
        npg = _ceil_div(width, pg.page_size)
        # prompt-row j's page blocks land in slot rows[j]'s fresh pages;
        # filler prompt rows (j >= r) scatter to trash
        ids = np.full((p, npg), pg.pool.trash_page, np.int32)
        for j, row in enumerate(rows):
            ids[j] = pg.table[row, :npg]
        logits, merged = _paged_refill_prefill(
            params, cfg, jnp.asarray(arr), pg.page_size,
            jnp.asarray(ids.reshape(-1)), state.caches)
    else:
        logits, caches = _prefill(params, cfg, jnp.asarray(arr))
        caches = _pad_caches(caches, state.max_len, width)
        merged = jax.tree.map(
            lambda full, new: full.at[:, ridx].set(
                new[:, :r].astype(full.dtype)),
            state.caches, caches)
    plens = jnp.asarray(lens, jnp.int32)
    # gather over the first r (real) prefilled rows only
    last = _gather_last(logits[:r], plens)              # (r, V) f32
    return dataclasses.replace(
        state,
        caches=merged,
        last_logits=state.last_logits.at[ridx].set(last),
        positions=state.positions.at[ridx].set(plens),
        done=state.done.at[ridx].set(False),
        used=max(state.used, int(lens.max())))


def refill_slot(params, cfg: ModelConfig, state: DecodeState, row: int,
                prompt: Sequence[int], *,
                prompt_len: Optional[int] = None) -> DecodeState:
    """Single-slot ``refill_slots``: admit one prompt into slot ``row``."""
    arr = np.asarray(prompt, np.int32).reshape(1, -1)
    return refill_slots(params, cfg, state, [row], arr,
                        prompt_lens=None if prompt_len is None
                        else [int(prompt_len)])


# ---------------------------------------------------------------------------
# One-shot generation (prefill + a single decode segment)
# ---------------------------------------------------------------------------
def generate_async(params, cfg: ModelConfig, prompts, *,
                   max_new_tokens: int = 12, temperature: float = 0.0,
                   rng: Optional[jax.Array] = None, stop_at_eos: bool = True,
                   prompt_lens=None) -> Tuple[jax.Array, jax.Array]:
    """``generate`` without the host sync: returns device arrays so the
    caller can keep assembling the next microbatch while this one decodes
    (double-buffered dispatch blocks only at parse time)."""
    if temperature > 0.0 and rng is None:
        raise ValueError(
            "generate(temperature > 0) requires an explicit rng key — the "
            "old PRNGKey(0) fallback made every stochastic call sample the "
            "identical key stream; pass rng=jax.random.PRNGKey(...) "
            "(greedy decoding stays deterministic without one)")
    state = prefill_state(params, cfg, prompts,
                          max_new_tokens=max_new_tokens,
                          prompt_lens=prompt_lens, rng=rng)
    _, gen, dec = decode_segment(params, cfg, state, max_new_tokens,
                                 temperature=temperature,
                                 stop_at_eos=stop_at_eos)
    return gen, dec


def generate(params, cfg: ModelConfig, prompts: np.ndarray, *,
             max_new_tokens: int = 12, temperature: float = 0.0,
             rng: Optional[jax.Array] = None, stop_at_eos: bool = True,
             prompt_lens=None) -> Tuple[np.ndarray, np.ndarray]:
    """prompts: (b, Lp) int32, right-padded; ``prompt_lens`` (b,) marks
    each row's true length (None = all exactly Lp).  Returns
    (generated (b, T) int32, decision_logits (b, T, 2) float32) where the
    last axis is the (YES, NO) logit pair at each step — the only slice of
    the vocab distribution the estimator reads."""
    gen, dec = generate_async(params, cfg, prompts,
                              max_new_tokens=max_new_tokens,
                              temperature=temperature, rng=rng,
                              stop_at_eos=stop_at_eos,
                              prompt_lens=prompt_lens)
    return np.asarray(gen), np.asarray(dec)
