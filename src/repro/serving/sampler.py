"""KV-cache generation loop: prefill + jitted single-token decode steps.

Prompts in SCOPE's structured serialization have constant length, so the
batch prefisll is a single full forward; decode steps are jitted with donated
caches.  Supports greedy and temperature sampling (GRPO rollouts) and
returns per-step logits (the estimator reads its correctness confidence off
the decision token's distribution).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import EOS, PAD
from repro.models import model as M


@functools.partial(jax.jit, static_argnums=(1,), donate_argnums=(3,))
def _decode_step(params, cfg: ModelConfig, token, caches, pos):
    logits, caches = M.decode_step(params, cfg, token, caches, pos)
    return logits[:, 0], caches


@functools.partial(jax.jit, static_argnums=(1,))
def _prefill(params, cfg: ModelConfig, tokens):
    return M.prefill(params, cfg, {"tokens": tokens})


def _pad_caches(caches, max_len: int, prompt_len: int):
    """Grow prefill caches (seq = prompt_len) to decode capacity."""
    def pad(path_leaf):
        return path_leaf

    def grow(leaf):
        # KV leaves have a seq axis == prompt_len somewhere; mamba states don't.
        shape = leaf.shape
        for ax, n in enumerate(shape):
            if n == prompt_len and ax >= 2:      # (count, b, ..., S, ...)
                widths = [(0, 0)] * leaf.ndim
                widths[ax] = (0, max_len - prompt_len)
                return jnp.pad(leaf, widths)
        return leaf

    return jax.tree.map(grow, caches)


def generate(params, cfg: ModelConfig, prompts: np.ndarray, *,
             max_new_tokens: int = 12, temperature: float = 0.0,
             rng: Optional[jax.Array] = None, stop_at_eos: bool = True
             ) -> Tuple[np.ndarray, np.ndarray]:
    """prompts: (b, Lp) int32, constant length.  Returns
    (generated (b, T) int32, step_logits (b, T, V) float32)."""
    prompts = jnp.asarray(prompts, jnp.int32)
    b, lp = prompts.shape
    max_len = lp + max_new_tokens

    logits, caches = _prefill(params, cfg, prompts)
    caches = _pad_caches(caches, max_len, lp)
    last_logits = logits[:, -1].astype(jnp.float32)

    outs, step_logits = [], []
    done = jnp.zeros((b,), bool)
    key = rng if rng is not None else jax.random.PRNGKey(0)
    for t in range(max_new_tokens):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, last_logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(last_logits, axis=-1)
        nxt = jnp.where(done, PAD, nxt).astype(jnp.int32)
        outs.append(nxt)
        step_logits.append(last_logits)
        if stop_at_eos:
            done = done | (nxt == EOS)
        last_logits, caches = _decode_step(params, cfg, nxt[:, None], caches,
                                           lp + t)
        last_logits = last_logits.astype(jnp.float32)
    gen = np.asarray(jnp.stack(outs, axis=1))
    lg = np.asarray(jnp.stack(step_logits, axis=1))
    return gen, lg
