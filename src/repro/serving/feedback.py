"""Outcome ledger + drift detection for self-healing serving.

SCOPE's estimates are conditioned on a model's *fingerprint* — a frozen
snapshot of its behavior on the anchor set.  When the deployed model
silently degrades, predictions keep flowing from the stale snapshot and
nothing in the serve stack notices.  This module closes that gap from
served traffic alone:

  ``Outcome``        — one served (query, model) pair: what the router
                       predicted vs. what the world returned, plus the
                       retrieval context (sims/idx) captured at decision
                       time so the observation can later be scattered back
                       onto anchors.
  ``ReplayBuffer``   — bounded FIFO ledger of outcomes (the oldest rows
                       fall off; capacity bounds both memory and how far
                       back a refresh looks).
  ``PageHinkley``    — sequential change detector over the calibration
                       residual ``predicted_p - observed_y``.  Under a
                       calibrated estimator the residual is ~zero-mean;
                       a drifted model pushes it persistently positive
                       (the router keeps predicting the old success rate).
  ``FeedbackMonitor``— per-model detectors + the buffer + the quarantine
                       set, and the refresh path: synthesize a new
                       ``Fingerprint`` from the buffer's observed outcomes
                       (similarity-weighted scatter onto the anchors,
                       blended with the old fingerprint where no
                       observations landed).

Everything here is deterministic: no RNG, no ambient clock (the row
timestamp comes from the injectable ``clock``), pure host arithmetic —
the module lives on the serve hot path and is scopelint-enforced.

Page–Hinkley, per model, over residuals x_t = predicted_p - observed_y:

  mean_t = mean(x_1..x_t)                      (running)
  m_t    = m_{t-1} + x_t - mean_t - delta      (cumulative drift mass)
  M_t    = min(M_{t-1}, m_t)
  alarm  when  t >= min_obs  and  m_t - M_t > threshold

``delta`` absorbs benign calibration wobble; ``threshold`` is the total
residual mass a model must accumulate above its own running mean before
the alarm fires.  The residual is Bernoulli-noisy (y is 0/1, so single
rows swing ~±0.5 even under perfect calibration) and real traffic
arrives with run structure — a calibrated model's drift mass oscillates
but stays *bounded* by the length of its overconfident runs, while a
genuinely drifted model accumulates ~``p_hat`` per observation without
bound.  The default threshold of 5.0 rides above the bounded clean
oscillation and still fires within a dozen or so drifted observations;
deployments that want faster alarms on trusted-calibration pools can
lower it.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.fingerprint import Fingerprint


@dataclasses.dataclass(frozen=True)
class Outcome:
    """One served pair: prediction vs. observation + retrieval context."""
    query_id: int               # content-derived key (api.cache.query_key)
    model: str
    predicted_p: float          # p_hat of the chosen pair at decision time
    predicted_cost: float       # cost_hat ($) of the chosen pair
    observed_y: float           # realized correctness (post-fault)
    observed_cost: float        # realized $ (post-fault)
    observed_tokens: int        # realized completion tokens
    sims: np.ndarray            # (K,) retrieval similarities at decision
    idx: np.ndarray             # (K,) retrieved anchor ids
    t: float = 0.0              # monitor clock at observation
    well_formed: bool = True    # estimator row parsed (p_hat is a real
                                # prediction, not the 0.5 parse fallback)

    @property
    def residual(self) -> float:
        """Calibration residual: positive when the router was overconfident."""
        return float(self.predicted_p) - float(self.observed_y)


class ReplayBuffer:
    """Bounded FIFO of ``Outcome`` rows (oldest fall off at capacity)."""

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError(f"capacity must be > 0, got {capacity}")
        self.capacity = int(capacity)
        self._rows: Deque[Outcome] = deque(maxlen=self.capacity)

    def __len__(self) -> int:
        return len(self._rows)

    def append(self, row: Outcome) -> None:
        self._rows.append(row)

    def rows(self, model: Optional[str] = None) -> List[Outcome]:
        if model is None:
            return list(self._rows)
        return [r for r in self._rows if r.model == model]

    def residuals(self, model: Optional[str] = None) -> np.ndarray:
        return np.asarray([r.residual for r in self.rows(model)],
                          np.float64)


class PageHinkley:
    """One-sided Page–Hinkley test for an upward shift in residual mean."""

    def __init__(self, *, delta: float = 0.05, threshold: float = 5.0,
                 min_obs: int = 8):
        if threshold <= 0.0:
            raise ValueError(f"threshold must be > 0, got {threshold}")
        if min_obs < 1:
            raise ValueError(f"min_obs must be >= 1, got {min_obs}")
        self.delta = float(delta)
        self.threshold = float(threshold)
        self.min_obs = int(min_obs)
        self.reset()

    def reset(self) -> None:
        self.n = 0
        self.mean = 0.0
        self.m = 0.0            # cumulative drift mass
        self.m_min = 0.0

    @property
    def score(self) -> float:
        """Current drift mass above the historical minimum."""
        return self.m - self.m_min

    def update(self, x: float) -> bool:
        """Feed one residual; returns True when the alarm fires."""
        self.n += 1
        self.mean += (x - self.mean) / self.n
        self.m += x - self.mean - self.delta
        self.m_min = min(self.m_min, self.m)
        return self.n >= self.min_obs and self.score > self.threshold


class FeedbackMonitor:
    """Replay buffer + per-model drift detectors + quarantine set.

    ``observe`` is the single serve-path entry point: append the outcome,
    update the model's detector, and return the model's name iff this
    observation newly tripped its alarm (the engine demotes the model's
    cached predictions on that signal).  A drifted model keeps
    accumulating outcomes — they are exactly what ``refresh_fingerprint``
    heals from — but never re-alarms until ``clear`` resets it (after
    ``onboard(refresh=True)``).

    Collection is passive by construction: ``observe`` writes only monitor
    state, never predictions or the cache, so with no alarm the serve path
    is bit-identical to running without a monitor.
    """

    def __init__(self, *, capacity: int = 4096, delta: float = 0.05,
                 threshold: float = 5.0, min_obs: int = 8,
                 clock: Callable[[], float] = time.monotonic):
        self.buffer = ReplayBuffer(capacity)
        self._mk = lambda: PageHinkley(delta=delta, threshold=threshold,
                                       min_obs=min_obs)
        self._detectors: Dict[str, PageHinkley] = {}
        self.drifted: Set[str] = set()
        self.alarms = 0                 # total alarm events (monotonic)
        self._clock = clock

    def detector(self, model: str) -> PageHinkley:
        det = self._detectors.get(model)
        if det is None:
            det = self._detectors[model] = self._mk()
        return det

    def observe(self, row: Outcome) -> Optional[str]:
        """Record one served outcome; returns the model name on a *new*
        alarm, else None.

        Malformed rows are buffered (their observed outcomes are real and
        feed the refresh) but never scored: the parse-fallback ``p_hat``
        of 0.5 is not a calibration claim, and its ±0.5 residual noise
        would false-alarm the detector on clean traffic.
        """
        if row.t == 0.0:
            row = dataclasses.replace(row, t=self._clock())
        self.buffer.append(row)
        if not row.well_formed:
            return None
        fired = self.detector(row.model).update(row.residual)
        if fired and row.model not in self.drifted:
            self.drifted.add(row.model)
            self.alarms += 1
            return row.model
        return None

    def clear(self, model: str) -> None:
        """Heal a model after re-fingerprinting: reset its detector (the
        old residuals were measured against the stale fingerprint) and
        lift its quarantine."""
        self.drifted.discard(model)
        det = self._detectors.get(model)
        if det is not None:
            det.reset()

    def residual_percentiles(self) -> Tuple[float, float]:
        """(p50, p95) of absolute calibration residuals over the buffer."""
        if not len(self.buffer):
            return 0.0, 0.0
        a = np.abs(self.buffer.residuals())
        return (float(np.percentile(a, 50)), float(np.percentile(a, 95)))

    # -- refresh path ---------------------------------------------------
    def can_refresh(self, model: str, *, min_rows: int = 1) -> bool:
        return len(self.buffer.rows(model)) >= min_rows

    def refresh_fingerprint(self, model: str, library,
                            *, prior_strength: float = 1.0) -> Fingerprint:
        """Synthesize a fingerprint for ``model`` from the buffer's
        observed outcomes — no offline dataset, no world pass.

        Each outcome row is scattered onto its retrieved anchors with its
        decision-time similarity weights; per anchor the observed
        y/tokens/cost are similarity-weighted means.  Where little or no
        observation mass landed, the old fingerprint's value carries
        through a mass-proportional blend ``w / (w + prior_strength)`` —
        served traffic rarely covers every anchor, and an anchor nobody
        queried near has learned nothing new.  The result has full anchor
        length, so ``FingerprintLibrary.add`` accepts it unchanged.
        """
        rows = self.buffer.rows(model)
        if not rows:
            raise ValueError(
                f"no replay-buffer outcomes for model {model!r}; serve "
                "traffic through it first or refresh offline")
        old = library.get(model)
        n = len(library.anchor_set)
        mass = np.zeros(n, np.float64)
        y_acc = np.zeros(n, np.float64)
        tok_acc = np.zeros(n, np.float64)
        cost_acc = np.zeros(n, np.float64)
        for r in rows:
            w = np.clip(np.asarray(r.sims, np.float64), 0.0, None)
            a = np.asarray(r.idx, int)
            np.add.at(mass, a, w)
            np.add.at(y_acc, a, w * float(r.observed_y))
            np.add.at(tok_acc, a, w * float(r.observed_tokens))
            np.add.at(cost_acc, a, w * float(r.observed_cost))
        seen = mass > 0.0
        obs_y = np.where(seen, y_acc / np.where(seen, mass, 1.0), 0.0)
        obs_tok = np.where(seen, tok_acc / np.where(seen, mass, 1.0), 0.0)
        obs_cost = np.where(seen, cost_acc / np.where(seen, mass, 1.0), 0.0)
        blend = mass / (mass + float(prior_strength))
        y = blend * obs_y + (1.0 - blend) * np.asarray(old.y, np.float64)
        tokens = blend * obs_tok + \
            (1.0 - blend) * np.asarray(old.tokens, np.float64)
        cost = blend * obs_cost + \
            (1.0 - blend) * np.asarray(old.cost, np.float64)
        return Fingerprint(model, y, np.round(tokens).astype(int),
                           cost.astype(np.float64))
