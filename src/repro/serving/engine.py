"""Batched serving engine.

Collects requests, pads them into fixed-size batches, runs prefill+decode
via ``sampler.generate``, and returns per-request results.  This is the
substrate both for the SCOPE estimator (pool-wide prediction batches: one
request per candidate model) and for the examples' serve driver.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import PAD
from repro.serving import sampler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]


@dataclasses.dataclass
class Result:
    rid: int
    tokens: np.ndarray           # (T,) generated
    decision_logits: np.ndarray  # (T, 2) per-step (YES, NO) logit pair


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch_size: int = 32,
                 max_new_tokens: int = 12, temperature: float = 0.0):
        self.params = params
        self.cfg = cfg
        self.batch_size = batch_size
        self.max_new_tokens = max_new_tokens
        self.temperature = temperature
        self._queue: List[Request] = []
        self._next_rid = 0

    def submit(self, prompt: Sequence[int]) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, list(prompt)))
        return rid

    def run(self, rng: Optional[jax.Array] = None) -> Dict[int, Result]:
        """Drain the queue in fixed-size batches (last batch padded).

        Stochastic decoding (temperature > 0) requires an explicit ``rng``
        — the sampler raises rather than silently reusing PRNGKey(0).
        """
        results: Dict[int, Result] = {}
        queue, self._queue = self._queue, []
        if not queue:
            return results
        lp = max(len(r.prompt) for r in queue)
        key = rng
        for i in range(0, len(queue), self.batch_size):
            chunk = queue[i: i + self.batch_size]
            pad_n = self.batch_size - len(chunk)
            prompts = np.full((len(chunk) + pad_n, lp), PAD, np.int32)
            for j, r in enumerate(chunk):
                prompts[j, : len(r.prompt)] = r.prompt
            sub = None
            if key is not None:
                key, sub = jax.random.split(key)
            gen, lg = sampler.generate(
                self.params, self.cfg, prompts,
                max_new_tokens=self.max_new_tokens,
                temperature=self.temperature, rng=sub)
            for j, r in enumerate(chunk):
                results[r.rid] = Result(r.rid, gen[j], lg[j])
        return results
