"""Pallas TPU flash attention kernel.

TPU-native design: the grid's innermost dimension walks KV blocks while an
online-softmax accumulator (running max / denominator / weighted values)
persists in VMEM scratch.  Q/K/V tiles stream HBM->VMEM via BlockSpecs with
MXU-aligned (multiple-of-128) tiles.  Supports causal masking, sliding
windows (gemma2 local layers / long-context variant), gemma2 logit softcap
and GQA (KV-head index map folds the query-head group).

Validated on CPU with ``interpret=True`` against ``ref.attention``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  block_q: int, block_k: int, seq_k: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_k                                   # padding guard
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows
    m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)
    p = jnp.exp(s - m_safe)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - m_safe))

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0, 0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: Optional[float] = None,
                    q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (b, hq, sq, d); k, v: (b, hkv, sk, d).  Returns (b, hq, sq, d)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(sk, block_k)

    # pad to block multiples: partially out-of-bounds blocks are poison in
    # interpret mode; masks below use the true seq_k so results are exact
    def _pad(x, mult):
        pad = (-x.shape[2]) % mult
        if pad:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return x
    q = _pad(q, block_q)
    k = _pad(k, block_k)
    v = _pad(v, block_k)
    sq_p = q.shape[2]

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, seq_k=sk,
        q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv), lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dv), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
