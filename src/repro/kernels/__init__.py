"""Pallas TPU kernels (+ XLA twins and pure-jnp oracles).

Kernels:
  flash_attention — online-softmax attention (causal/window/softcap/GQA)
  ssd_scan        — Mamba2 SSD chunk scan with VMEM-carried state
  topk_retrieval  — anchor-set cosine top-k (SCOPE fingerprint retrieval)

``ops`` holds the dispatching wrappers used by model code; ``ref`` the
oracles used by tests.
"""
from repro.kernels import ops, ref  # noqa: F401
