"""Pallas TPU kernel for the Mamba2 SSD chunk scan.

TPU adaptation of the SSD (state-space duality) algorithm: GPU
implementations use warp-level scans; here each chunk's intra-chunk work is
expressed as MXU matmuls over a VMEM-resident (chunk x chunk) decay matrix,
and the inter-chunk recurrence is carried in VMEM scratch across the
innermost grid dimension (chunks are visited sequentially per (batch, head)).

Inputs are per-head: the grid is (batch, heads, num_chunks); BlockSpecs
stream one chunk of x/dt/B/C per step.  Chunk length should be a multiple of
128 for MXU alignment (the interpret-mode tests also sweep small chunks).

Validated against ``ref.ssd`` in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_log_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref, *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (t, p)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (t, 1)
    A = a_log_ref[0, 0]                          # (1, 1) negative rate
    B = b_ref[0].astype(jnp.float32)             # (t, n)
    C = c_ref[0].astype(jnp.float32)             # (t, n)

    a = dt * A[0, 0]                             # (t, 1) log decay <= 0
    xdt = x * dt                                 # discretized input

    # cumulative decays
    a_cum = jnp.cumsum(a, axis=0)                # (t, 1)
    a_total = a_cum[-1, 0]

    # intra-chunk decay matrix L[s, t] = exp(sum_{t<k<=s} a_k), t <= s
    seg = a_cum - a_cum.reshape(1, chunk)        # (s, t) = a_cum[s] - a_cum[t]
    srow = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tcol = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(srow >= tcol, jnp.exp(seg), 0.0)

    # y_diag = (C B^T * L) @ xdt
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (s, t)
    y = jax.lax.dot_general(cb * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (s, p)

    # inter-chunk: y += (C decayed) @ h_entry^T   with h_entry (p, n)
    h_entry = state_ref[...]                                       # (p, n)
    c_dec = C * jnp.exp(a_cum)                                     # (s, n)
    y += jax.lax.dot_general(c_dec, h_entry, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (s, p)

    y_ref[0, 0, ...] = y.astype(y_ref.dtype)

    # state update: h_exit = exp(a_total) h_entry + sum_t decay_t xdt_t B_t
    decay_states = jnp.exp(a_total - a_cum)                        # (t, 1)
    upd = jax.lax.dot_general(xdt * decay_states, B,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (p, n)
    state_ref[...] = state_ref[...] * jnp.exp(a_total) + upd

    @pl.when(ic == nc - 1)
    def _finish():
        state_out_ref[0, 0, ...] = state_ref[...]


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = 128,
             interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Pallas SSD over full sequences.

    x: (b, l, h, p); dt: (b, l, h); A: (h,); B, C: (b, l, n).
    Returns (y (b, l, h, p), final_state (b, h, p, n)).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    # layout: per-(batch, head) chunked views
    xt = x.transpose(0, 2, 1, 3)                      # (b, h, l, p)
    dtt = dt.transpose(0, 2, 1)[..., None]            # (b, h, l, 1)
    a_log = A.reshape(1, h, 1, 1)                     # broadcastable block
    a_log = jnp.broadcast_to(a_log, (b, h, 1, 1))

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, final_state = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b_, h_, c_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, l, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, a_log, B, C)
    return y.transpose(0, 2, 1, 3), final_state
