"""Dispatching wrappers around the Pallas kernels and their XLA twins.

Model code calls these entry points.  ``impl`` selects:
  - "pallas": the Pallas TPU kernel (interpret=True on CPU) — the hardware
    target; exercised by kernel tests and benchmarks.
  - "xla": a blocked, memory-safe pure-XLA implementation with the same
    streaming structure (online softmax over KV blocks / chunked SSD).  This
    is the default inside model forward passes so the multi-pod dry-run's
    ``cost_analysis()`` reflects fused HLO rather than interpreter loops.
  - "ref": the naive oracle (small shapes / tests).

Note on causal FLOPs: the dense-blocked XLA path computes masked upper-
triangle blocks (~2x attention FLOPs at long seq); the Pallas kernel and the
banded sliding-window path skip them.  EXPERIMENTS.md §Roofline accounts for
this in the MODEL_FLOPS/HLO_FLOPs ratio.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd
from repro.kernels import topk_retrieval as _topk

def _interpret() -> bool:
    # single source of truth for backend detection (shared with direct
    # kernel callers)
    return _topk.default_interpret()


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: Optional[float] = None,
                    q_offset: int = 0, impl: str = "xla",
                    block_q: int = 512, block_k: int = 1024) -> jax.Array:
    """q: (b, hq, sq, d); k, v: (b, hkv, sk, d) -> (b, hq, sq, d)."""
    sq, sk = q.shape[2], k.shape[2]
    if impl == "pallas":
        return _fa.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, q_offset=q_offset, interpret=_interpret())
    # Unblocked path up to 4k x 4k: one fused logits tensor (sharded over
    # heads) beats the blocked scan under XLA, whose loop-invariant code
    # motion materializes every block's mask/logits at once (HC1-iter3,
    # EXPERIMENTS.md §Perf).  Only profitable when the head count shards
    # over the model axis (16) — otherwise the logits replicate and temp
    # memory explodes (starcoder2 kv=2 / qwen2-vl 28H).  Longer sequences
    # use the blocked/banded paths.
    heads_shardable = q.shape[1] % 16 == 0
    if impl == "ref" or (sq <= 1024 and sk <= 1024) or (
            sq <= 4096 and sk <= 4096 and heads_shardable):
        return ref.attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale, q_offset=q_offset)
    if window > 0:
        return _banded_window_attention(
            q, k, v, window=window, causal=causal, softcap=softcap,
            scale=scale, q_offset=q_offset, block_q=block_q)
    return _blocked_attention(q, k, v, causal=causal, softcap=softcap,
                              scale=scale, q_offset=q_offset,
                              block_q=block_q, block_k=block_k)


def _pad_axis(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _blocked_attention(q, k, v, *, causal, softcap, scale, q_offset,
                       block_q, block_k):
    """Online-softmax attention; outer scan over q blocks, inner over kv."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, max(sq, 1))
    block_k = min(block_k, max(sk, 1))

    qp = _pad_axis(q, 2, block_q)
    kp = _pad_axis(k, 2, block_k)
    vp = _pad_axis(v, 2, block_k)
    nq, nk = qp.shape[2] // block_q, kp.shape[2] // block_k

    qb = qp.reshape(b, hkv, g, nq, block_q, d).transpose(3, 0, 1, 2, 4, 5)
    kb = kp.reshape(b, hkv, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = vp.reshape(b, hkv, nk, block_k, dv).transpose(2, 0, 1, 3, 4)

    def q_block(carry, inp):
        iq, qblk = inp                                  # (b,hkv,g,bq,d)

        def kv_block(inner, kinp):
            m, l, acc = inner
            ik, kblk, vblk = kinp
            # keep q/k in their storage dtype; store logits in that dtype
            # too (bf16 halves the dominant logits HBM traffic), then do the
            # softmax math in f32
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                           preferred_element_type=q.dtype)
            s = s.astype(jnp.float32) * scale
            if softcap > 0.0:
                s = ref.softcap_fn(s, softcap)
            qpos = iq * block_q + jnp.arange(block_q) + q_offset
            kpos = ik * block_k + jnp.arange(block_k)
            mask = (kpos[None, :] < sk) & (qpos[:, None] < sq + q_offset)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            m_safe = jnp.where(m_new <= -1e30, 0.0, m_new)
            p = jnp.where(mask[None, None, None], jnp.exp(s - m_safe), 0.0)
            alpha = jnp.where(m <= -1e30, 0.0, jnp.exp(m - m_safe))
            l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            # probs in the storage dtype for the p@v matmul (f32 accum)
            acc = acc * alpha + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(v.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((b, hkv, g, block_q, 1), -1e30, jnp.float32),
                jnp.zeros((b, hkv, g, block_q, 1), jnp.float32),
                jnp.zeros((b, hkv, g, block_q, dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_block, init, (jnp.arange(nk), kb, vb))
        out = acc / jnp.where(l == 0.0, 1.0, l)
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, nq * block_q, dv)
    return out[:, :, :sq]


def _banded_window_attention(q, k, v, *, window, causal, softcap, scale,
                             q_offset, block_q):
    """Sliding-window attention with a static banded KV slice per q block.

    Exact-FLOPs path for gemma2 local layers and the long-context variant:
    each q block attends only a (window + block_q)-wide KV band fetched with
    a dynamic slice, so compiled FLOPs/bytes scale with window, not seq^2.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, sq)

    span = min(sk, window + block_q)
    qp = _pad_axis(q, 2, block_q)
    nq = qp.shape[2] // block_q
    qb = qp.reshape(b, hkv, g, nq, block_q, d).transpose(3, 0, 1, 2, 4, 5)

    def q_block(carry, inp):
        iq, qblk = inp
        q_end = iq * block_q + block_q + q_offset       # absolute, exclusive
        start = jnp.clip(q_end - span, 0, max(sk - span, 0))
        kblk = jax.lax.dynamic_slice_in_dim(k, start, span, axis=2)
        vblk = jax.lax.dynamic_slice_in_dim(v, start, span, axis=2)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                       preferred_element_type=q.dtype)
        s = s.astype(jnp.float32) * scale
        if softcap > 0.0:
            s = ref.softcap_fn(s, softcap)
        qpos = iq * block_q + jnp.arange(block_q) + q_offset
        kpos = start + jnp.arange(span)
        mask = (kpos[None, :] < sk) & (qpos[:, None] < sq + q_offset)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m = jnp.max(s, axis=-1, keepdims=True)
        m_safe = jnp.where(m <= -1e30, 0.0, m)
        p = jnp.where(mask[None, None, None], jnp.exp(s - m_safe), 0.0)
        l = jnp.sum(p, axis=-1, keepdims=True)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), vblk,
                         preferred_element_type=jnp.float32)
        out = out / jnp.where(l == 0.0, 1.0, l)
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, nq * block_q, dv)
    return out[:, :, :sq]


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     softcap: float = 0.0,
                     scale: Optional[float] = None,
                     impl: str = "xla") -> jax.Array:
    """Single-token attention against a cache.

    q: (b, hq, 1, d); caches: (b, hkv, S, d); cache_len: scalar or (b,) —
    number of valid cache entries INCLUDING the current token.
    """
    if impl == "pallas":
        return _da.decode_attention(q, k_cache, v_cache, cache_len,
                                    window=window, softcap=softcap,
                                    scale=scale, interpret=_interpret())
    b, hq, _, d = q.shape
    hkv, S = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[-1]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        cache_len = jnp.broadcast_to(cache_len, (b,))

    qf = q.astype(jnp.float32).reshape(b, hkv, g, d) * scale
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k_cache.astype(jnp.float32))
    if softcap > 0.0:
        s = ref.softcap_fn(s, softcap)
    kpos = jnp.arange(S)[None]                          # (1, S)
    mask = kpos < cache_len[:, None]
    if window > 0:
        mask &= kpos >= (cache_len[:, None] - window)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, 1, dv).astype(q.dtype)


def _paged_decode_attention_xla(q, k_pages, v_pages, cache_len, page_table,
                                *, page_size: int, kv_cap: int,
                                softcap: float = 0.0,
                                scale: Optional[float] = None) -> jax.Array:
    """XLA paged path: gather each row's pages into a dense per-row view,
    slice to ``kv_cap``, then run the exact dense masked-softmax above.

    Every valid cache position holds the same value as the dense layout
    (the scatter wrote it there) and every position past ``cache_len``
    reaches the softmax as an exact-zero probability, so this path is
    **bit-identical** to the dense oracle whenever ``kv_cap`` equals the
    dense cache length — the parity the tests pin down.
    """
    b, hq, _, d = q.shape
    hkv = k_pages.shape[1]
    n_w = page_table.shape[1]
    kd = k_pages[page_table]                    # (b, W, hkv, page, hd)
    vd = v_pages[page_table]
    kd = kd.transpose(0, 2, 1, 3, 4).reshape(
        b, hkv, n_w * page_size, d)[:, :, :kv_cap]
    vd = vd.transpose(0, 2, 1, 3, 4).reshape(
        b, hkv, n_w * page_size, -1)[:, :, :kv_cap]
    return decode_attention(q, kd, vd, cache_len, softcap=softcap,
                            scale=scale, impl="xla")


def _paged_decode_attention_pallas(q, k_pages, v_pages, cache_len,
                                   page_table, *, page_size: int,
                                   kv_cap: int, softcap: float = 0.0,
                                   scale: Optional[float] = None
                                   ) -> jax.Array:
    return _da.paged_decode_attention(
        q, k_pages, v_pages, cache_len, page_table, page_size=page_size,
        kv_cap=kv_cap, softcap=softcap, scale=scale, interpret=_interpret())


# KernelType -> implementation, the dispatch idiom shared with the other
# kernels: model code picks an enum member (a static jit argument), never
# a string, so the mapping is the single registry of paged backends.
KernelTypeMapping = {
    _da.KernelType.PALLAS: _paged_decode_attention_pallas,
    _da.KernelType.XLA: _paged_decode_attention_xla,
}


def paged_decode_attention(q, k_pages, v_pages, cache_len, page_table, *,
                           page_size: int, kv_cap: int, softcap: float = 0.0,
                           scale: Optional[float] = None,
                           kernel=_da.KernelType.XLA) -> jax.Array:
    """Single-token attention against a block-paged cache.

    q: (b, hq, 1, d); k_pages/v_pages: (n_pages, hkv, page_size, d)
    physical page storage (the last page is the trash page);
    page_table: (b, W) int32; cache_len: scalar or (b,) valid lengths
    INCLUDING the current token.
    """
    return KernelTypeMapping[kernel](
        q, k_pages, v_pages, cache_len, page_table, page_size=page_size,
        kv_cap=kv_cap, softcap=softcap, scale=scale)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
def ssd(x, dt, A, B, C, *, chunk: int = 128,
        init_state: Optional[jax.Array] = None,
        impl: str = "xla") -> Tuple[jax.Array, jax.Array]:
    if impl == "pallas":
        if init_state is not None:
            raise NotImplementedError("pallas ssd starts from zero state")
        return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk,
                             interpret=_interpret())
    return ref.ssd(x, dt, A, B, C, chunk=chunk, init_state=init_state)


ssd_decode_step = ref.ssd_decode_step


# ---------------------------------------------------------------------------
# Retrieval
# ---------------------------------------------------------------------------
def topk_retrieval(queries, anchors, k: int, *, impl: str = "xla",
                   anchors_prenormalized: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    if impl == "pallas":
        return _topk.topk_retrieval(
            queries, anchors, k, interpret=_interpret(),
            anchors_prenormalized=anchors_prenormalized)
    return ref.topk_retrieval(queries, anchors, k,
                              anchors_prenormalized=anchors_prenormalized)
