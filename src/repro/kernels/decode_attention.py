"""Pallas TPU kernels for single-token decode attention (dense + paged).

The decode hot spot is a memory-bound sweep of the KV cache: one query
token attends to S cached keys.  The grid walks KV blocks sequentially per
(batch, kv-head); an online-softmax accumulator for all grouped query
heads lives in VMEM scratch, so the cache streams HBM->VMEM exactly once
— the roofline-optimal traffic for this op.

Masking supports a per-batch valid length (``cache_len``) and an optional
sliding window (both used by the ring-buffer serving caches).

``paged_decode_attention`` is the block-paged variant backing the KV pool
(`serving/kv_pool.py`): the cache lives as (n_pages, hkv, page_size, hd)
physical pages and each row's logical block ``iw`` is resolved through a
scalar-prefetched page table — ``PrefetchScalarGridSpec`` makes the table
available to the BlockSpec index map, so the grid DMAs exactly the pages a
row owns and never materializes a gathered dense cache.  Callers select
the implementation via the ``KernelType`` enum (``KernelTypeMapping`` in
``kernels/ops.py`` maps it to this kernel or the XLA gather path).

Validated against ``ref.attention`` / ``ops.decode_attention`` in
interpret mode.
"""
from __future__ import annotations

import enum
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


class KernelType(enum.Enum):
    """Which paged decode-attention implementation to dispatch."""
    PALLAS = 0
    XLA = 1


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, window: int, softcap: float,
                   block_k: int, seq_k: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (g, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (g, bk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    valid = len_ref[0]
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (kpos < valid) & (kpos < seq_k)
    if window > 0:
        mask &= kpos >= (valid - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0, 0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, window: int = 0, softcap: float = 0.0,
                     scale: Optional[float] = None, block_k: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q: (b, hq, 1, d); caches: (b, hkv, S, d[v]); cache_len: (b,) or
    scalar valid lengths.  Returns (b, hq, 1, dv)."""
    b, hq, _, d = q.shape
    hkv, S = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[-1]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    cache_len = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))

    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = (S + pad) // block_k

    qg = q.reshape(b, hkv, g, d)[:, :, None]             # (b, hkv, 1, g, d)

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, softcap=softcap,
        block_k=block_k, seq_k=S)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h, ik: (b_,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda b_, h, ik: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, ik: (b_, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv), lambda b_, h, ik: (b_, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv), lambda b_, h, ik: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len, qg[:, :, 0], k_cache, v_cache)
    return out.reshape(b, hq, 1, dv)


def _paged_decode_kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float,
                         softcap: float, page_size: int, kv_cap: int,
                         n_w: int):
    """One grid step = one logical page of one (batch row, kv head).

    ``table_ref``/``len_ref`` are scalar-prefetched: the flattened page
    table already steered the BlockSpec index map, so ``k_ref``/``v_ref``
    hold the *physical* page this row's logical block ``iw`` maps to
    (the trash page for unallocated entries — fully masked below).
    """
    ib = pl.program_id(0)
    iw = pl.program_id(2)

    @pl.when(iw == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (g, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (page, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (page, dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (g, page)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    valid = len_ref[ib]
    kpos = iw * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (kpos < valid) & (kpos < kv_cap)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(iw == n_w - 1)
    def _finish():
        denom = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0, 0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, cache_len, page_table,
                           *, page_size: int, kv_cap: int,
                           softcap: float = 0.0,
                           scale: Optional[float] = None,
                           interpret: bool = True) -> jax.Array:
    """Block-paged decode attention.

    q: (b, hq, 1, d); k_pages/v_pages: (n_pages, hkv, page_size, d[v])
    physical page storage; page_table: (b, W) int32 mapping each row's
    logical page to a physical one; cache_len: (b,) or scalar valid
    lengths; kv_cap: the per-row logical capacity (W * page_size rounded
    down to it).  Returns (b, hq, 1, dv).
    """
    b, hq, _, d = q.shape
    hkv = k_pages.shape[1]
    dv = v_pages.shape[-1]
    g = hq // hkv
    n_w = page_table.shape[1]
    scale = scale if scale is not None else d ** -0.5
    cache_len = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    table_flat = jnp.asarray(page_table, jnp.int32).reshape(-1)   # (b*W,)

    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, softcap=softcap,
        page_size=page_size, kv_cap=kv_cap, n_w=n_w)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_w),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b_, h, iw, tbl, lens: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b_, h, iw, tbl, lens:
                         (tbl[b_ * n_w + iw], h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, dv),
                         lambda b_, h, iw, tbl, lens:
                         (tbl[b_ * n_w + iw], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda b_, h, iw, tbl, lens: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dv), q.dtype),
        interpret=interpret,
    )(table_flat, cache_len, qg, k_pages, v_pages)
    return out.reshape(b, hq, 1, dv)
