"""Pallas TPU kernel for single-token decode attention.

The decode hot spot is a memory-bound sweep of the KV cache: one query
token attends to S cached keys.  The grid walks KV blocks sequentially per
(batch, kv-head); an online-softmax accumulator for all grouped query
heads lives in VMEM scratch, so the cache streams HBM->VMEM exactly once
— the roofline-optimal traffic for this op.

Masking supports a per-batch valid length (``cache_len``) and an optional
sliding window (both used by the ring-buffer serving caches).

Validated against ``ref.attention`` / ``ops.decode_attention`` in
interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, window: int, softcap: float,
                   block_k: int, seq_k: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (g, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (g, bk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    valid = len_ref[0]
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (kpos < valid) & (kpos < seq_k)
    if window > 0:
        mask &= kpos >= (valid - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0, 0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, window: int = 0, softcap: float = 0.0,
                     scale: Optional[float] = None, block_k: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q: (b, hq, 1, d); caches: (b, hkv, S, d[v]); cache_len: (b,) or
    scalar valid lengths.  Returns (b, hq, 1, dv)."""
    b, hq, _, d = q.shape
    hkv, S = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[-1]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    cache_len = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))

    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = (S + pad) // block_k

    qg = q.reshape(b, hkv, g, d)[:, :, None]             # (b, hkv, 1, g, d)

    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, softcap=softcap,
        block_k=block_k, seq_k=S)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h, ik: (b_,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda b_, h, ik: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, ik: (b_, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv), lambda b_, h, ik: (b_, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv), lambda b_, h, ik: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len, qg[:, :, 0], k_cache, v_cache)
    return out.reshape(b, hq, 1, dv)
