"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth for kernel tests (``assert_allclose`` sweeps) and
the small-shape fallback implementations.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True,
              window: int = 0,
              softcap: float = 0.0,
              scale: Optional[float] = None,
              q_offset: int = 0) -> jax.Array:
    """Naive masked softmax attention.

    q: (b, hq, sq, d); k: (b, hkv, sk, d); v: (b, hkv, sk, dv) with
    hq % hkv == 0 (dv may differ from d, e.g. MLA).  ``window`` > 0
    restricts attention to the last ``window`` keys (sliding window,
    inclusive of self).  ``q_offset`` is the absolute position of q[0]
    (for decode: q_offset = sk - sq).
    """
    b, hq, sq, d = q.shape
    dv = v.shape[-1]
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(b, hkv, group, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf)

    if softcap > 0.0:
        logits = softcap_fn(logits, softcap)

    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= qpos[:, None] - kpos[None, :] < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def softcap_fn(x, cap):
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality, chunked) — follows the minimal listing of
# arXiv:2405.21060 App. B, with explicit initial state for decode handoff.
# ---------------------------------------------------------------------------
def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k]."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
        C: jax.Array, *, chunk: int,
        init_state: Optional[jax.Array] = None
        ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x:  (b, l, h, p)  per-head inputs
    dt: (b, l, h)     positive step sizes (already softplus'd)
    A:  (h,)          negative per-head decay rates
    B:  (b, l, n)     input projections (single group)
    C:  (b, l, n)     output projections
    Returns y (b, l, h, p) and final state (b, h, p, n).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    a = dt * A[None, None, :]                       # (b, l, h) log-decay <= 0
    xdt = x * dt[..., None]                         # discretized input

    # reshape into chunks
    ar = a.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)        # (b,h,c,t)
    xr = xdt.reshape(b, nc, chunk, h, p)                         # (b,c,t,h,p)
    Br = B.reshape(b, nc, chunk, n)                              # (b,c,t,n)
    Cr = C.reshape(b, nc, chunk, n)

    # 1. intra-chunk (diagonal block) output
    L = jnp.exp(_segsum(ar))                                     # (b,h,c,t,t)
    y_diag = jnp.einsum("bcsn,bctn,bhcst,bcthp->bcshp", Cr, Br, L, xr)

    # 2. chunk-final states
    a_cum = jnp.cumsum(ar, axis=-1)                              # (b,h,c,t)
    a_total = a_cum[..., -1]                                     # (b,h,c)
    decay_states = jnp.exp(a_total[..., None] - a_cum)           # (b,h,c,t)
    states = jnp.einsum("bctn,bhct,bcthp->bchpn", Br, decay_states, xr)

    # 3. inter-chunk recurrence over chunk states
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        st_in, a_tot = inp                                       # (b,h,p,n),(b,h)
        new = carry * jnp.exp(a_tot)[..., None, None] + st_in
        return new, carry                                        # emit state *entering* chunk

    final_state, entry_states = jax.lax.scan(
        step,
        init_state.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         a_total.transpose(2, 0, 1)),
    )
    # entry_states: (c, b, h, p, n) = state at the *start* of each chunk

    # 4. inter-chunk (off-diagonal) output: y_off = C_t · (decay_in · h_entry)
    decay_out = jnp.exp(a_cum)                                   # (b,h,c,t)
    y_off = jnp.einsum("bcsn,bhcs,cbhpn->bcshp",
                       Cr, decay_out, entry_states)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    A: jax.Array, B: jax.Array, C: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent SSD update.

    state: (b, h, p, n); x: (b, h, p); dt: (b, h); B, C: (b, n).
    Returns (y (b, h, p), new_state).
    """
    a = jnp.exp(dt * A[None, :])                        # (b, h)
    upd = jnp.einsum("bhp,bn->bhpn", x * dt[..., None], B)
    new_state = state * a[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Top-k cosine retrieval
# ---------------------------------------------------------------------------
def topk_retrieval(queries: jax.Array, anchors: jax.Array, k: int, *,
                   anchors_prenormalized: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Cosine-similarity top-k.

    queries: (q, d); anchors: (n, d).  Returns (scores (q, k), idx (q, k)).
    ``anchors_prenormalized`` skips anchor normalization (cached unit rows).
    """
    qn = queries / (jnp.linalg.norm(queries, axis=-1, keepdims=True) + 1e-8)
    if anchors_prenormalized:
        an = anchors
    else:
        an = anchors / (jnp.linalg.norm(anchors, axis=-1, keepdims=True)
                        + 1e-8)
    sims = qn @ an.T
    return jax.lax.top_k(sims, k)
