"""Pallas TPU kernel for anchor-set top-k cosine retrieval (SCOPE Eq. 2).

The anchor matrix streams HBM->VMEM in tiles along the innermost grid
dimension; per query-tile a running (scores, indices) top-k buffer persists
in VMEM scratch and is merged with each anchor tile's scores.  Cosine
normalization is pre-applied outside the kernel (cheap, fused by XLA) so the
kernel body is a pure MXU matmul + merge.

Validated against ``ref.topk_retrieval`` in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.0  # below min cosine similarity

_ON_CPU = None


def default_interpret() -> bool:
    """Interpret only off-TPU (``ops._interpret`` delegates here) so direct
    callers don't silently run the kernel in interpreter mode on hardware."""
    global _ON_CPU
    if _ON_CPU is None:
        _ON_CPU = jax.default_backend() == "cpu"
    return _ON_CPU


def _topk_kernel(q_ref, a_ref, sc_out_ref, ix_out_ref, sc_ref, ix_ref, *,
                 k: int, block_n: int, num_anchors: int):
    ia = pl.program_id(1)
    na = pl.num_programs(1)

    @pl.when(ia == 0)
    def _init():
        sc_ref[...] = jnp.full_like(sc_ref, NEG)
        ix_ref[...] = jnp.zeros_like(ix_ref)

    q = q_ref[...]                                   # (bq, d) normalized
    a = a_ref[...]                                   # (bn, d) normalized
    sims = jax.lax.dot_general(q, a, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (bq, bn)
    base = ia * block_n
    idx = base + jax.lax.broadcasted_iota(jnp.int32, sims.shape, 1)
    valid = idx < num_anchors
    sims = jnp.where(valid, sims, NEG)

    # merge running top-k with this tile
    all_sc = jnp.concatenate([sc_ref[...], sims], axis=1)
    all_ix = jnp.concatenate([ix_ref[...], idx], axis=1)
    top_sc, top_pos = jax.lax.top_k(all_sc, k)
    top_ix = jnp.take_along_axis(all_ix, top_pos, axis=1)
    sc_ref[...] = top_sc
    ix_ref[...] = top_ix

    @pl.when(ia == na - 1)
    def _finish():
        sc_out_ref[...] = sc_ref[...]
        ix_out_ref[...] = ix_ref[...]


def topk_retrieval(queries: jax.Array, anchors: jax.Array, k: int, *,
                   block_q: int = 128, block_n: int = 256,
                   interpret: Optional[bool] = None,
                   anchors_prenormalized: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """queries (q, d), anchors (n, d) -> (scores (q, k), indices (q, k)).

    ``anchors_prenormalized`` skips the per-call anchor normalization for
    callers (``AnchorRetriever``) that cache the unit-norm anchor matrix.
    """
    if interpret is None:
        interpret = default_interpret()
    nq, d = queries.shape
    na = anchors.shape[0]
    qn = (queries / (jnp.linalg.norm(queries, axis=-1, keepdims=True) + 1e-8)
          ).astype(jnp.float32)
    if anchors_prenormalized:
        an = anchors.astype(jnp.float32)
    else:
        an = (anchors / (jnp.linalg.norm(anchors, axis=-1, keepdims=True)
                         + 1e-8)).astype(jnp.float32)

    block_q = min(block_q, nq)
    block_n = min(block_n, na)
    gq = pl.cdiv(nq, block_q)
    gn = pl.cdiv(na, block_n)

    kernel = functools.partial(_topk_kernel, k=k, block_n=block_n,
                               num_anchors=na)
    scores, idx = pl.pallas_call(
        kernel,
        grid=(gq, gn),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda iq, ia: (iq, 0)),
            pl.BlockSpec((block_n, d), lambda iq, ia: (ia, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda iq, ia: (iq, 0)),
            pl.BlockSpec((block_q, k), lambda iq, ia: (iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, k), jnp.float32),
            jax.ShapeDtypeStruct((nq, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(qn, an)
    return scores, idx
