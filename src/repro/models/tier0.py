"""Tier-0 pre-router: one cross-attention forward instead of a reasoning
decode ("One Head, Many Models" applied to the SCOPE serve path).

The reasoning estimator spends ``max_new_tokens`` decode steps per
(query, model) pair.  The tier-0 head reads *exactly the features the
serialized prompt encodes* — the query embedding + domain, the retrieved
anchor slice of the model's fingerprint (similarities, outcomes, token
counts, anchor domains), and the model's metadata (price bucket, reasoning
flag, seen flag, identity embedding) — and emits the same prediction tuple
(p_correct, len_bucket) plus a calibrated confidence, in a single jitted
forward over all pairs.  ``ScopeEngine._prepare`` answers pairs whose
confidence clears ``EngineConfig.escalation_threshold`` directly from this
head; only the low-confidence remainder escalates to the reasoning decode.

Serve-path invariants (this module is on the scopelint hot-path manifest):

- **fixed bucket shapes**: pair batches are padded up to ``PAIR_BUCKETS``
  sizes so steady-state traffic reuses a handful of compiled executables —
  ``COMPILE_COUNTS["tier0"]`` is incremented inside the traced body, once
  per compilation, and feeds the "0 recompiles after warmup" CI gate;
- **no serve-time nondeterminism**: ``init_tier0`` takes its PRNG key as a
  parameter; nothing here reads clocks or constructs fresh keys;
- **temperature on the host**: calibration scales the correctness logit in
  numpy *after* the forward, so refitting the temperature never invalidates
  a compiled executable.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fingerprint import AnchorSet, Fingerprint
from repro.data import tokenizer as tok
from repro.data.worldsim import EMBED_DIM, NUM_DOMAINS, PoolModel, Query
from repro.models.common import dense_init, embed_init

# traced-body compile instrumentation (same idiom as serving/sampler.py)
COMPILE_COUNTS: "Counter[str]" = Counter()

# feature widths — derived from the same quantities serialize_prompt tokenizes
QUERY_FEATS = EMBED_DIM + NUM_DOMAINS       # raw embedding + domain one-hot
ANCHOR_FEATS = 3 + NUM_DOMAINS              # sim, fp.y, log-len + domain
MODEL_FEATS = 3                             # price bucket, reasoning, seen
N_MODEL_SLOTS = tok.NUM_MODEL_TOKENS + 1    # identity slots + shared UNK

# fixed pair-batch grid: a batch of n pairs is padded to the smallest
# bucket >= n (multiples of the largest bucket beyond it), so the jit
# cache holds one executable per bucket, never one per traffic shape
PAIR_BUCKETS = (16, 64, 256, 1024)


def pair_bucket(n: int) -> int:
    """Smallest configured pair-bucket >= n (largest-bucket multiples
    beyond the grid)."""
    for b in PAIR_BUCKETS:
        if b >= n:
            return b
    top = PAIR_BUCKETS[-1]
    return -(-n // top) * top


@dataclasses.dataclass(frozen=True)
class Tier0Config:
    d_model: int = 32
    d_hidden: int = 64
    n_len_buckets: int = tok.NUM_LEN_BUCKETS


def init_tier0(key: jax.Array, cfg: Tier0Config = Tier0Config()):
    """Head parameters; ``key`` is supplied by the caller (training code) —
    serve code never constructs keys."""
    ks = jax.random.split(key, 7)
    d, h = cfg.d_model, cfg.d_hidden
    slot = ANCHOR_FEATS + MODEL_FEATS + d
    return {
        "model_emb": embed_init(ks[0], (N_MODEL_SLOTS, d)),
        "wq": dense_init(ks[1], (QUERY_FEATS, d)),
        "wk": dense_init(ks[2], (slot, d)),
        "wv": dense_init(ks[3], (slot, d)),
        "w1": dense_init(ks[4], (3 * d, h)),
        "b1": jnp.zeros((h,), jnp.float32),
        "w_p": dense_init(ks[5], (h, 1)),
        "b_p": jnp.zeros((1,), jnp.float32),
        "w_len": dense_init(ks[6], (h, cfg.n_len_buckets)),
        "b_len": jnp.zeros((cfg.n_len_buckets,), jnp.float32),
    }


def tier0_forward(params, qf: jax.Array, af: jax.Array, mf: jax.Array,
                  mid: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Cross-attention forward over (n,) pairs.

    ``qf`` (n, QUERY_FEATS) query features; ``af`` (n, K, ANCHOR_FEATS)
    retrieved anchor slice; ``mf`` (n, MODEL_FEATS) model metadata; ``mid``
    (n,) model identity slot.  Returns the correctness logit (n,) and the
    length-bucket logits (n, n_len_buckets).
    """
    d = params["wq"].shape[1]
    me = params["model_emb"][mid]                           # (n, d)
    qv = jnp.tanh(qf @ params["wq"])                        # (n, d)
    K = af.shape[1]
    slot = jnp.concatenate(
        [af,
         jnp.broadcast_to(mf[:, None, :], (af.shape[0], K, mf.shape[1])),
         jnp.broadcast_to(me[:, None, :], (af.shape[0], K, d))], axis=-1)
    k = jnp.tanh(slot @ params["wk"])                       # (n, K, d)
    v = slot @ params["wv"]                                 # (n, K, d)
    attn = jax.nn.softmax(
        jnp.einsum("nd,nkd->nk", qv, k) / jnp.sqrt(jnp.float32(d)), axis=-1)
    pooled = jnp.einsum("nk,nkd->nd", attn, v)              # (n, d)
    h = jax.nn.relu(
        jnp.concatenate([qv, pooled, me], axis=-1) @ params["w1"]
        + params["b1"])
    p_logit = (h @ params["w_p"] + params["b_p"])[:, 0]
    len_logits = h @ params["w_len"] + params["b_len"]
    return p_logit, len_logits


@jax.jit
def _tier0_jit(params, qf, af, mf, mid):
    COMPILE_COUNTS["tier0"] += 1            # traced once per compilation
    return tier0_forward(params, qf, af, mf, mid)


# ---------------------------------------------------------------------------
# Featurization — mirrors serialize_prompt's inputs field for field
# ---------------------------------------------------------------------------
def pair_features(model: PoolModel, model_index: int, anchor_set: AnchorSet,
                  fp: Fingerprint, sims: np.ndarray, idx: np.ndarray,
                  query: Query
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """(qf, af, mf, mid) for one (query, model) pair — the same signature
    (and information) as ``serialization.serialize_prompt``, so the gate
    needs no retrieval or serialization pass of its own."""
    qf = np.zeros(QUERY_FEATS, np.float32)
    qf[:EMBED_DIM] = query.embedding
    qf[EMBED_DIM + int(query.domain)] = 1.0
    K = len(sims)
    af = np.zeros((K, ANCHOR_FEATS), np.float32)
    fy = np.asarray(fp.y, np.float64)
    ft = np.asarray(fp.tokens, np.float64)
    for j in range(K):
        i = int(idx[j])
        af[j, 0] = float(sims[j])
        af[j, 1] = float(fy[i])
        af[j, 2] = float(np.log1p(ft[i])) / 10.0
        af[j, 3 + int(anchor_set.queries[i].domain)] = 1.0
    mf = np.asarray(
        [tok.price_bucket(model.price_out) / tok.NUM_PRICE_BUCKETS,
         float(bool(model.reasoning)), float(bool(model.seen))], np.float32)
    mid = (int(model_index) % tok.NUM_MODEL_TOKENS if model.seen
           else tok.NUM_MODEL_TOKENS)
    return qf, af, mf, mid


@dataclasses.dataclass
class Tier0Batch:
    """Columnar tier-0 predictions for n pairs (``ParsedBatch``-shaped
    fields plus the calibrated escalation signal)."""
    p: np.ndarray               # (n,) calibrated P(correct)
    y_hat: np.ndarray           # (n,) int, p >= 0.5
    len_hat: np.ndarray         # (n,) float, LEN_CENTERS[argmax]
    conf: np.ndarray            # (n,) max(p, 1-p) in [0.5, 1]

    def __len__(self) -> int:
        return len(self.p)


class Tier0Head:
    """Trained tier-0 parameters + calibration temperature.

    ``predict_pairs`` pads the pair batch to the ``PAIR_BUCKETS`` grid,
    runs the jitted forward once, and converts on the host: the calibrated
    probability is ``sigmoid(p_logit / temperature)`` and the confidence
    is its distance from chance, ``max(p, 1 - p)``.
    """

    def __init__(self, params, cfg: Tier0Config = Tier0Config(), *,
                 temperature: float = 1.0, version: str = "v0"):
        if temperature <= 0.0:
            raise ValueError(f"temperature must be > 0, got {temperature}")
        self.params = params
        self.cfg = cfg
        self.temperature = float(temperature)
        # which estimator this head was distilled from/calibrated against
        # (EngineConfig.estimator_version); ScopeEngine.hot_swap stamps the
        # post-swap head so a stale head can never ride a version bump
        self.version = str(version)

    def forward_raw(self, qf: np.ndarray, af: np.ndarray, mf: np.ndarray,
                    mid: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Bucket-padded jitted forward; returns host (p_logit, len_logits)
        trimmed back to the true pair count."""
        n = len(qf)
        if n == 0:
            return (np.zeros(0, np.float32),
                    np.zeros((0, self.cfg.n_len_buckets), np.float32))
        b = pair_bucket(n)
        qf_b = np.zeros((b, QUERY_FEATS), np.float32)
        af_b = np.zeros((b, af.shape[1], ANCHOR_FEATS), np.float32)
        mf_b = np.zeros((b, MODEL_FEATS), np.float32)
        mid_b = np.zeros(b, np.int32)
        qf_b[:n], af_b[:n], mf_b[:n], mid_b[:n] = qf, af, mf, mid
        p_logit, len_logits = _tier0_jit(self.params, qf_b, af_b, mf_b,
                                         mid_b)
        return (np.asarray(p_logit)[:n], np.asarray(len_logits)[:n])

    def predict_pairs(self, qf: np.ndarray, af: np.ndarray, mf: np.ndarray,
                      mid: np.ndarray) -> Tier0Batch:
        p_logit, len_logits = self.forward_raw(qf, af, mf, mid)
        z = np.asarray(p_logit, np.float64) / self.temperature
        p = 1.0 / (1.0 + np.exp(-z))
        lb = np.argmax(len_logits, axis=-1) if len(p) else \
            np.zeros(0, int)
        return Tier0Batch(
            p=p, y_hat=(p >= 0.5).astype(int),
            len_hat=np.asarray(tok.LEN_CENTERS)[lb].astype(np.float64),
            conf=np.maximum(p, 1.0 - p))

    def predict_features(
            self, feats: List[Tuple[np.ndarray, np.ndarray, np.ndarray,
                                    int]]) -> Tier0Batch:
        """``predict_pairs`` over a list of ``pair_features`` tuples."""
        if not feats:
            return Tier0Batch(np.zeros(0), np.zeros(0, int), np.zeros(0),
                              np.zeros(0))
        qf = np.stack([f[0] for f in feats])
        af = np.stack([f[1] for f in feats])
        mf = np.stack([f[2] for f in feats])
        mid = np.asarray([f[3] for f in feats], np.int32)
        return self.predict_pairs(qf, af, mf, mid)

    def with_temperature(self, temperature: float) -> "Tier0Head":
        return Tier0Head(self.params, self.cfg, temperature=temperature,
                         version=self.version)
