"""Feed-forward blocks: SwiGLU (llama/qwen/gemma family) and GeLU (whisper)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, shard_activation


def init_mlp(key, cfg: ModelConfig, d_ff: int = 0) -> Dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind == "gelu":
        return {"w1": dense_init(ks[0], (d, f)),
                "w2": dense_init(ks[1], (f, d))}
    return {"wi_gate": dense_init(ks[0], (d, f)),
            "wi_up": dense_init(ks[1], (d, f)),
            "wo": dense_init(ks[2], (f, d))}


def mlp_forward(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.mlp_kind == "gelu":
        h = jax.nn.gelu(x @ p["w1"])
        h = shard_activation(h, "batch", None, "ffn")
        return h @ p["w2"]
    h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    h = shard_activation(h, "batch", None, "ffn")
    return h @ p["wo"]
