"""Composable model definitions for every assigned architecture family."""
from repro.models import model  # noqa: F401
from repro.models.model import (  # noqa: F401
    decode_step, forward_train, init_cache, init_params, loss_fn, prefill)
