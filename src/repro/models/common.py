"""Shared model building blocks: norms, initializers, sharding helpers.

Parameters are plain nested dicts (pytrees) of jnp arrays.  Every submodule
exposes ``init_*(key, cfg) -> params`` and a pure ``apply`` function.  Layer
stacks are built by vmapping ``init`` over a leading layer axis and scanning
the apply function, so a 94-layer model traces a single layer body.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Ambient mesh for activation sharding constraints (set by the launcher).
# ---------------------------------------------------------------------------
_MESH_STATE = threading.local()


@contextlib.contextmanager
def activation_mesh(mesh, rules: Optional[dict] = None):
    """Install an ambient mesh so model code can constrain activations.

    ``rules`` maps logical names ("batch", "model") to mesh axis names (or
    tuples).  Outside this context ``shard_activation`` is the identity, so
    all model code runs unchanged on a single CPU device.
    """
    prev = getattr(_MESH_STATE, "ctx", None)
    _MESH_STATE.ctx = (mesh, rules or {})
    try:
        yield
    finally:
        _MESH_STATE.ctx = prev


def shard_activation(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply with_sharding_constraint using the ambient mesh, if any.

    ``logical_axes`` has one entry per array dim; entries are logical names
    resolved through the installed rules, or None for replicated dims.
    """
    ctx = getattr(_MESH_STATE, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))

    def resolve(dim_size, logical):
        if logical is None:
            return None
        axes = rules.get(logical)
        if axes is None:
            return None
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        total = 1
        for a in axes_t:
            total *= sizes[a]
        if dim_size % total != 0:
            return None                  # skip non-divisible constraints
        return axes
    spec = P(*(resolve(x.shape[i], a) for i, a in enumerate(logical_axes)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype=jnp.float32, scale: float = 1.0):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[0] if len(shape) >= 2 else max(1, shape[-1])
    std = scale / (fan_in ** 0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


def stacked_init(init_fn, key, num: int):
    """vmap an init function over a leading layer axis."""
    return jax.vmap(init_fn)(jax.random.split(key, num))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma2-style logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def sinusoidal_positions(length: int, dim: int, dtype=jnp.float32) -> jax.Array:
    """Standard sinusoidal position table (whisper-style)."""
    pos = jnp.arange(length)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, dim, 2).astype(jnp.float32)
                  * (-jnp.log(10000.0) / dim))
    tab = jnp.zeros((length, dim), jnp.float32)
    tab = tab.at[:, 0::2].set(jnp.sin(pos * div))
    tab = tab.at[:, 1::2].set(jnp.cos(pos * div))
    return tab.astype(dtype)
