"""Top-level model API.

  init_params(key, cfg)                      -> params pytree
  forward_train(params, cfg, batch)          -> (logits, aux)
  loss_fn(params, cfg, batch)                -> (loss, metrics)
  prefill(params, cfg, batch, max_len)       -> (logits, caches)
  decode_step(params, cfg, token, caches, pos) -> (logits, caches)
  init_cache(cfg, batch_size, max_len)       -> zeroed cache pytree

Batch dict keys: "tokens" (b, s) int32; optional "labels" (b, s) int32
(-100 = ignore), "enc_features" (b, enc_seq, d) for audio stubs,
"image_embeds" (b, P, d) for VLM stubs, "positions_3d" (3, b, s) for M-RoPE.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rope as rope_mod
from repro.models import transformer as tf
from repro.models.common import (
    dense_init, dtype_of, embed_init, init_rmsnorm, rmsnorm,
    shard_activation, sinusoidal_positions, softcap)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig) -> Dict:
    plan = tf.build_plan(cfg)
    ks = jax.random.split(key, len(plan) + 5)
    dt = dtype_of(cfg.dtype)
    cross = cfg.is_encoder_decoder

    params: Dict = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model)),
        "final_norm": init_rmsnorm(cfg.d_model),
        "segments": tuple(
            tf.init_segment(ks[i + 1], cfg, unit, count, cross=cross)
            for i, (unit, count) in enumerate(plan)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[-1], (cfg.d_model, cfg.vocab_size))
    if any("shared_attn" in unit for unit, _ in plan):
        params["shared_attn"] = tf.init_block(ks[-2], cfg, "attn")
    if cfg.is_encoder_decoder:
        enc_plan = [(("attn",), cfg.num_encoder_layers)]
        params["encoder"] = {
            "frontend_proj": dense_init(ks[-3], (cfg.d_model, cfg.d_model)),
            "segments": tuple(
                tf.init_segment(ks[-4], cfg, unit, count)
                for unit, count in enc_plan),
            "final_norm": init_rmsnorm(cfg.d_model),
        }
    if cfg.num_stub_patches > 0:
        params["vision_proj"] = dense_init(ks[-5], (cfg.d_model, cfg.d_model))
    params = jax.tree.map(
        lambda a: a.astype(dt) if a.dtype == jnp.float32 and a.ndim >= 2 else a,
        params)
    return params


# ---------------------------------------------------------------------------
# Position embeddings
# ---------------------------------------------------------------------------
def _cos_sin_full(cfg: ModelConfig, batch: Dict, b: int, s: int):
    if cfg.rope_kind == "none" or cfg.is_attention_free() and cfg.shared_attn_every == 0:
        return None, None
    hd = cfg.resolved_head_dim
    rope_dim = cfg.qk_rope_head_dim if any(
        tf._is_mla(k) for k in cfg.layer_kinds()) else hd
    if cfg.rope_kind == "mrope":
        pos3 = batch.get("positions_3d")
        if pos3 is None:
            pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            pos3 = rope_mod.text_positions_3d(pos)
        return rope_mod.mrope_cos_sin(pos3, rope_dim, cfg.rope_theta,
                                      cfg.mrope_sections)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    return rope_mod.rope_cos_sin(pos, rope_dim, cfg.rope_theta)


def _cos_sin_decode(cfg: ModelConfig, b: int, pos):
    """``pos``: (b,) int32 — per-row absolute position of the new token."""
    if cfg.rope_kind == "none" or cfg.is_attention_free() and cfg.shared_attn_every == 0:
        return None, None
    hd = cfg.resolved_head_dim
    rope_dim = cfg.qk_rope_head_dim if any(
        tf._is_mla(k) for k in cfg.layer_kinds()) else hd
    positions = pos[:, None]                              # (b, 1)
    if cfg.rope_kind == "mrope":
        return rope_mod.mrope_cos_sin(rope_mod.text_positions_3d(positions),
                                      rope_dim, cfg.rope_theta,
                                      cfg.mrope_sections)
    return rope_mod.rope_cos_sin(positions, rope_dim, cfg.rope_theta)


def _sinusoid_at(pos, d: int):
    """pos: (b,) -> (b, d) sinusoidal embedding at each row's position."""
    div = jnp.exp(jnp.arange(0, d, 2).astype(jnp.float32)
                  * (-jnp.log(10000.0) / d))
    ang = pos.astype(jnp.float32)[..., None] * div        # (b, d/2)
    out = jnp.zeros(ang.shape[:-1] + (d,), jnp.float32)
    out = out.at[..., 0::2].set(jnp.sin(ang))
    out = out.at[..., 1::2].set(jnp.cos(ang))
    return out


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def _embed(params, cfg: ModelConfig, tokens, batch: Dict):
    h = params["embed"][tokens]
    if cfg.scale_embeddings:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    if cfg.rope_kind == "none" and not cfg.is_attention_free():
        s = tokens.shape[1]
        h = h + sinusoidal_positions(s, cfg.d_model, h.dtype)[None]
    if cfg.num_stub_patches > 0 and "image_embeds" in batch:
        img = batch["image_embeds"] @ params["vision_proj"]
        npatch = img.shape[1]
        h = jnp.concatenate([img.astype(h.dtype), h[:, npatch:]], axis=1)
    return h


def _logits(params, cfg: ModelConfig, h):
    h = rmsnorm(params["final_norm"], h, cfg.rmsnorm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    logits = shard_activation(logits, "batch", None, "vocab")
    if cfg.final_logit_softcap > 0.0:
        logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return logits


def _encode(params, cfg: ModelConfig, enc_features):
    """Whisper-style encoder over stub frame embeddings."""
    enc = params["encoder"]
    h = enc_features @ enc["frontend_proj"]
    s = h.shape[1]
    h = h + sinusoidal_positions(s, cfg.d_model, h.dtype)[None]
    for seg, (unit, count) in zip(enc["segments"],
                                  [(("attn",), cfg.num_encoder_layers)],
                                  strict=False):
        h, _, _ = tf.segment_full(seg, None, cfg, unit, count, h, None, None,
                                  causal=False)
    return rmsnorm(enc["final_norm"], h, cfg.rmsnorm_eps)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------
def _forward_full(params, cfg: ModelConfig, batch: Dict, *,
                  want_cache: bool = False):
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = _embed(params, cfg, tokens, batch)
    h = shard_activation(h, "batch", None, None)
    cos, sin = _cos_sin_full(cfg, batch, b, s)

    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, batch["enc_features"])

    plan = tf.build_plan(cfg)
    shared = params.get("shared_attn")
    aux_total = jnp.zeros((), jnp.float32)
    caches = []
    for seg, (unit, count) in zip(params["segments"], plan, strict=True):
        h, aux, cache = tf.segment_full(seg, shared, cfg, unit, count, h,
                                        cos, sin, enc_out=enc_out,
                                        want_cache=want_cache)
        aux_total = aux_total + aux
        caches.append(cache)
    return _logits(params, cfg, h), aux_total, tuple(caches)


def forward_train(params, cfg: ModelConfig, batch: Dict):
    logits, aux, _ = _forward_full(params, cfg, batch)
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch: Dict):
    logits, aux = forward_train(params, cfg, batch)
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.concatenate(
            [batch["tokens"][:, 1:], jnp.full_like(batch["tokens"][:, :1], -100)],
            axis=1)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1)
    ce = jnp.sum(nll * mask) / denom
    loss = ce + cfg.router_aux_coef * aux
    acc = jnp.sum((jnp.argmax(logits, -1) == safe) * mask) / denom
    return loss, {"ce": ce, "aux": aux, "acc": acc}


def prefill(params, cfg: ModelConfig, batch: Dict):
    """Full forward returning per-layer caches sized to the prompt."""
    logits, _, caches = _forward_full(params, cfg, batch, want_cache=True)
    return logits, caches


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def decode_step(params, cfg: ModelConfig, token, caches, pos, *, paged=None):
    """token: (b, 1) int32; pos: scalar OR (b,) int32 — per-row count of
    tokens already cached (row ``i``'s new token lands at absolute position
    ``pos[i]``).  A scalar broadcasts to every row, so rows at different
    sequence positions share one compiled decode executable.

    ``paged`` = (PagedSpec, page table (b, W)) switches the attention
    caches to the block-paged layout from ``serving/kv_pool.py``; the RoPE
    rotation, embedding and head math are untouched — positions stay
    absolute, only the KV storage addressing changes.

    Returns (logits (b, 1, V), new caches)."""
    b = token.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    h = params["embed"][token]
    if cfg.scale_embeddings:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    if cfg.rope_kind == "none" and not cfg.is_attention_free():
        h = h + _sinusoid_at(pos, cfg.d_model).astype(h.dtype)[:, None]
    cos, sin = _cos_sin_decode(cfg, b, pos)

    plan = tf.build_plan(cfg)
    shared = params.get("shared_attn")
    new_caches = []
    for seg, cache, (unit, count) in zip(params["segments"], caches, plan,
                                      strict=True):
        h, nc = tf.segment_decode(seg, shared, cfg, unit, count, h, cos, sin,
                                  cache, pos, paged=paged)
        new_caches.append(nc)
    return _logits(params, cfg, h), tuple(new_caches)


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------
def _block_cache_spec(cfg: ModelConfig, kind: str, b: int, S: int, dt):
    hd = cfg.resolved_head_dim if cfg.num_heads else 0
    if kind == "mamba":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        return {
            "conv": jnp.zeros((b, cfg.ssm_conv_width - 1, conv_dim), dt),
            "ssm": jnp.zeros((b, cfg.resolved_ssm_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32),
        }
    if tf._is_mla(kind):
        c = {"c_kv": jnp.zeros((b, S, cfg.kv_lora_rank), dt),
             "k_rope": jnp.zeros((b, S, cfg.qk_rope_head_dim), dt)}
    else:
        c = {"k": jnp.zeros((b, cfg.num_kv_heads, S, hd), dt),
             "v": jnp.zeros((b, cfg.num_kv_heads, S, hd), dt)}
    if cfg.is_encoder_decoder:
        c["ck"] = jnp.zeros((b, cfg.num_heads, cfg.encoder_seq_len, hd), dt)
        c["cv"] = jnp.zeros((b, cfg.num_heads, cfg.encoder_seq_len, hd), dt)
    return c


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    """Zeroed cache pytree shaped for ``decode_step``.

    Windowed layers (attn_local / force_window) allocate only
    window-sized KV rings... sized min(max_len, window + 1) here since the
    decode path indexes absolute positions we keep full length for
    correctness; the dry-run variant uses windowed sizes via
    ``cache_len_for``.
    """
    from repro.models.attention import resolve_window
    dt = dtype_of(cfg.dtype)
    plan = tf.build_plan(cfg)
    caches = []
    for unit, count in plan:
        unit_cache = {}
        for j, kind in enumerate(unit):
            kk = "attn" if kind == "shared_attn" else kind
            # windowed layers get ring buffers of exactly `window` slots
            w = resolve_window(cfg, kk) if not tf._is_mla(kk) else 0
            S = min(max_len, w) if w > 0 else max_len
            spec = _block_cache_spec(cfg, kk, batch_size, S, dt)
            unit_cache[str(j)] = jax.tree.map(
                lambda a, count=count: jnp.broadcast_to(a[None], (count,) + a.shape),
                spec)
        caches.append(unit_cache)
    return tuple(caches)
