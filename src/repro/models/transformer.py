"""Decoder/encoder stack assembly.

Layers are grouped into homogeneous **segments**; per-layer parameters are
stacked (leading layer axis) and the layer body is applied with
``jax.lax.scan`` so the traced HLO contains each distinct layer body once —
this keeps 94-layer MoE dry-run compiles tractable on 512 devices.

Unit patterns handle heterogeneous stacks:
  gemma2      -> unit ("attn_local", "attn") x 21
  zamba2      -> unit ("mamba",)*6 + ("shared_attn",) x 13  (+ remainder)
  deepseek-v2 -> segment ("mla",) x 1 (dense layer 0) + ("mla_moe",) x 26
``shared_attn`` blocks reuse one parameter set (closed over, Zamba2-style)
but keep per-occurrence KV caches.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (init_rmsnorm, rmsnorm, shard_activation,
                                 stacked_init)


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------
def build_plan(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    """Returns [(unit_kinds, count), ...] covering the decoder stack."""
    if cfg.has_ssm() and cfg.shared_attn_every > 0:
        every = cfg.shared_attn_every
        unit = ("mamba",) * every + ("shared_attn",)
        full = cfg.num_layers // every
        rem = cfg.num_layers % every
        plan = []
        if full:
            plan.append((unit, full))
        if rem:
            plan.append((("mamba",), rem))
        return plan

    kinds = list(cfg.layer_kinds())
    # first_dense_layers: MoE variants fall back to dense FFN
    for i in range(min(cfg.first_dense_layers, len(kinds))):
        kinds[i] = {"mla_moe": "mla", "moe": "attn"}.get(kinds[i], kinds[i])

    pat = cfg.block_pattern
    if (len(pat) > 1 and len(kinds) % len(pat) == 0
            and tuple(kinds[:len(pat)]) == pat
            and all(kinds[i] == pat[i % len(pat)] for i in range(len(kinds)))):
        return [(tuple(pat), len(kinds) // len(pat))]

    # group consecutive identical kinds
    plan = []
    i = 0
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        plan.append(((kinds[i],), j - i))
        i = j
    return plan


def _is_attn(kind: str) -> bool:
    return kind in ("attn", "attn_local", "moe", "shared_attn")


def _is_mla(kind: str) -> bool:
    return kind in ("mla", "mla_moe")


def _is_moe(kind: str) -> bool:
    return kind in ("moe", "mla_moe")


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------
def init_block(key, cfg: ModelConfig, kind: str, *, cross: bool = False) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    if kind == "mamba":
        return {"norm": init_rmsnorm(d), "mamba": ssm_mod.init_mamba(ks[0], cfg)}
    p: Dict = {"attn_norm": init_rmsnorm(d), "mlp_norm": init_rmsnorm(d)}
    if _is_mla(kind):
        p["attn"] = attn_mod.init_mla(ks[0], cfg)
    else:
        p["attn"] = attn_mod.init_gqa(ks[0], cfg)
    if _is_moe(kind):
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["mlp"] = mlp_mod.init_mlp(ks[1], cfg)
    if cfg.sandwich_norm:
        p["post_attn_norm"] = init_rmsnorm(d)
        p["post_mlp_norm"] = init_rmsnorm(d)
    if cross:
        p["cross_norm"] = init_rmsnorm(d)
        p["cross"] = attn_mod.init_cross(ks[2], cfg)
    return p


# ---------------------------------------------------------------------------
# Block apply — full sequence
# ---------------------------------------------------------------------------
def block_full(p: Dict, cfg: ModelConfig, kind: str, h: jax.Array,
               cos, sin, *, enc_out=None, causal: bool = True
               ) -> Tuple[jax.Array, Dict, jax.Array]:
    """Returns (h, cache, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    cache: Dict = {}
    if kind == "mamba":
        y, cache = ssm_mod.mamba_full(p["mamba"], cfg,
                                      rmsnorm(p["norm"], h, cfg.rmsnorm_eps))
        return shard_activation(h + y, "batch", None, "residual"), cache, aux

    x = rmsnorm(p["attn_norm"], h, cfg.rmsnorm_eps)
    if _is_mla(kind):
        y, kv = attn_mod.mla_full(p["attn"], cfg, x, cos, sin, kind=kind,
                                  causal=causal)
    else:
        y, kv = attn_mod.gqa_full(p["attn"], cfg, x, cos, sin, kind=kind,
                                  causal=causal)
    if cfg.sandwich_norm:
        y = rmsnorm(p["post_attn_norm"], y, cfg.rmsnorm_eps)
    h = h + y
    cache.update(kv)

    if "cross" in p and enc_out is not None:
        ckv = attn_mod.cross_kv(p["cross"], cfg, enc_out)
        xc = rmsnorm(p["cross_norm"], h, cfg.rmsnorm_eps)
        h = h + attn_mod.cross_attend(p["cross"], cfg, xc, ckv)
        cache.update(ckv)

    x2 = rmsnorm(p["mlp_norm"], h, cfg.rmsnorm_eps)
    if _is_moe(kind):
        y2, aux = moe_mod.moe_forward(p["moe"], cfg, x2)
    else:
        y2 = mlp_mod.mlp_forward(p["mlp"], cfg, x2)
    if cfg.sandwich_norm:
        y2 = rmsnorm(p["post_mlp_norm"], y2, cfg.rmsnorm_eps)
    out = shard_activation(h + y2, "batch", None, "residual")
    return out, cache, aux


# ---------------------------------------------------------------------------
# Block apply — single-token decode
# ---------------------------------------------------------------------------
def block_decode(p: Dict, cfg: ModelConfig, kind: str, h: jax.Array,
                 cos, sin, cache: Dict, pos, *, paged=None
                 ) -> Tuple[jax.Array, Dict]:
    """``paged`` = (PagedSpec, page table (b, W)) routes attention layers
    through the block-paged cache layout; ``kv_pool.check_paged_support``
    guarantees only plain GQA kinds reach here when it is set."""
    if kind == "mamba":
        y, new = ssm_mod.mamba_decode(p["mamba"], cfg,
                                      rmsnorm(p["norm"], h, cfg.rmsnorm_eps),
                                      cache)
        return h + y, new

    new_cache: Dict = {}
    x = rmsnorm(p["attn_norm"], h, cfg.rmsnorm_eps)
    if paged is not None:
        if _is_mla(kind):
            raise ValueError("paged decode does not support MLA layers")
        spec, table = paged
        y, kv = attn_mod.gqa_decode_paged(p["attn"], cfg, x, cos, sin,
                                          cache, pos, table, spec, kind=kind)
    elif _is_mla(kind):
        y, kv = attn_mod.mla_decode(p["attn"], cfg, x, cos, sin, cache, pos,
                                    kind=kind)
    else:
        y, kv = attn_mod.gqa_decode(p["attn"], cfg, x, cos, sin, cache, pos,
                                    kind=kind)
    if cfg.sandwich_norm:
        y = rmsnorm(p["post_attn_norm"], y, cfg.rmsnorm_eps)
    h = h + y
    new_cache.update(kv)

    if "cross" in p:
        ckv = {"ck": cache["ck"], "cv": cache["cv"]}
        xc = rmsnorm(p["cross_norm"], h, cfg.rmsnorm_eps)
        h = h + attn_mod.cross_attend(p["cross"], cfg, xc, ckv)
        new_cache.update(ckv)

    x2 = rmsnorm(p["mlp_norm"], h, cfg.rmsnorm_eps)
    if _is_moe(kind):
        y2, _ = moe_mod.moe_forward(p["moe"], cfg, x2)
    else:
        y2 = mlp_mod.mlp_forward(p["mlp"], cfg, x2)
    if cfg.sandwich_norm:
        y2 = rmsnorm(p["post_mlp_norm"], y2, cfg.rmsnorm_eps)
    return h + y2, new_cache


# ---------------------------------------------------------------------------
# Segment init / apply
# ---------------------------------------------------------------------------
def init_segment(key, cfg: ModelConfig, unit: Tuple[str, ...], count: int,
                 *, cross: bool = False) -> Dict:
    """Stacked per-unit params.  ``shared_attn`` kinds hold no per-layer
    params (tied set lives at model level)."""
    seg = {}
    ks = jax.random.split(key, len(unit))
    for j, kind in enumerate(unit):
        if kind == "shared_attn":
            continue
        seg[str(j)] = stacked_init(
            lambda k_, kind=kind: init_block(k_, cfg, kind, cross=cross),
            ks[j], count)
    return seg


def segment_full(seg_params: Dict, shared_params, cfg: ModelConfig,
                 unit: Tuple[str, ...], count: int, h: jax.Array, cos, sin,
                 *, enc_out=None, causal: bool = True, remat: bool = True,
                 want_cache: bool = True):
    """Scan the unit body over ``count`` stacked layers.

    The body is rematerialized (activation checkpointing, MaxText-style):
    backward recomputes layer internals instead of storing the blocked
    attention / SSD scan carries — without this, training memory explodes
    (the online-softmax accumulators of every KV block would be saved).
    """
    def body(carry, xs):
        hh, aux = carry
        caches = {}
        for j, kind in enumerate(unit):
            p = shared_params if kind == "shared_attn" else xs[str(j)]
            kk = "attn" if kind == "shared_attn" else kind
            hh, cache, a = block_full(p, cfg, kk, hh, cos, sin,
                                      enc_out=enc_out, causal=causal)
            if want_cache:
                caches[str(j)] = cache
            aux = aux + a
        return (hh, aux), caches

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), caches = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), seg_params, length=count)
    return h, aux, caches


def segment_decode(seg_params: Dict, shared_params, cfg: ModelConfig,
                   unit: Tuple[str, ...], count: int, h: jax.Array, cos, sin,
                   caches: Dict, pos, *, paged=None):
    # the page table (in ``paged``) is closed over, not scanned: every
    # layer shares one table while each scanned layer consumes its own
    # (n_pages, hkv, page, hd) slice of the stacked page storage
    def body(hh, xs):
        layer_caches = xs["__cache__"]
        new_caches = {}
        for j, kind in enumerate(unit):
            p = shared_params if kind == "shared_attn" else xs[str(j)]
            kk = "attn" if kind == "shared_attn" else kind
            hh, nc = block_decode(p, cfg, kk, hh, cos, sin,
                                  layer_caches[str(j)], pos, paged=paged)
            new_caches[str(j)] = nc
        return hh, new_caches

    xs = dict(seg_params)
    xs["__cache__"] = caches
    h, new_caches = jax.lax.scan(body, h, xs, length=count)
    return h, new_caches
