"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE splits the head dim into (temporal, height, width) sections; each
section rotates by its own position stream.  For text-only tokens all three
streams coincide, recovering standard RoPE.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2).astype(jnp.float32)
                            / head_dim))


def _rotate_half(x: jax.Array) -> jax.Array:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float
                 ) -> Tuple[jax.Array, jax.Array]:
    """positions: (..., seq) int32 -> cos/sin of shape (..., seq, head_dim)."""
    freqs = rope_frequencies(head_dim, theta)           # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    angles = jnp.concatenate([angles, angles], axis=-1)  # (..., s, hd)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (batch, seq, heads, head_dim); cos/sin: (batch, seq, head_dim)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return (x32 * c + _rotate_half(x32) * s).astype(dt)


def mrope_cos_sin(positions_3d: jax.Array, head_dim: int, theta: float,
                  sections: Sequence[int]) -> Tuple[jax.Array, jax.Array]:
    """Qwen2-VL multimodal RoPE.

    positions_3d: (3, batch, seq) int32 — temporal / height / width streams.
    ``sections`` gives the per-stream share of head_dim (sums to head_dim);
    internally each stream owns ``sections[i] // 2`` of the hd/2 frequency
    slots, interleaved as in the reference implementation.
    """
    assert sum(sections) == head_dim, (sections, head_dim)
    freqs = rope_frequencies(head_dim, theta)            # (hd/2,)
    # (3, b, s, hd/2)
    angles = positions_3d[..., None].astype(jnp.float32) * freqs
    half_secs = [s // 2 for s in sections]
    # pick stream i for its slice of the hd/2 frequency axis
    parts = []
    start = 0
    for i, hs in enumerate(half_secs):
        parts.append(angles[i, ..., start:start + hs])
        start += hs
    merged = jnp.concatenate(parts, axis=-1)             # (b, s, hd/2)
    merged = jnp.concatenate([merged, merged], axis=-1)  # (b, s, hd)
    return jnp.cos(merged), jnp.sin(merged)


def text_positions_3d(positions: jax.Array) -> jax.Array:
    """Lift 1-D positions (batch, seq) to degenerate 3-D M-RoPE streams."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)
