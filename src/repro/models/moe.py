"""Mixture-of-Experts FFN (Qwen3-MoE, DeepSeek-V2 style).

TPU-idiomatic token-choice routing with capacity buckets, in the
**einsum-dispatch** form (Mesh-TF / Flaxformer lineage):

  1. tokens are regrouped into routing groups of <= MOE_GROUP tokens —
     small groups keep the (T, E, C) dispatch tensor tiny (C scales with
     group size) while remaining MXU-friendly;
  2. per group, top-k choices get a position-in-expert via a cumsum rank;
     tokens beyond capacity drop (capacity_factor);
  3. dispatch/combine are one-hot einsums — **no scatter/gather**: data-
     dependent scatters defeat the SPMD partitioner, which replicates the
     (G, E, C, d) buffer and all-reduces it across the mesh (measured:
     80 TB/device of all-reduce on qwen3-moe train_4k; see EXPERIMENTS.md
     §Perf HC2).  Einsums shard cleanly: the expert axis resharding lowers
     to the expected expert-parallel all-to-all;
  4. per-expert SwiGLU runs as batched einsums on the MXU (experts sharded
     on the ``model`` axis);
  5. shared experts (DeepSeek) are a dense SwiGLU on every token.

The load-balance auxiliary loss is the switch-style E * sum(f_e * P_e).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, shard_activation
from repro.models.mlp import init_mlp, mlp_forward

MOE_GROUP = 256


def init_moe(key, cfg: ModelConfig) -> Dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.resolved_moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), scale=1.0),
        "wi_gate": jax.vmap(lambda k_: dense_init(k_, (d, f)))(
            jax.random.split(ks[1], e)),
        "wi_up": jax.vmap(lambda k_: dense_init(k_, (d, f)))(
            jax.random.split(ks[2], e)),
        "wo": jax.vmap(lambda k_: dense_init(k_, (f, d)))(
            jax.random.split(ks[3], e)),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = init_mlp(ks[4], cfg,
                               d_ff=cfg.resolved_moe_d_ff * cfg.num_shared_experts)
    return p


def _group_size(total: int) -> int:
    g = min(MOE_GROUP, total)
    while total % g != 0:
        g -= 1
    return g


def moe_forward(p: Dict, cfg: ModelConfig, x: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (y, aux_load_balance_loss)."""
    b, s, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    total = b * s
    T = _group_size(total)
    G = total // T
    C = max(int(math.ceil(T * k / E * cfg.capacity_factor)), 1)

    xg = x.reshape(G, T, d)
    xg = shard_activation(xg, "batch", None, None)
    logits = (xg.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (G, T, E)
    top_p, top_i = jax.lax.top_k(probs, k)                     # (G, T, k)
    top_p = top_p / (jnp.sum(top_p, -1, keepdims=True) + 1e-9)

    # --- position-in-expert via cumsum rank over the (T*k) flat order ----
    oe = jax.nn.one_hot(top_i, E, dtype=jnp.float32)           # (G, T, k, E)
    oe_flat = oe.reshape(G, T * k, E)
    pos = jnp.cumsum(oe_flat, axis=1) * oe_flat                # rank occurrences
    pos = jnp.sum(pos, axis=-1).reshape(G, T, k) - 1.0         # (G, T, k)
    keep = (pos < C).astype(jnp.float32)
    pos_c = jnp.clip(pos, 0, C - 1).astype(jnp.int32)

    # --- one-hot dispatch / combine tensors (no scatter) -----------------
    # build in the activation dtype: the (G,T,E,C) products are the largest
    # routing tensors and exact in bf16 (entries are 0/1 and top-k probs)
    oe_a = oe.astype(x.dtype)
    oc = (jax.nn.one_hot(pos_c, C, dtype=jnp.float32)
          * keep[..., None]).astype(x.dtype)
    dispatch = jnp.einsum("gtke,gtkc->gtec", oe_a, oc)         # (G, T, E, C)
    combine = jnp.einsum("gtke,gtkc->gtec", oe_a,
                         oc * top_p[..., None].astype(x.dtype))
    dispatch = shard_activation(dispatch, "batch", None, None, None)
    combine = shard_activation(combine, "batch", None, None, None)

    # --- dispatch to experts ---------------------------------------------
    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg)     # (G, E, C, d)
    expert_in = shard_activation(expert_in, "batch", None, None, None)

    # --- expert compute: weight-gathered expert parallelism --------------
    # Tokens stay sharded on (pod, data); the (much smaller) expert weights
    # are gathered per layer instead.  Resharding tokens group->expert made
    # GSPMD all-gather the full global expert_in (86 GB/layer); weights are
    # 4.8 GB/layer — an 18x collective reduction (EXPERIMENTS.md §Perf HC2).
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["wi_gate"])) \
        * jnp.einsum("gecd,edf->gecf", expert_in, p["wi_up"])
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["wo"])      # (G, E, C, d)
    expert_out = shard_activation(expert_out, "batch", None, None, None)

    # --- combine ----------------------------------------------------------
    y = jnp.einsum("gtec,gecd->gtd", combine, expert_out)
    y = y.reshape(b, s, d)

    if cfg.num_shared_experts > 0:
        y = y + mlp_forward(p["shared"], cfg, x)

    # --- load-balance aux loss -------------------------------------------
    frac_tokens = jnp.sum(oe, axis=(0, 1, 2)) / (G * T * k)    # f_e
    mean_prob = jnp.mean(probs, axis=(0, 1))                   # P_e
    aux = E * jnp.sum(frac_tokens * mean_prob)
    return y, aux
