"""Attention blocks: GQA (RoPE / M-RoPE / softcap / sliding window),
cross-attention (enc-dec), and Multi-head Latent Attention (DeepSeek-V2).

Shapes: activations are (batch, seq, d_model); heads are split internally.
Every block exposes a full-sequence path (train/prefill, returns the KV
cache slice) and a single-token decode path (reads/writes a cache).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import rope as rope_mod
from repro.models.common import dense_init, init_rmsnorm, rmsnorm, shard_activation


def resolve_window(cfg: ModelConfig, kind: str) -> int:
    if cfg.force_window > 0:
        return cfg.force_window
    if kind == "attn_local":
        return cfg.sliding_window
    return 0


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def init_gqa(key, cfg: ModelConfig) -> Dict:
    d, hq, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd)),
        "wk": dense_init(ks[1], (d, hkv * hd)),
        "wv": dense_init(ks[2], (d, hkv * hd)),
        "wo": dense_init(ks[3], (hq * hd, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _gqa_qkv(p, cfg: ModelConfig, x, cos, sin):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.rmsnorm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.rmsnorm_eps)
    if cos is not None:
        q = rope_mod.apply_rope(q, cos, sin)
        k = rope_mod.apply_rope(k, cos, sin)
    return q, k, v


def gqa_full(p, cfg: ModelConfig, x, cos, sin, *, kind: str = "attn",
             causal: bool = True) -> Tuple[jax.Array, Dict]:
    """Full-sequence GQA.  Returns (y, {"k", "v"} cache slice)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q, k, v = _gqa_qkv(p, cfg, x, cos, sin)
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    qh = shard_activation(qh, "batch", "heads", None, None)
    out = ops.flash_attention(
        qh, kh, vh, causal=causal,
        window=resolve_window(cfg, kind),
        softcap=cfg.logit_softcap,
        scale=cfg.attn_scale or None)
    y = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.num_heads * hd)
    y = y @ p["wo"]
    return y, {"k": kh, "v": vh}


def gqa_decode(p, cfg: ModelConfig, x, cos, sin, cache: Dict, pos,
               *, kind: str = "attn") -> Tuple[jax.Array, Dict]:
    """Single-token GQA decode.

    x: (b, 1, d); cache["k"/"v"]: (b, hkv, S, hd); pos: scalar or (b,)
    int — per-row number of tokens already cached (row ``i``'s new token
    has absolute position ``pos[i]``).  Per-row positions let rows at
    different sequence offsets (continuous batching, ragged prompt
    lengths) share one decode executable: the new KV lands at each row's
    own slot and the attention mask sees each row's own valid length.

    Windowed layers whose cache is allocated at exactly ``window`` entries
    run in **ring-buffer mode**: the new KV lands at ``pos % window`` and
    attention sees min(pos+1, window) valid slots — softmax is permutation
    invariant, so slot order is irrelevant.  This keeps long_500k decode
    memory/traffic at O(window), not O(context) (EXPERIMENTS.md §Perf HC3).
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k, v = _gqa_qkv(p, cfg, x, cos, sin)
    window = resolve_window(cfg, kind)
    S_cache = cache["k"].shape[2]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    ring = window > 0 and S_cache == window
    if ring:
        slot = pos % window
        valid = jnp.minimum(pos + 1, window)
        attn_window = 0                     # ring already enforces it
    else:
        slot = pos
        valid = pos + 1
        attn_window = window
    rows = jnp.arange(b)
    kc = cache["k"].at[rows, :, slot].set(
        k.transpose(0, 2, 1, 3)[:, :, 0].astype(cache["k"].dtype),
        unique_indices=True)
    vc = cache["v"].at[rows, :, slot].set(
        v.transpose(0, 2, 1, 3)[:, :, 0].astype(cache["v"].dtype),
        unique_indices=True)
    out = ops.decode_attention(
        q.transpose(0, 2, 1, 3), kc, vc, valid,
        window=attn_window,
        softcap=cfg.logit_softcap,
        scale=cfg.attn_scale or None)
    y = out.transpose(0, 2, 1, 3).reshape(b, 1, cfg.num_heads * hd)
    return y @ p["wo"], {"k": kc, "v": vc}


def gqa_decode_paged(p, cfg: ModelConfig, x, cos, sin, cache: Dict, pos,
                     table, spec, *, kind: str = "attn"
                     ) -> Tuple[jax.Array, Dict]:
    """Single-token GQA decode against a block-paged cache.

    cache["k"/"v"]: (n_pages, hkv, page_size, hd) physical pages shared by
    the whole batch; ``table``: (b, W) int32 page table; ``spec``: a
    ``PagedSpec`` (static page_size / kv_cap / kernel).  The new KV
    scatters into slot ``pos % page_size`` of physical page
    ``table[row, pos // page_size]``.  The logical page index is clamped
    to the table width: live rows never pass ``kv_cap`` (the sampler
    guards each segment), so the clamp only fires for retired/done rows
    whose table points at the trash page — their PAD writes collide there
    harmlessly, which is also why the scatter must NOT claim unique
    indices.  No ring-buffer mode: paged layers are full-window only
    (``kv_pool.check_paged_support``).
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k, v = _gqa_qkv(p, cfg, x, cos, sin)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    lp = jnp.minimum(pos // spec.page_size, table.shape[1] - 1)
    rows = jnp.arange(b)
    pid = table[rows, lp]                                # (b,)
    slot = pos % spec.page_size
    kc = cache["k"].at[pid, :, slot].set(
        k.transpose(0, 2, 1, 3)[:, :, 0].astype(cache["k"].dtype))
    vc = cache["v"].at[pid, :, slot].set(
        v.transpose(0, 2, 1, 3)[:, :, 0].astype(cache["v"].dtype))
    out = ops.paged_decode_attention(
        q.transpose(0, 2, 1, 3), kc, vc, pos + 1, table,
        page_size=spec.page_size, kv_cap=spec.kv_cap,
        softcap=cfg.logit_softcap, scale=cfg.attn_scale or None,
        kernel=spec.kernel)
    y = out.transpose(0, 2, 1, 3).reshape(b, 1, cfg.num_heads * hd)
    return y @ p["wo"], {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------
def init_cross(key, cfg: ModelConfig) -> Dict:
    d, hq = cfg.d_model, cfg.num_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, hq * hd)),
        "wk": dense_init(ks[1], (d, hq * hd)),
        "wv": dense_init(ks[2], (d, hq * hd)),
        "wo": dense_init(ks[3], (hq * hd, d)),
    }


def cross_kv(p, cfg: ModelConfig, enc_out) -> Dict:
    """Project encoder output once; cached for the whole decode."""
    b, se, _ = enc_out.shape
    hq, hd = cfg.num_heads, cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(b, se, hq, hd).transpose(0, 2, 1, 3)
    v = (enc_out @ p["wv"]).reshape(b, se, hq, hd).transpose(0, 2, 1, 3)
    return {"ck": k, "cv": v}


def cross_attend(p, cfg: ModelConfig, x, kv: Dict) -> jax.Array:
    b, s, _ = x.shape
    hq, hd = cfg.num_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    out = ops.flash_attention(q, kv["ck"], kv["cv"], causal=False)
    y = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return y @ p["wo"]


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 family)
# ---------------------------------------------------------------------------
def init_mla(key, cfg: ModelConfig) -> Dict:
    d, h = cfg.d_model, cfg.num_heads
    nope, rdim, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], (d, h * (nope + rdim))),
        "w_dkv": dense_init(ks[1], (d, lora + rdim)),
        "kv_norm": init_rmsnorm(lora),
        "w_uk": dense_init(ks[2], (lora, h * nope)),
        "w_uv": dense_init(ks[3], (lora, h * vdim)),
        "wo": dense_init(ks[4], (h * vdim, d)),
    }


def _mla_q(p, cfg: ModelConfig, x, cos, sin):
    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = (x @ p["wq"]).reshape(b, s, h, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope_mod.apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_compress(p, cfg: ModelConfig, x, cos, sin):
    """Down-project to the latent cache: c_kv (b,s,lora) + k_rope (b,s,rdim)."""
    lora, rdim = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    dkv = x @ p["w_dkv"]
    c_kv = rmsnorm(p["kv_norm"], dkv[..., :lora], cfg.rmsnorm_eps)
    k_rope = dkv[..., lora:][:, :, None, :]                 # 1 shared head
    k_rope = rope_mod.apply_rope(k_rope, cos, sin)[:, :, 0]
    return c_kv, k_rope


def mla_full(p, cfg: ModelConfig, x, cos, sin, *, kind: str = "mla",
             causal: bool = True) -> Tuple[jax.Array, Dict]:
    """Full-sequence MLA (naive/up-projected form for train & prefill)."""
    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rdim, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, cos, sin)
    c_kv, k_rope = _mla_compress(p, cfg, x, cos, sin)

    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, nope)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, vdim)
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, rdim))

    q = jnp.concatenate([q_nope, q_rope], -1).transpose(0, 2, 1, 3)
    k = jnp.concatenate([k_nope, k_rope_h], -1).transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    scale = (nope + rdim) ** -0.5
    out = ops.flash_attention(q, k, vh, causal=causal, scale=scale,
                              window=resolve_window(cfg, kind),
                              softcap=cfg.logit_softcap)
    y = out.transpose(0, 2, 1, 3).reshape(b, s, h * vdim)
    return y @ p["wo"], {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(p, cfg: ModelConfig, x, cos, sin, cache: Dict, pos,
               *, kind: str = "mla") -> Tuple[jax.Array, Dict]:
    """Absorbed-form MLA decode: attention runs in the latent space.

    cache: {"c_kv": (b, S, lora), "k_rope": (b, S, rdim)}.  ``pos`` is a
    scalar or per-row (b,) position, as in ``gqa_decode``.  The up
    projections w_uk/w_uv are folded into the query / output instead of
    re-expanding the cache each step (the TPU-friendly serving form — the
    naive form would up-project all S cached entries per token).
    """
    b = x.shape[0]
    h = cfg.num_heads
    nope, rdim, vdim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    q_nope, q_rope = _mla_q(p, cfg, x, cos, sin)            # (b,1,h,·)
    c_kv_new, k_rope_new = _mla_compress(p, cfg, x, cos, sin)

    rows = jnp.arange(b)
    ckv = cache["c_kv"].at[rows, pos].set(
        c_kv_new[:, 0].astype(cache["c_kv"].dtype), unique_indices=True)
    krope = cache["k_rope"].at[rows, pos].set(
        k_rope_new[:, 0].astype(cache["k_rope"].dtype), unique_indices=True)

    # absorb w_uk into q: q_lat[b,h,lora] = sum_n q_nope[b,h,n] w_uk[lora,h,n]
    w_uk = p["w_uk"].reshape(lora, h, nope)
    q_lat = jnp.einsum("bhn,lhn->bhl", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = (nope + rdim) ** -0.5
    s_lat = jnp.einsum("bhl,bsl->bhs", q_lat,
                       ckv.astype(jnp.float32)) * scale
    s_rope = jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                        krope.astype(jnp.float32)) * scale
    s = s_lat + s_rope
    if cfg.logit_softcap > 0.0:
        s = cfg.logit_softcap * jnp.tanh(s / cfg.logit_softcap)
    S = ckv.shape[1]
    kpos = jnp.arange(S)[None, None]                    # (1, 1, S)
    mask = kpos <= pos[:, None, None]                   # (b, 1, S)
    window = resolve_window(cfg, kind)
    if window > 0:
        mask = mask & (kpos > pos[:, None, None] - window)
    s = jnp.where(mask, s, -1e30)
    probs = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsl->bhl", probs, ckv.astype(jnp.float32))
    w_uv = p["w_uv"].reshape(lora, h, vdim)
    v_ctx = jnp.einsum("bhl,lhv->bhv", ctx_lat, w_uv.astype(jnp.float32))
    y = v_ctx.reshape(b, 1, h * vdim).astype(x.dtype) @ p["wo"]
    return y, {"c_kv": ckv, "k_rope": krope}
