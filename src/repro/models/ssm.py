"""Mamba2 block (SSD — state-space duality) [arXiv:2405.21060].

Full-sequence path uses the chunked SSD scan (``kernels.ops.ssd``);
decode maintains an O(1) recurrent state (conv window + SSM state), which is
what makes long_500k decode linear for mamba2/zamba2.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, init_rmsnorm, rmsnorm, shard_activation


def init_mamba(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    din = cfg.d_inner
    n = cfg.ssm_state
    heads = cfg.resolved_ssm_heads
    conv_dim = din + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din + 2 * n + heads)),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_dim), scale=1.0),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((heads,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "norm": init_rmsnorm(din),
        "out_proj": dense_init(ks[3], (din, d)),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    din, n, heads = cfg.d_inner, cfg.ssm_state, cfg.resolved_ssm_heads
    z = zxbcdt[..., :din]
    xBC = zxbcdt[..., din:din + din + 2 * n]
    dt = zxbcdt[..., -heads:]
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq: xBC (b, l, c); w (width, c)."""
    width = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1]] * w[i][None, None]
              for i in range(width))
    return jax.nn.silu(out + b[None, None])


def mamba_full(p: Dict, cfg: ModelConfig, x: jax.Array,
               init_state: Optional[Dict] = None
               ) -> Tuple[jax.Array, Dict]:
    """x: (b, l, d) -> (y, cache {"conv", "ssm"})."""
    b, l, d = x.shape
    din, n = cfg.d_inner, cfg.ssm_state
    heads, hd = cfg.resolved_ssm_heads, cfg.ssm_head_dim

    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    x_in, B, C = xBC[..., :din], xBC[..., din:din + n], xBC[..., din + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None])            # (b, l, h)
    A = -jnp.exp(p["A_log"])

    xh = x_in.reshape(b, l, heads, hd)
    xh = shard_activation(xh, "batch", None, "heads", None)
    from repro.kernels import ops                                 # local import
    chunk = cfg.ssm_chunk if l % cfg.ssm_chunk == 0 else (
        1 if l == 1 else _largest_chunk(l, cfg.ssm_chunk))
    y, final_state = ops.ssd(
        xh, dt, A, B.astype(jnp.float32), C.astype(jnp.float32),
        chunk=chunk,
        init_state=None if init_state is None else init_state["ssm"])
    y = y + xh * p["D"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(b, l, din)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.rmsnorm_eps)
    out = y @ p["out_proj"]

    conv_state = _conv_tail(cfg, zxbcdt)
    return out, {"conv": conv_state, "ssm": final_state}


def _largest_chunk(l: int, preferred: int) -> int:
    for c in range(min(preferred, l), 0, -1):
        if l % c == 0:
            return c
    return 1


def _conv_tail(cfg: ModelConfig, zxbcdt: jax.Array) -> jax.Array:
    """Last (width-1) pre-conv xBC inputs — the decode conv state."""
    _, xBC, _ = _split_proj(cfg, zxbcdt)
    w = cfg.ssm_conv_width
    b, l, c = xBC.shape
    if l >= w - 1:
        return xBC[:, l - (w - 1):]
    pad = jnp.zeros((b, w - 1 - l, c), xBC.dtype)
    return jnp.concatenate([pad, xBC], axis=1)


def mamba_decode(p: Dict, cfg: ModelConfig, x: jax.Array, cache: Dict
                 ) -> Tuple[jax.Array, Dict]:
    """Single-token recurrent step.

    x: (b, 1, d); cache: {"conv": (b, width-1, conv_dim),
    "ssm": (b, heads, head_dim, n)}.
    """
    b = x.shape[0]
    din, n = cfg.d_inner, cfg.ssm_state
    heads, hd = cfg.resolved_ssm_heads, cfg.ssm_head_dim
    width = cfg.ssm_conv_width

    zxbcdt = x @ p["in_proj"]
    z, xBC_new, dt = _split_proj(cfg, zxbcdt)                   # (b,1,·)
    conv_in = jnp.concatenate([cache["conv"], xBC_new], axis=1)  # (b,w,c)
    conv_out = jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    xBC = jax.nn.silu(conv_out + p["conv_b"][None]).astype(x.dtype)
    x_in, B, C = xBC[:, :din], xBC[:, din:din + n], xBC[:, din + n:]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None])
    A = -jnp.exp(p["A_log"])

    from repro.kernels import ops
    xh = x_in.reshape(b, heads, hd)
    y, new_ssm = ops.ssd_decode_step(
        cache["ssm"], xh.astype(jnp.float32), dt, A,
        B.astype(jnp.float32), C.astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(b, 1, din).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.rmsnorm_eps)
    out = y @ p["out_proj"]
    new_conv = conv_in[:, 1:]
    return out, {"conv": new_conv, "ssm": new_ssm}
