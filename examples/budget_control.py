"""Budget-aware control (Fig. 8 / Appendix D): hand SCOPE a set-level
dollar budget via ``SetBudgetPolicy``; it solves for alpha* with the
Prop. D.1 finite breakpoint search and routes within the budget.  Every
budget in the sweep reuses the same cached pool predictions — one estimator
pass for the whole figure.

  PYTHONPATH=src python examples/budget_control.py
"""
import jax
import numpy as np

from repro.api import EngineConfig, RouteRequest, ScopeEngine, SetBudgetPolicy
from repro.configs.scope_estimator import TINY
from repro.core.estimator import ReasoningEstimator
from repro.launch.train import build_world
from repro.models import model as M
from repro.training.sft import build_sft_dataset, train_sft


def main():
    world, data, lib, retr = build_world(400, 150, seed=0)
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    ds = build_sft_dataset(data, lib, retr, max_examples=2500)
    params, _ = train_sft(params, TINY, ds, steps=200, batch_size=32)

    engine = ScopeEngine.build(EngineConfig(
        estimator=ReasoningEstimator(TINY, params), retriever=retr,
        library=lib, models_meta={m: world.models[m] for m in data.models}))
    qids = data.test_qids[:24]
    queries = [data.queries[int(q)] for q in qids]
    pool = engine.predict(RouteRequest(queries))

    lo = float(pool.cost_hat.min(1).sum())
    hi = float(pool.cost_hat.max(1).sum())
    print(f"feasible cost range for {len(qids)} queries: "
          f"${lo:.4f} .. ${hi:.4f}")
    for budget in np.geomspace(lo * 1.1, hi, 5):
        rep = engine.serve(data, qids, SetBudgetPolicy(float(budget)))
        print(f"budget=${budget:.4f} -> alpha*={rep.alpha:.3f} "
              f"predicted=${rep.info['expected_cost']:.4f} "
              f"realized=${rep.total_cost:.4f} acc={rep.accuracy:.2f} "
              f"cache={rep.cache_hits}h/{rep.cache_misses}m")


if __name__ == "__main__":
    main()
