"""Budget-aware control (Fig. 8 / Appendix D): hand SCOPE a set-level
dollar budget; it solves for alpha* with the Prop. D.1 finite breakpoint
search and routes within the budget.

  PYTHONPATH=src python examples/budget_control.py
"""
import jax
import numpy as np

from repro.configs.scope_estimator import TINY
from repro.core.estimator import ReasoningEstimator
from repro.core.router import ScopeRouter
from repro.launch.train import build_world
from repro.models import model as M
from repro.training.sft import build_sft_dataset, train_sft


def main():
    world, data, lib, retr = build_world(400, 150, seed=0)
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    ds = build_sft_dataset(data, lib, retr, max_examples=2500)
    params, _ = train_sft(params, TINY, ds, steps=200, batch_size=32)

    est = ReasoningEstimator(TINY, params)
    router = ScopeRouter(est, retr, lib, world.models,
                         {m: i for i, m in enumerate(data.models)})
    qids = data.test_qids[:24]
    queries = [data.queries[int(q)] for q in qids]
    pool = router.predict_pool(queries, data.models)

    lo = float(pool.cost_hat.min(1).sum())
    hi = float(pool.cost_hat.max(1).sum())
    print(f"feasible cost range for {len(qids)} queries: "
          f"${lo:.4f} .. ${hi:.4f}")
    for budget in np.geomspace(lo * 1.1, hi, 5):
        alpha, choices, info = router.route_with_budget(pool, float(budget))
        real = sum(data.record(int(q), data.models[c]).cost
                   for q, c in zip(qids, choices))
        acc = np.mean([data.record(int(q), data.models[c]).y
                       for q, c in zip(qids, choices)])
        print(f"budget=${budget:.4f} -> alpha*={alpha:.3f} "
              f"predicted=${info['expected_cost']:.4f} "
              f"realized=${real:.4f} acc={acc:.2f}")


if __name__ == "__main__":
    main()
