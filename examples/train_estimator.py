"""End-to-end driver: train the SCOPE estimator for a few hundred steps
(SFT -> GRPO), evaluate predictive quality, save a checkpoint.

This wraps the production launcher; pass --size 100m for a ~100M-parameter
backbone (slower on CPU) or keep the default tiny config.

  PYTHONPATH=src python examples/train_estimator.py
  PYTHONPATH=src python examples/train_estimator.py --size 100m --sft-steps 200
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.exit(main())
