"""Serve a batch of requests through the `repro.api` surface, including
training-free onboarding of unseen (OOD) models — the paper's headline
generalization mechanism — and cached re-serving.

The flow demonstrates the two claims the API is built around:
  1. scalable pool growth: after an initial serve, onboarding a new model
     and re-serving the same batch reuses every cached (query, model)
     estimate — the estimator runs only for the new model's pairs;
  2. controllable trade-offs: the same batch is served under several
     distinct ``RoutingPolicy`` implementations, no router internals touched.

  PYTHONPATH=src python examples/serve_router.py
"""
import jax

from repro.api import (
    AccuracyFloorPolicy, CostCeilingPolicy, EngineConfig, FixedAlphaPolicy,
    ScopeEngine, SetBudgetPolicy)
from repro.core.estimator import ReasoningEstimator
from repro.launch.train import build_world
from repro.models import model as M
from repro.training.sft import build_sft_dataset, train_sft
from repro.configs.scope_estimator import TINY


def main():
    world, data, lib, retr = build_world(400, 150, seed=0)
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    ds = build_sft_dataset(data, lib, retr, max_examples=2500)
    params, _ = train_sft(params, TINY, ds, steps=200, batch_size=32)

    engine = ScopeEngine.build(EngineConfig(
        estimator=ReasoningEstimator(TINY, params), retriever=retr,
        library=lib, models_meta={m: world.models[m] for m in data.models}))

    # ---- initial serve over the seen pool (cold cache) ----
    qids = data.test_qids[:16]
    rep = engine.serve(data, qids, FixedAlphaPolicy(0.7))
    print(f"[seen pool]   acc={rep.accuracy:.2f} cost=${rep.total_cost:.4f} "
          f"overhead={rep.overhead_tokens}tok "
          f"cache={rep.cache_hits}h/{rep.cache_misses}m")

    # ---- onboard the unseen models mid-session: fingerprints only, ----
    # ---- no retraining; re-serving reuses every cached estimate     ----
    unseen = [m.name for m in world.pool if not m.seen]
    for m in unseen:
        engine.onboard(world, m, seed=99)
    data.extend_models(unseen, seed=99)
    rep2 = engine.serve(data, qids, FixedAlphaPolicy(0.7))
    assert rep2.cache_misses == len(qids) * len(unseen), \
        "estimator must run only for the onboarded models' pairs"
    print(f"[+{len(unseen)} unseen] acc={rep2.accuracy:.2f} "
          f"cost=${rep2.total_cost:.4f} overhead={rep2.overhead_tokens}tok "
          f"cache={rep2.cache_hits}h/{rep2.cache_misses}m "
          f"portfolio={ {k: round(v, 2) for k, v in rep2.per_model_share.items() if v > 0} }")

    # ---- same batch, distinct control policies (now fully cached) ----
    budget = rep2.total_cost * 0.5
    ceiling = float(max(d.cost_hat for d in rep2.decisions))
    for policy in (FixedAlphaPolicy(0.3),
                   SetBudgetPolicy(budget),
                   AccuracyFloorPolicy(0.6),
                   CostCeilingPolicy(ceiling * 0.25, alpha=0.7)):
        r = engine.serve(data, qids, policy)
        assert r.cache_misses == 0, "policy sweep must be estimator-free"
        extra = {k: v for k, v in r.info.items()
                 if k in ("feasible", "fallback_queries")}
        print(f"[{policy.name:>14}] alpha={r.alpha if r.alpha is None else round(r.alpha, 3)} "
              f"acc={r.accuracy:.2f} cost=${r.total_cost:.4f} {extra}")


if __name__ == "__main__":
    main()
