"""Serve a batch of requests through the SCOPE routing service, including
training-free onboarding of unseen (OOD) models — the paper's headline
generalization mechanism.

  PYTHONPATH=src python examples/serve_router.py
"""
import jax
import numpy as np

from repro.core.estimator import ReasoningEstimator
from repro.core.router import ScopeRouter
from repro.data.datasets import build_scope_data
from repro.launch.train import build_world
from repro.models import model as M
from repro.serving.router_service import RouterService
from repro.training.sft import build_sft_dataset, train_sft
from repro.configs.scope_estimator import TINY


def main():
    world, data, lib, retr = build_world(400, 150, seed=0)
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    ds = build_sft_dataset(data, lib, retr, max_examples=2500)
    params, _ = train_sft(params, TINY, ds, steps=200, batch_size=32)
    est = ReasoningEstimator(TINY, params)

    # ---- seen pool ----
    router = ScopeRouter(est, retr, lib, world.models,
                         {m: i for i, m in enumerate(data.models)})
    service = RouterService(router, data, data.models)
    rep = service.serve(data.test_qids[:16], alpha=0.7)
    print(f"[seen pool]   acc={rep.accuracy:.2f} cost=${rep.total_cost:.4f} "
          f"overhead={rep.overhead_tokens}tok")

    # ---- unseen pool: fingerprints only, no retraining ----
    unseen = [m.name for m in world.pool if not m.seen]
    for m in unseen:
        lib.onboard(world, m, seed=99)
    ood = build_scope_data(world, n_queries=120, models=unseen, seed=3,
                           difficulty_shift=0.9)
    router2 = ScopeRouter(est, retr, lib, world.models,
                          {m: i for i, m in enumerate(unseen)})
    service2 = RouterService(router2, ood, unseen)
    rep2 = service2.serve(ood.test_qids[:16], alpha=0.7)
    print(f"[unseen pool] acc={rep2.accuracy:.2f} cost=${rep2.total_cost:.4f} "
          f"portfolio={ {k: round(v,2) for k,v in rep2.per_model_share.items() if v>0} }")


if __name__ == "__main__":
    main()
