"""Quickstart: build a world, fingerprint a model pool, train a tiny SCOPE
estimator with hindsight-distillation SFT, and route a few queries through
the ``repro.api`` surface.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.api import EngineConfig, FixedAlphaPolicy, ScopeEngine
from repro.configs.scope_estimator import TINY
from repro.core.estimator import ReasoningEstimator
from repro.core.fingerprint import FingerprintLibrary, build_anchor_set
from repro.core.retrieval import AnchorRetriever
from repro.data.datasets import build_scope_data, stratified_anchors
from repro.data.worldsim import World
from repro.models import model as M
from repro.training.sft import build_sft_dataset, train_sft


def main():
    # 1. the model pool world and the SCOPE-60K-style interaction corpus
    world = World(seed=0)
    data = build_scope_data(world, n_queries=400, seed=0)
    print(f"pool: {data.models}")

    # 2. SCOPE-250-style anchors + behavioral fingerprints (Eq. 1)
    anchors = build_anchor_set(world, stratified_anchors(world, n=150))
    library = FingerprintLibrary(anchors)
    for m in data.models:
        library.onboard(world, m)
    retriever = AnchorRetriever(anchors)

    # 3. Stage-1 training: SFT via hindsight distillation (§4.3)
    ds = build_sft_dataset(data, library, retriever, max_examples=2500)
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    params, losses = train_sft(params, TINY, ds, steps=200, batch_size=32)
    print(f"SFT loss {np.mean(losses[:10]):.2f} -> {np.mean(losses[-10:]):.2f}")

    # 4. assemble the engine and serve held-out queries at two trade-offs
    engine = ScopeEngine.build(EngineConfig(
        estimator=ReasoningEstimator(TINY, params), retriever=retriever,
        library=library,
        models_meta={m: world.models[m] for m in data.models}))
    qids = data.test_qids[:8]
    for alpha in (0.0, 1.0):
        rep = engine.serve(data, qids, FixedAlphaPolicy(alpha))
        picked = [d.model for d in rep.decisions[:4]]
        print(f"alpha={alpha:.1f}: acc={rep.accuracy:.2f} "
              f"cost=${rep.total_cost:.4f} picked={picked}")


if __name__ == "__main__":
    main()
