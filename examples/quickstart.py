"""Quickstart: build a world, fingerprint a model pool, train a tiny SCOPE
estimator with hindsight-distillation SFT, and route a few queries.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.scope_estimator import TINY
from repro.core.estimator import ReasoningEstimator
from repro.core.fingerprint import FingerprintLibrary, build_anchor_set
from repro.core.retrieval import AnchorRetriever
from repro.core.router import ScopeRouter
from repro.data.datasets import build_scope_data, stratified_anchors
from repro.data.worldsim import World
from repro.models import model as M
from repro.training.sft import build_sft_dataset, train_sft


def main():
    # 1. the model pool world and the SCOPE-60K-style interaction corpus
    world = World(seed=0)
    data = build_scope_data(world, n_queries=400, seed=0)
    print(f"pool: {data.models}")

    # 2. SCOPE-250-style anchors + behavioral fingerprints (Eq. 1)
    anchors = build_anchor_set(world, stratified_anchors(world, n=150))
    library = FingerprintLibrary(anchors)
    for m in data.models:
        library.onboard(world, m)
    retriever = AnchorRetriever(anchors)

    # 3. Stage-1 training: SFT via hindsight distillation (§4.3)
    ds = build_sft_dataset(data, library, retriever, max_examples=2500)
    params = M.init_params(jax.random.PRNGKey(0), TINY)
    params, losses = train_sft(params, TINY, ds, steps=200, batch_size=32)
    print(f"SFT loss {np.mean(losses[:10]):.2f} -> {np.mean(losses[-10:]):.2f}")

    # 4. route held-out queries at two trade-off settings (§5)
    est = ReasoningEstimator(TINY, params)
    router = ScopeRouter(est, retriever, library, world.models,
                         {m: i for i, m in enumerate(data.models)})
    qids = data.test_qids[:8]
    queries = [data.queries[int(q)] for q in qids]
    pool = router.predict_pool(queries, data.models)
    for alpha in (0.0, 1.0):
        choices = router.route(pool, alpha)
        accs = [data.record(int(q), data.models[c]).y
                for q, c in zip(qids, choices)]
        costs = [data.record(int(q), data.models[c]).cost
                 for q, c in zip(qids, choices)]
        print(f"alpha={alpha:.1f}: acc={np.mean(accs):.2f} "
              f"cost=${np.sum(costs):.4f} "
              f"picked={[data.models[c] for c in choices[:4]]}")


if __name__ == "__main__":
    main()
