"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
artifacts.  Run after `repro.launch.dryrun --all [--multi-pod]`.

  PYTHONPATH=src python experiments/make_report.py > experiments/tables.md
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ART = os.path.join(os.path.dirname(__file__), "artifacts")

PEAK, HBM, LINK = 197e12, 819e9, 50e9


def model_flops(arch, shape_name):
    from repro.configs import get_config, INPUT_SHAPES
    import numpy as np
    import jax
    from repro.launch import specs as S
    cfg = get_config(arch)
    params = S.abstract_params(cfg)
    n_total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    if cfg.has_moe():
        expert_params = (cfg.num_experts * 3 * cfg.d_model
                         * cfg.resolved_moe_d_ff * cfg.num_layers)
        active_expert = ((cfg.num_experts_per_tok + cfg.num_shared_experts)
                         * 3 * cfg.d_model * cfg.resolved_moe_d_ff
                         * cfg.num_layers)
        n_active = n_total - expert_params + active_expert
    else:
        n_active = n_total
    sh = INPUT_SHAPES[shape_name]
    if sh.mode == "train":
        return 6.0 * n_active * sh.seq_len * sh.global_batch
    if sh.mode == "prefill":
        return 2.0 * n_active * sh.seq_len * sh.global_batch
    return 2.0 * n_active * sh.global_batch


def fmt(v, p=3):
    if v == 0:
        return "0"
    if abs(v) >= 1e4 or abs(v) < 1e-3:
        return f"{v:.2e}"
    return f"{v:.{p}f}"


def render(path, title):
    results = json.load(open(path))
    print(f"\n### {title}\n")
    print("| arch | shape | status | compile s | temp GB/dev | compute s | "
          "memory s | collective s | bottleneck | MODEL/HLO flops |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in results:
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | skip: sub-quadratic "
                  f"required | | | | | | | |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | | |")
            continue
        t = r["roofline"]
        mf = model_flops(r["arch"], r["shape"])
        ratio = mf / max(r["hlo_flops_per_device"] * r["num_devices"], 1.0)
        temp = r["memory_analysis"].get("temp_size_in_bytes", 0) / 1e9
        print(f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']:.0f} "
              f"| {temp:.1f} | {fmt(t['compute_s'])} | {fmt(t['memory_s'])} "
              f"| {fmt(t['collective_s'])} "
              f"| {t['bottleneck'].replace('_s','')} | {ratio:.2f} |")


if __name__ == "__main__":
    render(os.path.join(ART, "dryrun_single_pod.json"),
           "Single pod 16x16 (256 chips) — optimized")
    render(os.path.join(ART, "dryrun_multi_pod.json"),
           "Multi-pod 2x16x16 (512 chips) — optimized")
    base = os.path.join(ART, "baseline_single_pod.json")
    if os.path.exists(base):
        render(base, "Single pod 16x16 — paper-faithful baseline "
                     "(pre-hillclimb)")
