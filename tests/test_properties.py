"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import alpha_search, rewards, utility
from repro.data import tokenizer as tok

finite = st.floats(allow_nan=False, allow_infinity=False)


# ---------------------------------------------------------------------------
# Cost normalization (Eq. 11)
# ---------------------------------------------------------------------------
@given(st.lists(st.floats(min_value=1e-6, max_value=100.0), min_size=2,
                max_size=12))
@settings(max_examples=200, deadline=None)
def test_cost_normalization_bounds_and_order(costs):
    c = np.asarray(costs)
    out = utility.normalize_cost(c)
    assert np.all(out >= 0.0) and np.all(out <= 1.0)
    # order-preserving (monotone transform)
    i, j = np.argmin(c), np.argmax(c)
    assert out[i] <= out[j] + 1e-12
    if c.max() > c.min() * (1 + 1e-6):
        assert abs(out[i]) < 1e-9 and abs(out[j] - 1.0) < 1e-9


# ---------------------------------------------------------------------------
# Utility (Eq. 12-13)
# ---------------------------------------------------------------------------
@given(st.floats(min_value=0, max_value=1), st.floats(min_value=0, max_value=1),
       st.floats(min_value=0, max_value=1))
@settings(max_examples=200, deadline=None)
def test_utility_bounded(p, c, alpha):
    u = utility.predicted_utility(np.array([p]), np.array([c]), alpha)
    assert 0.0 - 1e-9 <= u[0] <= 1.0 + 1e-9


@given(st.floats(min_value=0, max_value=1))
@settings(max_examples=100, deadline=None)
def test_gamma_dyn_range(alpha):
    g = utility.gamma_dyn(alpha, gamma_base=1.0, beta=2.0)
    assert 1.0 - 1e-9 <= g <= 3.0 + 1e-9
    # alpha -> 0 gives the harshest cost penalty
    assert utility.gamma_dyn(0.0) >= utility.gamma_dyn(1.0)


@given(st.floats(min_value=0.0, max_value=1.0),
       st.floats(min_value=0.0, max_value=1.0))
@settings(max_examples=100, deadline=None)
def test_utility_monotone_in_accuracy_at_fixed_cost(alpha, c):
    """Higher predicted accuracy never lowers utility."""
    lo = utility.predicted_utility(np.array([0.2]), np.array([c]), alpha)[0]
    hi = utility.predicted_utility(np.array([0.9]), np.array([c]), alpha)[0]
    assert hi >= lo - 1e-12


def test_w_cal_endpoints():
    assert abs(utility.w_cal(0.0) - 0.1) < 1e-12
    assert abs(utility.w_cal(1.0) - 0.2) < 1e-12


# ---------------------------------------------------------------------------
# Adaptive token reward (Eq. 9-10)
# ---------------------------------------------------------------------------
@given(st.floats(min_value=1, max_value=20000))
@settings(max_examples=200, deadline=None)
def test_token_reward_plateau(len_gt):
    tau = rewards.adaptive_tolerance(len_gt)
    assert tau == max(200.0, 0.5 * len_gt)
    # full reward inside tau/2
    assert rewards.token_reward(len_gt + tau / 2 * 0.99, len_gt) == 1.0
    # zero beyond tau
    assert rewards.token_reward(len_gt + tau * 1.01, len_gt) == 0.0
    # linear decay in between
    mid = rewards.token_reward(len_gt + 0.75 * tau, len_gt)
    assert 0.0 < mid < 1.0


@given(st.integers(min_value=0, max_value=1),
       st.integers(min_value=0, max_value=1),
       st.floats(min_value=1, max_value=16384),
       st.floats(min_value=1, max_value=16384))
@settings(max_examples=100, deadline=None)
def test_grpo_reward_gate_and_range(y_hat, y_gt, lh, lg):
    parsed = {"y_hat": y_hat, "len_hat": lh, "well_formed": True}
    r = rewards.grpo_reward(parsed, y_gt, lg)
    assert 0.0 <= r <= 2.0
    bad = dict(parsed, well_formed=False)
    assert rewards.grpo_reward(bad, y_gt, lg) == 0.0


# ---------------------------------------------------------------------------
# Tokenizer roundtrips
# ---------------------------------------------------------------------------
@given(st.floats(min_value=8, max_value=16384))
@settings(max_examples=200, deadline=None)
def test_len_bucket_roundtrip_within_tolerance(tokens):
    b = tok.len_bucket(tokens)
    back = tok.len_from_bucket(b)
    # geometric buckets: relative error bounded by bucket ratio
    ratio = (16384 / 8) ** (1 / tok.NUM_LEN_BUCKETS)
    assert back / tokens < ratio * 1.01 and tokens / back < ratio * 1.01


@given(st.integers(min_value=0, max_value=1),
       st.integers(min_value=0, max_value=tok.NUM_LEN_BUCKETS - 1),
       st.booleans())
@settings(max_examples=100, deadline=None)
def test_parse_prediction_roundtrip(y, lb, cot):
    seq = []
    if cot:
        seq += [tok.THINK, tok.cnt_token(3), tok.LEN_BASE + 5,
                tok.domain_token(2), tok.THINK_END]
    seq += [tok.YES if y else tok.NO, tok.LEN_BASE + lb, tok.EOS]
    parsed = tok.parse_prediction(seq)
    assert parsed["well_formed"]
    assert parsed["y_hat"] == y
    assert parsed["len_hat"] == tok.len_from_bucket(lb)


@given(st.lists(st.integers(min_value=0, max_value=tok.VOCAB_SIZE - 1),
                min_size=0, max_size=12))
@settings(max_examples=200, deadline=None)
def test_parse_prediction_never_crashes(seq):
    parsed = tok.parse_prediction(seq)
    assert isinstance(parsed["well_formed"], bool)


@given(st.floats(min_value=-1, max_value=1))
@settings(max_examples=100, deadline=None)
def test_sim_bucket_in_range(s):
    b = tok.sim_bucket(s)
    assert 0 <= b < tok.NUM_SIM_BUCKETS


# ---------------------------------------------------------------------------
# Budget-controlled alpha (Prop. D.1)
# ---------------------------------------------------------------------------
@st.composite
def _pool(draw):
    q = draw(st.integers(min_value=1, max_value=5))
    m = draw(st.integers(min_value=2, max_value=5))
    p = draw(st.lists(st.floats(min_value=0, max_value=1),
                      min_size=q * m, max_size=q * m))
    s = draw(st.lists(st.floats(min_value=0, max_value=1),
                      min_size=q * m, max_size=q * m))
    c = draw(st.lists(st.floats(min_value=0.001, max_value=2.0),
                      min_size=q * m, max_size=q * m))
    return (np.array(p).reshape(q, m), np.array(s).reshape(q, m),
            np.array(c).reshape(q, m))


@given(_pool(), st.floats(min_value=0.001, max_value=10.0))
@settings(max_examples=60, deadline=None)
def test_budget_alpha_feasible_and_optimal_vs_grid(pool, budget):
    p, s, c = pool
    a_star, choice, info = alpha_search.budget_alpha(p, s, c, budget)
    if info["feasible"]:
        assert info["expected_cost"] <= budget + 1e-9
        # no denser grid alpha beats it on the same affine objective
        for a in np.linspace(0, 1, 47):
            ch = alpha_search.route_for_alpha(p, s, a)
            cost = c[np.arange(len(ch)), ch].sum()
            perf = p[np.arange(len(ch)), ch].sum()
            if cost <= budget:
                assert perf <= info["expected_perf"] + 1e-9


@given(_pool())
@settings(max_examples=60, deadline=None)
def test_routing_constant_between_breakpoints(pool):
    """Prop D.1: decisions are piecewise-constant in alpha."""
    p, s, _ = pool
    bps = alpha_search.breakpoints(p, s)
    grid = np.concatenate([[0.0], bps, [1.0]])
    for lo, hi in zip(grid[:-1], grid[1:], strict=True):
        if hi - lo < 1e-9:
            continue
        a1 = lo + (hi - lo) * 0.25
        a2 = lo + (hi - lo) * 0.75
        c1 = alpha_search.route_for_alpha(p, s, a1)
        c2 = alpha_search.route_for_alpha(p, s, a2)
        assert np.array_equal(c1, c2)


# ---------------------------------------------------------------------------
# GRPO group advantages
# ---------------------------------------------------------------------------
@given(st.lists(st.floats(min_value=0, max_value=2), min_size=4, max_size=4),
       st.integers(min_value=1, max_value=4))
@settings(max_examples=100, deadline=None)
def test_group_advantages_zero_mean(rewards_, groups):
    r = np.tile(np.asarray(rewards_), (groups, 1))
    adv = (r - r.mean(1, keepdims=True)) / (r.std(1, keepdims=True) + 1e-6)
    assert np.all(np.abs(adv.mean(1)) < 1e-6)


# ---------------------------------------------------------------------------
# Microbatch scheduler: flush decomposition + deadline/occupancy flushing
# ---------------------------------------------------------------------------
def _scheduler_mod():
    from repro.serving import scheduler as sched_mod
    return sched_mod


@st.composite
def _traffic(draw):
    batch_sizes = tuple(draw(st.lists(st.integers(1, 16), min_size=1,
                                      max_size=4, unique=True)))
    n = draw(st.integers(min_value=0, max_value=40))
    lens = draw(st.lists(st.integers(min_value=1, max_value=24),
                         min_size=n, max_size=n))
    return batch_sizes, lens


@given(_traffic())
@settings(max_examples=150, deadline=None)
def test_flush_largest_fit_decomposition_invariants(traffic):
    """flush(): every emitted batch is a configured bucket, every submitted
    prompt is emitted exactly once, FIFO order holds per length class, and
    the token matrix matches the prompts."""
    sm = _scheduler_mod()
    batch_sizes, lens = traffic
    cfg = sm.BucketConfig(batch_sizes=batch_sizes)
    sched = sm.MicrobatchScheduler(cfg)
    prompts = {i: [7 + (i % 5)] * ln for i, ln in enumerate(lens)}
    for i, p in prompts.items():
        sched.submit(i, p)
    mbs = sched.flush()
    assert len(sched) == 0

    seen = []
    per_class = {}
    for mb in mbs:
        assert mb.bucket[0] in cfg.batch_sizes          # configured bucket
        assert mb.tokens.shape == mb.bucket
        assert mb.lengths.shape == (mb.bucket[0],)
        for row, tag in enumerate(mb.tags):
            p = prompts[tag]
            assert mb.bucket[1] == cfg.len_bucket(len(p))
            assert int(mb.lengths[row]) == len(p)
            assert list(mb.tokens[row, : len(p)]) == p
            per_class.setdefault(mb.bucket[1], []).append(tag)
        seen.extend(mb.tags)
    assert sorted(seen) == sorted(prompts)              # exactly once
    for tags in per_class.values():                     # deterministic FIFO
        assert tags == sorted(tags)
    assert sched.stats.emitted == len(prompts)


@st.composite
def _arrival_trace(draw):
    steps = draw(st.lists(
        st.tuples(st.floats(min_value=0.0, max_value=3.0),
                  st.integers(min_value=0, max_value=5)),
        min_size=1, max_size=20))
    max_age = draw(st.floats(min_value=0.25, max_value=2.0))
    return steps, max_age


@given(_arrival_trace())
@settings(max_examples=150, deadline=None)
def test_tick_deadline_bounds_queue_age(trace):
    """After every tick(), no queued prompt is older than max_queue_age,
    and the stream still emits every prompt exactly once in valid buckets."""
    sm = _scheduler_mod()
    steps, max_age = trace
    now = [0.0]
    cfg = sm.BucketConfig(batch_sizes=(2, 8))
    sched = sm.MicrobatchScheduler(cfg, max_queue_age=max_age,
                                   clock=lambda: now[0])
    emitted, i = [], 0
    for dt, k in steps:
        now[0] += dt
        for _ in range(k):
            sched.submit(i, [5] * 6)
            i += 1
        emitted.extend(sched.tick())
        assert sched.oldest_age() < max_age
    emitted.extend(sched.flush())
    tags = sorted(t for mb in emitted for t in mb.tags)
    assert tags == list(range(i))
    assert all(mb.bucket[0] in cfg.batch_sizes for mb in emitted)


@st.composite
def _refill_trace(draw):
    """Interleaved submits, single-slot pops, and bucket ticks — the
    operation mix of the segment-chunked refill serve path."""
    return draw(st.lists(st.one_of(
        st.tuples(st.just("submit"), st.integers(1, 24)),   # prompt length
        st.tuples(st.just("pop"), st.integers(4, 28)),      # slot width
        st.tuples(st.just("tick"), st.just(0))),
        min_size=1, max_size=40))


@given(_refill_trace())
@settings(max_examples=150, deadline=None)
def test_refill_pop_one_preserves_exactly_once_and_fifo(ops):
    """Segment-chunked + refilled streams keep the scheduler contract:
    interleaving ``pop_one`` (mid-batch slot refill) with ``tick``/
    ``flush`` bucket emission delivers every submitted prompt exactly
    once, never hands out a prompt wider than the open slot, and
    preserves FIFO order within each length class."""
    sm = _scheduler_mod()
    cfg = sm.BucketConfig(batch_sizes=(2, 8))
    sched = sm.MicrobatchScheduler(cfg, clock=lambda: 0.0)
    submitted, delivered, pops, i = {}, [], 0, 0
    for op, arg in ops:
        if op == "submit":
            sched.submit(i, [5] * arg)
            submitted[i] = arg
            i += 1
        elif op == "pop":
            item = sched.pop_one(arg)
            if item is not None:
                tag, prompt, ln = item
                assert ln == len(prompt) == submitted[tag] <= arg
                delivered.append(tag)
                pops += 1
        else:
            for mb in sched.tick():
                delivered.extend(mb.tags)
    for mb in sched.flush():
        delivered.extend(mb.tags)
    assert sorted(delivered) == list(range(i))          # exactly once
    per_class = {}
    for t in delivered:
        per_class.setdefault(submitted[t], []).append(t)
    for tags in per_class.values():                     # per-class FIFO
        assert tags == sorted(tags)
    assert sched.stats.emitted == i
    assert sched.stats.slots_refilled == pops


@given(st.integers(min_value=0, max_value=40),
       st.floats(min_value=0.05, max_value=1.0))
@settings(max_examples=150, deadline=None)
def test_tick_min_fill_caps_queue_occupancy(n, fill):
    """min_fill: after tick() a queue never holds >= min_fill * max_batch
    prompts, and emitted microbatches stay valid buckets in FIFO order."""
    sm = _scheduler_mod()
    cfg = sm.BucketConfig(batch_sizes=(4, 16))
    sched = sm.MicrobatchScheduler(cfg, min_fill=fill, clock=lambda: 0.0)
    for i in range(n):
        sched.submit(i, [3] * 5)
    mbs = sched.tick()
    assert len(sched) < max(fill * cfg.max_batch, 1)
    tags = [t for mb in mbs for t in mb.tags]
    assert tags == sorted(tags) == list(range(len(tags)))
    assert all(mb.bucket[0] in cfg.batch_sizes for mb in mbs)
    mbs += sched.flush()
    assert sorted(t for mb in mbs for t in mb.tags) == list(range(n))


# ---------------------------------------------------------------------------
# Paged KV allocator (serving.kv_pool)
# ---------------------------------------------------------------------------
def _pool_trace():
    """Op traces over a small PagedKV batch: admissions with arbitrary
    prompt lengths, decode advances, retirements."""
    return st.lists(st.one_of(
        st.tuples(st.just("admit"), st.integers(0, 3), st.integers(1, 24)),
        st.tuples(st.just("ensure"), st.integers(1, 6), st.just(0)),
        st.tuples(st.just("retire"), st.integers(0, 3), st.just(0))),
        min_size=1, max_size=50)


@given(_pool_trace())
@settings(max_examples=200, deadline=None)
def test_kv_pool_alloc_release_invariants(ops):
    """Any interleaving of admit / decode-advance / retire keeps the pool
    consistent: no page is handed out twice, every live row's page table
    covers exactly [0, row_high) with distinct non-trash pages, retired
    rows point wholly at trash, and a fully-retired pool is whole again."""
    from repro.serving.kv_pool import KVPool
    pool = KVPool(n_pages=24, page_size=4)
    pg = pool.attach(4, kv_cap=32, budget_steps=8)
    live = set()
    for op, row, arg in ops:
        if op == "admit":
            if pg.row_live[row] or not pg.can_admit(arg):
                continue
            pg.admit_row(row, arg)
            live.add(row)
        elif op == "ensure":
            # mirror decode_segment's host guard before advancing
            if live and int(pg.row_high[list(live)].max()) + row > pg.kv_cap:
                continue
            try:
                pg.ensure(row)
            except RuntimeError:
                # a row past its own budget found the unreserved pool dry
                # (legal, loud); the pool must stay consistent regardless
                pass
        else:
            pg.retire_row(row)
            live.discard(row)
        # -- invariants after every op --
        owned = [pid for r in range(4) for pid in pg.row_pages[r]]
        assert len(owned) == len(set(owned)), "page double-allocated"
        assert not (set(owned) & set(pool._free)), "owned page also free"
        assert pool.trash_page not in owned
        assert len(owned) + len(pool._free) == pool.n_pages, "page leaked"
        assert pool.reserved >= 0 and pool.available() >= 0
        for r in range(4):
            n_covered = -(-int(pg.row_high[r]) // pg.page_size)
            if pg.row_live[r]:
                # table[:n_covered] are that row's distinct real pages...
                ids = pg.table[r, :n_covered].tolist()
                assert sorted(ids) == sorted(pg.row_pages[r][:n_covered])
                assert pool.trash_page not in ids
                # ...and nothing past the covered prefix is a real page
                assert (pg.table[r, n_covered:] == pool.trash_page).all()
            else:
                assert (pg.table[r] == pool.trash_page).all()
                assert not pg.row_pages[r]
    for r in range(4):
        pg.retire_row(r)
    assert pool.pages_in_use == 0 and pool.reserved == 0
    assert pool.available() == pool.n_pages
    assert sorted(pool._free) == list(range(pool.n_pages))


@given(st.integers(1, 64), st.integers(1, 16), st.integers(0, 200))
@settings(max_examples=200, deadline=None)
def test_kv_pool_free_rejects_double_and_foreign(n_pages, page_size, seed):
    """free() is exactly-once: double frees and out-of-range ids raise
    instead of corrupting the free list."""
    from repro.serving.kv_pool import KVPool
    pool = KVPool(n_pages=n_pages, page_size=page_size)
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, n_pages + 1))
    ids = pool.alloc(n)
    pool.free(ids[: n // 2])
    with pytest.raises(RuntimeError, match="free"):
        pool.free([ids[0]] if n // 2 else [pool.n_pages])
    with pytest.raises(RuntimeError, match="invalid"):
        pool.free([pool.n_pages])       # the trash page is never pool-owned
    pool.free(ids[n // 2:])
    assert pool.available() == pool.n_pages


# ---------------------------------------------------------------------------
# Fault tolerance: bounded retry/requeue + SLO cancels over the scheduler
# ---------------------------------------------------------------------------
@st.composite
def _fault_trace(draw):
    """Interleaved submits, bucket ticks with injected microbatch
    failures, and SLO cancels — the operation mix of the fault-tolerant
    serve path (engine._StreamControl over MicrobatchScheduler)."""
    ops = draw(st.lists(st.one_of(
        st.tuples(st.just("submit"), st.integers(1, 12)),   # prompt length
        st.tuples(st.just("tick"), st.integers(0, 3)),      # mbs to fail
        st.tuples(st.just("cancel"), st.integers(0, 60))),  # tag to expire
        min_size=1, max_size=40))
    max_retries = draw(st.integers(0, 2))
    return ops, max_retries


@given(_fault_trace())
@settings(max_examples=150, deadline=None)
def test_retry_requeue_exactly_once_and_fifo(trace):
    """Bounded retry: under any interleaving of submits, emissions,
    injected microbatch failures (rows requeued up to max_retries, then
    quarantined) and SLO cancels, every submitted prompt resolves exactly
    once — delivered, quarantined, or cancelled — rows that never failed
    keep per-class FIFO order, and the requeue ledger balances."""
    sm = _scheduler_mod()
    ops, max_retries = trace
    cfg = sm.BucketConfig(batch_sizes=(2, 4))
    sched = sm.MicrobatchScheduler(cfg, clock=lambda: 0.0)
    i, requeues = 0, 0
    cls, attempts = {}, {}
    delivered, quarantined, cancelled = [], [], []

    def fail_mb(mb):
        """engine._StreamControl.on_failed over one microbatch."""
        nonlocal requeues
        for r in range(mb.n_real):
            tag = mb.tags[r]
            n = attempts.get(tag, 0) + 1
            attempts[tag] = n
            if n <= max_retries:
                sched.requeue(tag, mb.tokens[r, : mb.lengths[r]].tolist())
                requeues += 1
            else:
                quarantined.append(tag)

    for op, arg in ops:
        if op == "submit":
            prompt = [7 + (i % 5)] * arg
            cls[i] = cfg.len_bucket(len(prompt))
            sched.submit(i, prompt)
            i += 1
        elif op == "tick":
            for k, mb in enumerate(sched.tick()):
                fail_mb(mb) if k < arg else delivered.extend(mb.tags)
        elif sched.cancel(arg) is not None:     # op == "cancel"
            cancelled.append(arg)
    while len(sched):               # shutdown drain (bounded: attempts
        for mb in sched.flush():    # cap at max_retries + 1 per tag)
            delivered.extend(mb.tags)

    assert sorted(delivered + quarantined + cancelled) == list(range(i))
    per_class = {}
    for t in delivered:
        if attempts.get(t, 0) == 0:             # never touched a failure
            per_class.setdefault(cls[t], []).append(t)
    for tags in per_class.values():             # per-class FIFO survives
        assert tags == sorted(tags)
    assert sched.stats.submitted == i           # exactly-once accounting:
    assert sched.stats.requeued == requeues     # retries never re-count
    # every emission is a delivery or a failure event, nothing else
    assert sched.stats.emitted == len(delivered) + sum(attempts.values())


def _chaos_pool_trace():
    """Op traces mixing admissions, segment growth with the runtime's
    fail-starved-rows recovery, and re-admission of failed rows."""
    return st.lists(st.one_of(
        st.tuples(st.just("admit"), st.integers(0, 3), st.integers(1, 24)),
        st.tuples(st.just("grow"), st.integers(1, 6), st.just(0)),
        st.tuples(st.just("recover"), st.integers(0, 3), st.integers(1, 24))),
        min_size=1, max_size=50)


@given(_chaos_pool_trace())
@settings(max_examples=200, deadline=None)
def test_kv_pool_starved_fail_recover_conserves_pages(ops):
    """starved_rows() is an exact dry run of ensure(): failing precisely
    the rows it names (the runtime's row-level KV-exhaustion path — pages
    released, row requeued) always lets the survivors' ensure() succeed,
    and any number of fail / re-admit cycles never leaks or double-books
    a page."""
    from repro.serving.kv_pool import KVPool
    pool = KVPool(n_pages=24, page_size=4)
    pg = pool.attach(4, kv_cap=32, budget_steps=8)
    failed = []
    for op, row, arg in ops:
        if op == "admit":
            if not pg.row_live[row] and pg.can_admit(arg):
                pg.admit_row(row, arg)
        elif op == "grow":
            steps = row
            if pg.row_live.any() and \
                    int(pg.row_high[pg.row_live].max()) + steps > pg.kv_cap:
                continue                # decode_segment's kv_cap guard
            for r in pg.starved_rows(steps):
                pg.retire_row(r)        # SlotRuntime._fail_row
                failed.append(r)
            pg.ensure(steps)            # survivors must never raise
        else:                           # "recover": retried row re-admits
            if failed and not pg.row_live[failed[0]] \
                    and pg.can_admit(arg):
                pg.admit_row(failed.pop(0), arg)
        owned = [pid for r in range(4) for pid in pg.row_pages[r]]
        assert len(owned) == len(set(owned)), "page double-allocated"
        assert not (set(owned) & set(pool._free)), "owned page also free"
        assert len(owned) + len(pool._free) == pool.n_pages, "page leaked"
        assert pool.reserved >= 0 and pool.available() >= 0
    for r in range(4):
        pg.retire_row(r)
    assert pool.pages_in_use == 0 and pool.reserved == 0
    assert sorted(pool._free) == list(range(pool.n_pages))


# ---------------------------------------------------------------------------
# Hot-swap at a serve boundary: stale tier-0 stashes are never served
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def swap_state(tiny_trained, world, retriever, library):
    """A real engine + one prepared request (host-only: prompts are
    serialized and submitted, never decoded)."""
    from repro.api import EngineConfig, RouteRequest, ScopeEngine
    from repro.core.estimator import ReasoningEstimator
    from repro.data.datasets import build_scope_data
    cfg, params, _ = tiny_trained
    data = build_scope_data(world, n_queries=40, seed=11)
    eng = ScopeEngine.build(EngineConfig(
        estimator=ReasoningEstimator(cfg, params, max_new_tokens=6),
        retriever=retriever, library=library,
        models_meta={m: world.models[m] for m in data.models}))
    queries = [data.queries[int(q)] for q in data.test_qids[:2]]
    return eng, eng._prepare(RouteRequest(queries), use_cache=False)


@st.composite
def _swap_trace(draw):
    """Interleaved degrades, mid-stream estimator hot-swaps, and
    post-swap re-stashes (what a fresh request's submit does)."""
    return draw(st.lists(st.one_of(
        st.tuples(st.just("degrade"), st.integers(0, 31)),
        st.tuples(st.just("swap"), st.just(0)),
        st.tuples(st.just("restash"), st.just(0))),
        min_size=1, max_size=24))


@given(_swap_trace())
@settings(max_examples=100, deadline=None)
def test_hot_swap_boundary_stash_versioning_property(swap_state, ops):
    """Under any interleaving of degrades, hot-swaps, and re-stashes:
    a degraded pair takes the tier-0 fallback rung iff its stash was
    minted under the *current* estimator version (a swap stales every
    earlier stash at once), every pair resolves at most once, and the
    degrade ledger balances."""
    from repro.api.engine import _StreamControl, _StreamEntry
    from repro.serving.scheduler import BucketConfig, MicrobatchScheduler
    eng, pstate = swap_state
    row = (0.8, 12.0, 1)
    try:
        entry = _StreamEntry(pstate)
        sched = MicrobatchScheduler(BucketConfig(batch_sizes=(2, 4)))
        inflight = {}
        control = _StreamControl(eng, sched, inflight, use_cache=False)
        eng._submit_misses(pstate, entry, sched, inflight, False, 0, control)
        keys = list(control.unresolved)
        n = len(keys)
        for k in keys:                  # what _submit_misses does with a
            control.t0_rows[k] = ("v0", row)    # tier-0 head configured
        fresh = dict.fromkeys(keys, True)       # stash minted at current ver?
        degraded, expect_fb, swaps = set(), 0, 0
        for op, arg in ops:
            if op == "swap":
                swaps += 1
                eng.hot_swap(eng.estimator, f"v0+s{swaps}")
                fresh = dict.fromkeys(keys, False)
            elif op == "restash":
                for k in keys:
                    if k not in degraded:
                        control.t0_rows[k] = (eng.config.estimator_version,
                                              row)
                        fresh[k] = True
            else:
                k = keys[arg % n]
                if k not in degraded and fresh[k]:
                    expect_fb += 1
                degraded.add(k)
                control.degrade(k)      # second degrade of k is a no-op
        stats = sched.stats
        assert stats.tier0_fallbacks == expect_fb
        assert stats.degraded == len(degraded)
        assert stats.failed_pairs == 0
        assert entry.remaining == n - len(degraded)     # exactly-once fills
        assert set(control.unresolved) == set(keys) - degraded
    finally:
        eng.config.estimator_version = "v0"
