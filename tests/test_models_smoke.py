"""Per-assigned-architecture smoke tests: a REDUCED variant of the same
family (<=2 layers, d_model<=512, <=4 experts) runs one forward + one train
step on CPU; output shapes asserted, no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import model as M
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def _batch(cfg, key, b=2, s=32):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["enc_features"] = jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model))
    if cfg.num_stub_patches:
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.num_stub_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    b, s = 2, 32
    batch = _batch(cfg, key, b, s)

    logits, aux = M.forward_train(params, cfg, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))

    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    ostate = adamw_init(params)

    @jax.jit
    def step(p, st, bt):
        (loss, metrics), g = jax.value_and_grad(
            lambda pp: M.loss_fn(pp, cfg, bt), has_aux=True)(p)
        p2, st2 = adamw_update(ocfg, g, st, p)
        return p2, st2, loss

    p2, _, loss = step(params, ostate, batch)
    assert bool(jnp.isfinite(loss))
    # params changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", ["gemma2-2b", "zamba2-7b", "mamba2-1.3b"])
def test_long_context_variant_lowers_smoke(arch):
    """The long-context (windowed) variant of sub-quadratic archs runs."""
    from repro.configs.base import long_context_variant
    cfg = long_context_variant(get_config(arch).reduced())
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, _ = M.forward_train(params, cfg, batch)
    assert bool(jnp.isfinite(logits).all())


def test_build_plan_structures():
    from repro.models.transformer import build_plan
    assert build_plan(get_config("gemma2-9b")) == [(("attn_local", "attn"), 21)]
    plan = build_plan(get_config("zamba2-7b"))
    assert plan[0][0] == ("mamba",) * 6 + ("shared_attn",)
    assert plan[0][1] == 13 and plan[1] == (("mamba",), 3)
    ds = build_plan(get_config("deepseek-v2-lite-16b"))
    assert ds == [(("mla",), 1), (("mla_moe",), 26)]
    assert build_plan(get_config("qwen3-moe-235b-a22b")) == [(("moe",), 94)]
    assert build_plan(get_config("mamba2-1.3b")) == [(("mamba",), 48)]
