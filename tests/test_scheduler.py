"""Streaming serve subsystem: bucketed microbatch scheduler, stream-vs-batch
predict parity, fixed-executable reuse, sharded multi-device serving, and
the ``_pad_caches`` seq-axis contract."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import EngineConfig, FixedAlphaPolicy, RouteRequest, ScopeEngine
from repro.core.estimator import Prediction
from repro.data.datasets import build_scope_data
from repro.serving.sampler import _pad_caches
from repro.serving.scheduler import (
    BucketConfig, MicrobatchScheduler, decode_compile_counts)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# BucketConfig / MicrobatchScheduler unit behavior
# ---------------------------------------------------------------------------
def test_bucket_assignment_boundaries():
    cfg = BucketConfig(batch_sizes=(1, 2, 4, 8), prompt_lens=(16, 64))
    assert cfg.batch_bucket(1) == 1
    assert cfg.batch_bucket(2) == 2
    assert cfg.batch_bucket(3) == 4          # rounds up, never down
    assert cfg.batch_bucket(8) == 8
    with pytest.raises(ValueError):
        cfg.batch_bucket(9)
    assert cfg.len_bucket(10) == 16
    assert cfg.len_bucket(16) == 16          # boundary is inclusive
    assert cfg.len_bucket(17) == 64
    assert cfg.len_bucket(100) == 100        # grid overflow -> exact fit
    # exact-fit default: every length is its own bucket
    assert BucketConfig().len_bucket(37) == 37
    with pytest.raises(ValueError):
        BucketConfig(batch_sizes=())
    with pytest.raises(ValueError):
        BucketConfig(batch_sizes=(0, 4))


def test_scheduler_assembles_and_flushes_greedily():
    sched = MicrobatchScheduler(BucketConfig(batch_sizes=(1, 2, 4, 8)))
    for i in range(11):
        sched.submit(i, [5] * 10)
    ready = sched.ready()                    # one full 8-batch
    assert [mb.bucket for mb in ready] == [(8, 10)]
    assert ready[0].tags == list(range(8))
    rest = sched.flush()                     # 3 left -> greedy [2, 1]
    assert [mb.bucket for mb in rest] == [(2, 10), (1, 10)]
    assert len(sched) == 0
    st = sched.stats
    assert st.submitted == st.emitted == 11
    assert st.pad_rows == 0 and st.pad_fraction == 0.0
    assert st.occupancy == {(8, 10): 1, (2, 10): 1, (1, 10): 1}


def test_scheduler_pads_rows_and_lengths():
    from repro.data.tokenizer import PAD
    sched = MicrobatchScheduler(BucketConfig(batch_sizes=(4,),
                                             prompt_lens=(12,)))
    sched.submit("a", [7] * 9)
    sched.submit("b", [8] * 12)
    [mb] = sched.flush()
    assert mb.bucket == (4, 12) and mb.n_real == 2
    assert mb.tokens.shape == (4, 12)
    assert list(mb.tokens[0, :9]) == [7] * 9
    assert list(mb.tokens[0, 9:]) == [PAD] * 3       # length padding
    assert list(mb.tokens[2]) == [PAD] * 12          # row padding
    assert sched.stats.pad_rows == 2
    assert sched.stats.pad_tokens == 4 * 12 - 21
    with pytest.raises(ValueError):
        sched.submit("c", [])


def test_padded_rows_do_not_change_real_rows(tiny_trained):
    """Batch-axis padding parity: the decode scan is row-independent, so a
    bucket-padded batch reproduces the unpadded rows bit-for-bit."""
    from repro.data.tokenizer import PAD
    from repro.serving.sampler import generate
    cfg, params, _ = tiny_trained
    rng = np.random.default_rng(0)
    prompts = rng.integers(3, 100, size=(3, 20)).astype(np.int32)
    padded = np.full((8, 20), PAD, np.int32)
    padded[:3] = prompts
    g_ref, d_ref = generate(params, cfg, prompts, max_new_tokens=5)
    g_pad, d_pad = generate(params, cfg, padded, max_new_tokens=5)
    np.testing.assert_array_equal(g_pad[:3], g_ref)
    np.testing.assert_array_equal(d_pad[:3], d_ref)


def test_fixed_executable_reuse_across_batch_sizes(tiny_trained):
    """Within a bucket, varying per-step batch sizes must not compile new
    prefill/scan executables once the bucket shape is warm."""
    from repro.data.tokenizer import PAD
    from repro.serving.sampler import generate
    cfg, params, _ = tiny_trained
    rng = np.random.default_rng(1)
    sched = MicrobatchScheduler(BucketConfig(batch_sizes=(4,)))
    for step, n_real in enumerate((1, 3, 2, 4)):     # ragged steps, one bucket
        for r in range(n_real):
            sched.submit(f"{step}.{r}", rng.integers(3, 100, size=24).tolist())
        for mb in sched.flush():
            assert mb.tokens.shape == (4, 24)
            generate(params, cfg, mb.tokens, max_new_tokens=4)
        if step == 0:                        # first step compiled the bucket
            warm = decode_compile_counts()
    after = decode_compile_counts()
    assert after == warm, f"bucketed shapes recompiled: {warm} -> {after}"
    assert -1 not in warm.values()           # the counter API is available
    # a genuinely new shape DOES compile (sanity check of the counter)
    generate(params, cfg,
             np.full((3, 24), PAD, np.int32), max_new_tokens=4)
    assert decode_compile_counts() != after


# ---------------------------------------------------------------------------
# Stream vs batch predict through the engine
# ---------------------------------------------------------------------------
class CountingEstimator:
    """Deterministic stand-in: prediction is a pure function of the prompt."""

    def __init__(self):
        self.pairs = 0

    def predict(self, prompts, rng=None, **kw):
        self.pairs += len(prompts)
        out = []
        for p in prompts:
            h = sum(p) % 97
            out.append(Prediction(
                y_hat=h % 2, len_hat=64.0 + h, well_formed=True,
                p_conf=0.25 + 0.5 * (h / 97.0), pred_tokens=6,
                rationale_len=4))
        return out


@pytest.fixture()
def stream_setup(world, retriever, library):
    data = build_scope_data(world, n_queries=400, seed=5)

    def mk():
        return ScopeEngine.build(EngineConfig(
            estimator=CountingEstimator(), retriever=retriever,
            library=library,
            models_meta={m: world.models[m] for m in data.models}))
    return mk, data


def test_stream_matches_batch_predict_and_cache_stats(stream_setup):
    mk, data = stream_setup
    queries = [data.queries[int(q)] for q in data.test_qids[:17]]
    e_batch, e_stream = mk(), mk()
    pool = e_batch.predict(RouteRequest(queries))

    sched = MicrobatchScheduler(BucketConfig(batch_sizes=(1, 2, 4, 8)))
    ticks = [queries[0:4], queries[4:5], queries[5:12], queries[12:17]]
    pools = list(e_stream.predict_stream((RouteRequest(t) for t in ticks),
                                         scheduler=sched))
    assert len(pools) == len(ticks)
    for field in ("p_hat", "y_hat", "len_hat", "cost_hat", "well_formed",
                  "pred_overhead", "sims", "idx"):
        np.testing.assert_array_equal(
            np.concatenate([getattr(p, field) for p in pools]),
            getattr(pool, field), err_msg=field)
    M = len(data.models)
    assert [p.cache_misses for p in pools] == [4 * M, M, 7 * M, 5 * M]
    assert sum(p.cache_hits for p in pools) == 0
    assert sched.stats.emitted == 17 * M
    assert e_stream.config.estimator.pairs >= e_batch.config.estimator.pairs

    # warm re-stream: all hits, no estimator work, same values
    before = e_stream.config.estimator.pairs
    pools2 = list(e_stream.predict_stream(RouteRequest(t) for t in ticks))
    assert e_stream.config.estimator.pairs == before
    assert [p.cache_hits for p in pools2] == [4 * M, M, 7 * M, 5 * M]
    np.testing.assert_array_equal(
        np.concatenate([p.p_hat for p in pools2]), pool.p_hat)


def test_stream_small_ticks_ride_along_and_empty_ticks(stream_setup):
    mk, data = stream_setup
    queries = [data.queries[int(q)] for q in data.test_qids[:6]]
    engine = mk()
    sched = MicrobatchScheduler(BucketConfig(batch_sizes=(8,)))
    ticks = [queries[:1], [], queries[1:6]]
    pools = list(engine.predict_stream((RouteRequest(t) for t in ticks),
                                       scheduler=sched))
    assert [p.p_hat.shape[0] for p in pools] == [1, 0, 5]
    # the 1-query tick couldn't fill a bucket alone: it was held and shipped
    # together with the later traffic (cross-request microbatching)
    assert sched.stats.microbatches > 0
    ref = mk().predict(RouteRequest(queries))
    np.testing.assert_array_equal(
        np.concatenate([p.p_hat for p in pools]), ref.p_hat)


def test_stream_dedupes_inflight_duplicate_queries(stream_setup):
    """A hot query repeated across ticks while still in flight shares the
    first tick's generation instead of scheduling a duplicate prompt."""
    mk, data = stream_setup
    q = data.queries[int(data.test_qids[0])]
    engine = mk()
    # bucket larger than one tick's prompts: tick 1 is still queued when
    # tick 2 repeats the same query
    sched = MicrobatchScheduler(BucketConfig(batch_sizes=(8,)))
    pools = list(engine.predict_stream(
        (RouteRequest(t) for t in ([q], [q])), scheduler=sched))
    M = len(data.models)
    assert sched.stats.submitted == M            # duplicates not scheduled
    assert engine.config.estimator.pairs == 8    # one padded microbatch
    np.testing.assert_array_equal(pools[0].p_hat, pools[1].p_hat)
    assert pools[0].pred_overhead.sum() > 0
    assert pools[1].pred_overhead.sum() == 0     # shared: no new tokens
    ref = mk().predict(RouteRequest([q]))
    np.testing.assert_array_equal(pools[1].p_hat, ref.p_hat)
    # the cache keeps the primary's true token spend, not the rider's 0
    from repro.api.cache import query_key
    cached = engine.cache.get(query_key(q), data.models[0],
                              engine.config.estimator_version)
    assert cached is not None and cached.pred_tokens > 0
    # uncached streams never share work
    e2 = mk()
    sched2 = MicrobatchScheduler(BucketConfig(batch_sizes=(8,)))
    list(e2.predict_stream((RouteRequest(t) for t in ([q], [q])),
                           scheduler=sched2, use_cache=False))
    assert sched2.stats.submitted == 2 * M


def test_predict_empty_request_skips_model_validation(stream_setup):
    """Zero-query predict returns an empty pool even for a model that is
    not onboarded yet (validation applies to non-empty requests only)."""
    mk, data = stream_setup
    engine = mk()
    pool = engine.predict(RouteRequest([], models=["not-onboarded"]))
    assert pool.p_hat.shape == (0, 1)
    q = data.queries[int(data.test_qids[0])]
    with pytest.raises(KeyError):
        engine.predict(RouteRequest([q], models=["not-onboarded"]))


def test_serve_stream_matches_serve(stream_setup):
    mk, data = stream_setup
    qids = [int(q) for q in data.test_qids[:12]]
    policy = FixedAlphaPolicy(0.6)
    rep = mk().serve(data, qids, policy)
    reports = list(mk().serve_stream(data, [qids[:7], qids[7:]], policy))
    assert len(reports) == 2
    assert all(r.executed for r in reports)
    assert sum(r.n_queries for r in reports) == len(qids)
    got = [d.model for r in reports for d in r.decisions]
    want = [d.model for d in rep.decisions]
    assert got == want
    total = sum(r.total_cost for r in reports)
    assert total == pytest.approx(rep.total_cost)


# ---------------------------------------------------------------------------
# Sharded multi-device serving (subprocess: isolated device-count flag)
# ---------------------------------------------------------------------------
SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, json
import numpy as np
from repro.api import EngineConfig, RouteRequest, ScopeEngine
from repro.configs.scope_estimator import TINY
from repro.core.estimator import ReasoningEstimator
from repro.core.fingerprint import FingerprintLibrary, build_anchor_set
from repro.core.retrieval import AnchorRetriever
from repro.data.datasets import build_scope_data, stratified_anchors
from repro.data.worldsim import World
from repro.launch.mesh import make_serve_mesh
from repro.models import model as M
from repro.serving.scheduler import BucketConfig, MicrobatchScheduler

world = World(seed=0)
data = build_scope_data(world, n_queries=120, seed=0)
aset = build_anchor_set(world, stratified_anchors(world, n=40, seed=7))
lib = FingerprintLibrary(aset)
for m in data.models:
    lib.onboard(world, m, seed=3)
params = M.init_params(jax.random.PRNGKey(0), TINY)

def mk():
    return ScopeEngine.build(EngineConfig(
        estimator=ReasoningEstimator(TINY, params),
        retriever=AnchorRetriever(aset), library=lib,
        models_meta={m: world.models[m] for m in data.models}))

queries = [data.queries[int(q)] for q in data.test_qids[:4]]
ref = mk().predict(RouteRequest(queries))

mesh = make_serve_mesh()
engine = mk()
engine.estimator.shard(mesh)
sched = MicrobatchScheduler(BucketConfig(batch_sizes=(4, 8)))
ticks = [queries[:1], queries[1:4]]
pools = list(engine.predict_stream((RouteRequest(t) for t in ticks),
                                   scheduler=sched))
p_hat = np.concatenate([p.p_hat for p in pools])
cost = np.concatenate([p.cost_hat for p in pools])
print(json.dumps({
    "devices": jax.local_device_count(),
    "mesh_data": int(mesh.devices.shape[0]),
    "identical": bool(np.array_equal(p_hat, ref.p_hat)
                      and np.array_equal(cost, ref.cost_hat)),
    "hits_misses": [[p.cache_hits, p.cache_misses] for p in pools],
    "n_models": len(data.models),
    "microbatches": sched.stats.microbatches,
}))
"""


def test_stream_predict_sharded_multi_device_matches_single():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["devices"] == 4 and res["mesh_data"] == 4
    assert res["identical"], "sharded stream diverged from 1-device predict"
    M_ = res["n_models"]
    assert res["hits_misses"] == [[0, 1 * M_], [0, 3 * M_]]
    assert res["microbatches"] > 0


# ---------------------------------------------------------------------------
# _pad_caches: explicit seq-axis contract (regression for axis sniffing)
# ---------------------------------------------------------------------------
def test_pad_caches_adversarial_shapes():
    """Shapes engineered so prompt_len coincides with head count, conv
    width, SSM state dim, and the encoder cross-cache seq — the old
    axis-sniffing implementation pads the wrong axis on every one."""
    lp, new, L, b = 4, 6, 2, 3                  # prompt_len == 4 everywhere
    caches = ({
        "0": {
            # kv_heads == prompt_len: seq is axis 3, NOT the head axis
            "k": jnp.zeros((L, b, lp, lp, 8)),
            "v": jnp.zeros((L, b, lp, lp, 8)),
            # conv width-1 == prompt_len: mamba state, never grown
            "conv": jnp.zeros((L, b, lp, 16)),
            # ssm state dim == prompt_len: never grown
            "ssm": jnp.zeros((L, b, 2, 8, lp)),
            # encoder cross cache with enc_seq == prompt_len: never grown
            "ck": jnp.zeros((L, b, 2, lp, 8)),
            "cv": jnp.zeros((L, b, 2, lp, 8)),
        },
        "1": {
            # MLA latent caches: seq is axis 2
            "c_kv": jnp.zeros((L, b, lp, 16)),
            "k_rope": jnp.zeros((L, b, lp, lp)),
        },
    },)
    out = _pad_caches(caches, lp + new, lp)
    leaf = out[0]["0"]
    assert leaf["k"].shape == (L, b, lp, lp + new, 8)
    assert leaf["v"].shape == (L, b, lp, lp + new, 8)
    assert leaf["conv"].shape == (L, b, lp, 16)
    assert leaf["ssm"].shape == (L, b, 2, 8, lp)
    assert leaf["ck"].shape == (L, b, 2, lp, 8)
    assert leaf["cv"].shape == (L, b, 2, lp, 8)
    mla = out[0]["1"]
    assert mla["c_kv"].shape == (L, b, lp + new, 16)
    assert mla["k_rope"].shape == (L, b, lp + new, lp)


def test_pad_caches_rejects_seq_mismatch():
    caches = ({"0": {"k": jnp.zeros((1, 1, 2, 9, 4))}},)
    with pytest.raises(ValueError, match="seq axis"):
        _pad_caches(caches, 16, prompt_len=8)


def test_generate_with_prompt_len_equal_to_head_count(tiny_trained):
    """End-to-end: a prompt whose length equals the KV head count decodes
    correctly (the sniffing version grew the head axis instead)."""
    from repro.serving.sampler import generate
    cfg, params, _ = tiny_trained
    lp = cfg.num_kv_heads
    rng = np.random.default_rng(2)
    prompts = rng.integers(3, 100, size=(2, lp)).astype(np.int32)
    gen, dec = generate(params, cfg, prompts, max_new_tokens=4)
    assert gen.shape == (2, 4) and dec.shape == (2, 4, 2)
