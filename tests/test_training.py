"""Training substrate: optimizer, SFT convergence, GRPO step, checkpoints."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.scope_estimator import TINY
from repro.models import model as M
from repro.training import checkpoint
from repro.training.optimizer import (
    AdamWConfig, adamw_init, adamw_update, global_norm, lr_at)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, schedule="constant")
    state = adamw_init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda pp: jnp.sum(pp["w"] ** 2))(p)
        return adamw_update(cfg, g, s, p)

    for _ in range(200):
        params, state = step(params, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_lr_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 10)) <= 1e-3 * (1 + 1e-5)   # f32 rounding
    assert float(lr_at(cfg, 100)) < float(lr_at(cfg, 50))


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros(4)}
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0,
                      schedule="constant", weight_decay=0.0)
    state = adamw_init(params)
    huge = {"w": jnp.full(4, 1e6)}
    clipped = jax.tree.map(
        lambda g: g * jnp.minimum(1.0, cfg.grad_clip / global_norm(huge)),
        huge)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    p2, _ = adamw_update(cfg, huge, state, params)
    assert bool(jnp.isfinite(p2["w"]).all())


def test_sft_loss_decreases(tiny_trained):
    _, _, losses = tiny_trained
    assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:10])


def test_grpo_improves_or_holds_reward(scope_data, library, retriever,
                                       tiny_trained):
    from repro.training.grpo import GRPOConfig, GRPOTrainer
    cfg, params, _ = tiny_trained
    tr = GRPOTrainer(cfg, params, scope_data, library, retriever,
                     gcfg=GRPOConfig(group_size=4, tasks_per_step=8),
                     seed=1)
    hist = tr.train(8)
    assert len(hist) == 8
    assert all(np.isfinite(hist))
    assert all(0.0 <= r <= 2.0 for r in hist)


def test_checkpoint_roundtrip(tmp_path):
    cfg = TINY
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    path = os.path.join(tmp_path, "ckpt")
    checkpoint.save(path, params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    restored = checkpoint.load(path, zeros)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grpo_format_rate_reports_gate_pass_rate(scope_data, library,
                                                retriever, tiny_trained):
    """format_rate must be the parse-gate pass rate, not mean(rewards > 0):
    a well-formed rollout with zero composite reward (wrong label, far-off
    length) passes the gate but earns nothing."""
    import pytest
    from repro.core import rewards as rw
    from repro.data import tokenizer as tok
    from repro.serving import sampler
    from repro.training.grpo import GRPOConfig, GRPOTrainer

    cfg, params, _ = tiny_trained
    gcfg = GRPOConfig(group_size=2, tasks_per_step=6, temperature=1.0)
    t1 = GRPOTrainer(cfg, params, scope_data, library, retriever,
                     gcfg=gcfg, seed=3)
    t2 = GRPOTrainer(cfg, params, scope_data, library, retriever,
                     gcfg=gcfg, seed=3)
    # _build_prompts draws embedding noise from the world's shared rng;
    # rewind it so the twin replay sees the identical stream
    world_rng_state = scope_data.world.rng.bit_generator.state
    info = t1.rollout_step()
    scope_data.world.rng.bit_generator.state = world_rng_state

    # replay the identical rollout with the twin trainer's rng stream
    tasks = t2._sample_tasks(gcfg.tasks_per_step)
    prompts, gts = t2._build_prompts(tasks)
    tiled = np.repeat(np.asarray(prompts, np.int32), gcfg.group_size, axis=0)
    _, sub = jax.random.split(t2.key)
    gen, _ = sampler.generate(t2.params, cfg, tiled,
                              max_new_tokens=gcfg.max_new_tokens,
                              temperature=gcfg.temperature, rng=sub)
    parsed = [tok.parse_prediction([int(x) for x in g]) for g in gen]
    gate = float(np.mean([p.get("well_formed", False) for p in parsed]))
    rewards = np.asarray(
        [rw.grpo_reward(p, *gts[i // gcfg.group_size])
         for i, p in enumerate(parsed)])
    assert info["format_rate"] == pytest.approx(gate)
    assert info["reward"] == pytest.approx(float(rewards.mean()), abs=1e-6)
