"""Training substrate: optimizer, SFT convergence, GRPO step, checkpoints."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.scope_estimator import TINY
from repro.models import model as M
from repro.training import checkpoint
from repro.training.optimizer import (
    AdamWConfig, adamw_init, adamw_update, global_norm, lr_at)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, schedule="constant")
    state = adamw_init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda pp: jnp.sum(pp["w"] ** 2))(p)
        return adamw_update(cfg, g, s, p)

    for _ in range(200):
        params, state = step(params, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_lr_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 10)) <= 1e-3 * (1 + 1e-5)   # f32 rounding
    assert float(lr_at(cfg, 100)) < float(lr_at(cfg, 50))


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros(4)}
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0,
                      schedule="constant", weight_decay=0.0)
    state = adamw_init(params)
    huge = {"w": jnp.full(4, 1e6)}
    clipped = jax.tree.map(
        lambda g: g * jnp.minimum(1.0, cfg.grad_clip / global_norm(huge)),
        huge)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    p2, _ = adamw_update(cfg, huge, state, params)
    assert bool(jnp.isfinite(p2["w"]).all())


def test_sft_loss_decreases(tiny_trained):
    _, _, losses = tiny_trained
    assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:10])


def test_grpo_improves_or_holds_reward(scope_data, library, retriever,
                                       tiny_trained):
    from repro.training.grpo import GRPOConfig, GRPOTrainer
    cfg, params, _ = tiny_trained
    tr = GRPOTrainer(cfg, params, scope_data, library, retriever,
                     gcfg=GRPOConfig(group_size=4, tasks_per_step=8),
                     seed=1)
    hist = tr.train(8)
    assert len(hist) == 8
    assert all(np.isfinite(hist))
    assert all(0.0 <= r <= 2.0 for r in hist)


def test_checkpoint_roundtrip(tmp_path):
    cfg = TINY
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    path = os.path.join(tmp_path, "ckpt")
    checkpoint.save(path, params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    restored = checkpoint.load(path, zeros)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
