"""Decode-with-cache must reproduce the full forward, per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M

FAMILIES = ["internlm2-1.8b", "starcoder2-3b", "gemma2-2b", "qwen2-vl-7b",
            "mamba2-1.3b", "zamba2-7b", "deepseek-v2-lite-16b",
            "qwen3-moe-235b-a22b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.has_moe():
        # capacity drops are routing-order dependent; remove them for parity
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    b, s, S = 2, 8, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                              cfg.vocab_size)
    full_logits, _ = M.forward_train(params, cfg, {"tokens": toks})
    caches = M.init_cache(cfg, b, S)
    outs = []
    for t in range(s):
        lg, caches = M.decode_step(params, cfg, toks[:, t:t + 1], caches, t)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=5e-5, rtol=1e-3)


def test_prefill_then_decode_continuation():
    """prefill(prompt) caches + decode steps == full forward on the whole
    sequence (the serving path the sampler uses)."""
    from repro.serving.sampler import _pad_caches
    cfg = get_config("internlm2-1.8b").reduced()
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    b, lp, extra = 2, 6, 4
    toks = jax.random.randint(jax.random.PRNGKey(4), (b, lp + extra), 0,
                              cfg.vocab_size)
    full_logits, _ = M.forward_train(params, cfg, {"tokens": toks})

    logits_p, caches = M.prefill(params, cfg, {"tokens": toks[:, :lp]})
    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(full_logits[:, :lp], np.float32),
                               atol=5e-5, rtol=1e-3)
    caches = _pad_caches(caches, lp + extra, lp)
    for t in range(extra):
        lg, caches = M.decode_step(params, cfg, toks[:, lp + t: lp + t + 1],
                                   caches, lp + t)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full_logits[:, lp + t], np.float32),
            atol=5e-5, rtol=1e-3)


def test_ring_buffer_window_decode_matches_full():
    """Ring-buffer KV cache (cache size == window) must equal full-cache
    windowed attention at every step."""
    import dataclasses
    cfg = get_config("gemma2-2b").reduced()
    cfg = dataclasses.replace(cfg, sliding_window=4, force_window=4)
    params = M.init_params(jax.random.PRNGKey(5), cfg)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(6), (b, s), 0,
                              cfg.vocab_size)
    full_logits, _ = M.forward_train(params, cfg, {"tokens": toks})

    caches = M.init_cache(cfg, b, s)      # windowed layers -> ring of 4
    # verify the ring allocation actually happened
    kv_lens = {leaf.shape[3]
               for path, leaf in jax.tree_util.tree_flatten_with_path(caches)[0]
               if getattr(path[-1], "key", "") in ("k", "v")}
    assert kv_lens == {4}
    outs = []
    for t in range(s):
        lg, caches = M.decode_step(params, cfg, toks[:, t:t + 1], caches, t)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=5e-5, rtol=1e-3)
