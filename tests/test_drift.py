"""Drift-aware self-healing serving: outcome ledger, Page–Hinkley
detection, model quarantine, replay-buffer fingerprint refresh, live
estimator hot-swap, and the DriftAwarePolicy wrapper — unit coverage of
serving.feedback plus the closed inject -> detect -> quarantine ->
refresh -> recover loop through the engine."""
import numpy as np
import pytest

from repro.api import (
    DriftAwarePolicy, EngineConfig, FixedAlphaPolicy, RouteRequest,
    ScopeEngine)
from repro.api.cache import CachedPrediction, PredictionCache
from repro.core.estimator import ReasoningEstimator
from repro.core.fingerprint import FingerprintLibrary
from repro.core.status import STATUS_DEGRADED, STATUS_DRIFTED, STATUS_OK
from repro.data.datasets import build_scope_data
from repro.serving.faults import FaultInjector, FaultPlan, FaultSpec
from repro.serving.feedback import (
    FeedbackMonitor, Outcome, PageHinkley, ReplayBuffer)


def _out(model="m", p=0.8, y=1.0, qid=0, wf=True, t=0.0,
         sims=None, idx=None, tokens=10, cost=0.01):
    return Outcome(
        query_id=qid, model=model, predicted_p=p, predicted_cost=cost,
        observed_y=y, observed_cost=cost, observed_tokens=tokens,
        sims=(np.array([0.9, 0.5, 0.3, 0.2, 0.1]) if sims is None
              else np.asarray(sims, np.float64)),
        idx=(np.arange(5) if idx is None else np.asarray(idx, int)),
        t=t, well_formed=wf)


# ---------------------------------------------------------------------------
# Page–Hinkley units
# ---------------------------------------------------------------------------
def test_page_hinkley_validation():
    with pytest.raises(ValueError, match="threshold"):
        PageHinkley(threshold=0.0)
    with pytest.raises(ValueError, match="min_obs"):
        PageHinkley(min_obs=0)


def test_page_hinkley_deterministic_and_reset():
    xs = [0.7, -0.3, -0.3, 0.7, 0.6, 0.6, 0.6, 0.6, 0.6]
    a = PageHinkley(delta=0.05, threshold=1.0, min_obs=1)
    b = PageHinkley(delta=0.05, threshold=1.0, min_obs=1)
    fired_a = [a.update(x) for x in xs]
    fired_b = [b.update(x) for x in xs]
    assert fired_a == fired_b
    assert (a.n, a.mean, a.m, a.score) == (b.n, b.mean, b.m, b.score)
    a.reset()
    assert a.n == 0 and a.mean == 0.0 and a.score == 0.0


def test_page_hinkley_min_obs_gates_alarm():
    det = PageHinkley(delta=0.0, threshold=0.1, min_obs=6)
    xs = [0.0, 0.0, 0.9, 0.9, 0.9]         # mass is there by obs 5...
    assert not any(det.update(x) for x in xs)
    assert det.score > det.threshold        # ...but the gate held it
    assert det.update(0.9)                  # obs 6 may alarm


def test_page_hinkley_clean_bounded_drift_unbounded():
    """A calibrated Bernoulli residual stream (p=0.7 predictions against
    70%-correct outcomes, in runs like real traffic) keeps the drift mass
    bounded below the 5.0 default; a persistent overconfidence shift
    accumulates without bound and alarms."""
    tile = [0.7, 0.7, 0.7] + [-0.3] * 7     # mean-zero, run-structured
    clean = PageHinkley()                   # defaults: 0.05 / 5.0 / 8
    assert not any(clean.update(x) for x in tile * 20)
    assert clean.score < clean.threshold
    drifted = PageHinkley()
    fired = [drifted.update(x) for x in tile * 2 + [0.7] * 40]
    assert any(fired)                       # the shift crosses 5.0
    assert not any(fired[: len(tile) * 2])  # but not on the clean prefix


# ---------------------------------------------------------------------------
# ReplayBuffer units
# ---------------------------------------------------------------------------
def test_replay_buffer_capacity_fifo_and_filters():
    with pytest.raises(ValueError, match="capacity"):
        ReplayBuffer(0)
    buf = ReplayBuffer(capacity=4)
    for i in range(6):
        buf.append(_out(model="a" if i % 2 else "b", p=0.5 + 0.1 * i, y=0.0,
                        qid=i))
    assert len(buf) == 4
    assert [r.query_id for r in buf.rows()] == [2, 3, 4, 5]   # oldest fell
    assert [r.query_id for r in buf.rows("a")] == [3, 5]
    np.testing.assert_allclose(buf.residuals("a"), [0.8, 1.0])
    assert buf.rows("nope") == []


def test_outcome_residual_sign():
    assert _out(p=0.9, y=0.0).residual == pytest.approx(0.9)   # overconfident
    assert _out(p=0.2, y=1.0).residual == pytest.approx(-0.8)


# ---------------------------------------------------------------------------
# FeedbackMonitor units
# ---------------------------------------------------------------------------
def _drive_drift(mon, model="m"):
    """One calibrated row to anchor the detector's mean, then a run of
    overconfident ones (Page–Hinkley detects the *shift*, not the level)."""
    hits = [mon.observe(_out(model=model, p=0.9, y=1.0))]
    hits += [mon.observe(_out(model=model, p=0.9, y=0.0)) for _ in range(5)]
    return [h for h in hits if h]


def test_monitor_alarms_once_until_cleared():
    mon = FeedbackMonitor(threshold=0.5, min_obs=1, delta=0.0)
    assert _drive_drift(mon) == ["m"]           # exactly one alarm event
    assert mon.drifted == {"m"} and mon.alarms == 1
    assert _drive_drift(mon) == []              # quarantined: no re-alarm
    mon.clear("m")
    assert mon.drifted == set()
    assert mon.detector("m").n == 0             # detector reset with it
    assert _drive_drift(mon) == ["m"]           # re-alarm after heal allowed
    assert mon.alarms == 2
    mon.clear("never-seen")                     # unknown model: no-op


def test_monitor_malformed_rows_buffered_not_scored():
    mon = FeedbackMonitor(threshold=0.5, min_obs=1, delta=0.0)
    for _ in range(10):
        assert mon.observe(_out(model="m", p=0.5, y=0.0, wf=False)) is None
    assert len(mon.buffer) == 10                # outcomes kept for refresh
    assert mon.detector("m").n == 0             # never scored
    assert mon.drifted == set() and mon.alarms == 0


def test_monitor_injectable_clock_stamps_rows():
    mon = FeedbackMonitor(clock=lambda: 42.0)
    mon.observe(_out(t=0.0))
    mon.observe(_out(t=7.0))
    assert [r.t for r in mon.buffer.rows()] == [42.0, 7.0]


def test_monitor_percentiles_and_can_refresh():
    mon = FeedbackMonitor()
    assert mon.residual_percentiles() == (0.0, 0.0)
    assert not mon.can_refresh("m")
    mon.observe(_out(model="m", p=0.8, y=1.0))      # residual -0.2
    mon.observe(_out(model="m", p=0.9, y=0.0))      # residual +0.9
    p50, p95 = mon.residual_percentiles()
    assert p50 == pytest.approx(0.55) and p95 == pytest.approx(0.865)
    assert mon.can_refresh("m") and mon.can_refresh("m", min_rows=2)
    assert not mon.can_refresh("m", min_rows=3)


def test_refresh_fingerprint_blend_math(world, library):
    """Observation mass pulls touched anchors toward the observed values
    by w/(w+1); untouched anchors keep the old fingerprint exactly."""
    model = next(m.name for m in world.pool if m.seen)
    old = library.get(model)
    mon = FeedbackMonitor()
    with pytest.raises(ValueError, match="no replay-buffer outcomes"):
        mon.refresh_fingerprint(model, library)
    # one observation, all similarity mass on anchor 0, observed wrong
    mon.observe(_out(model=model, p=0.8, y=0.0, tokens=20, cost=0.5,
                     sims=[1.0, 0.0, 0.0, 0.0, 0.0], idx=[0, 1, 2, 3, 4]))
    fp = mon.refresh_fingerprint(model, library)
    n = len(library.anchor_set)
    assert len(fp.y) == len(fp.tokens) == len(fp.cost) == n
    # blend = 1/(1+1) = 0.5: halfway from the old value toward observed 0
    assert fp.y[0] == pytest.approx(0.5 * old.y[0])
    assert fp.cost[0] == pytest.approx(0.5 * 0.5 + 0.5 * old.cost[0])
    assert fp.tokens[0] == round(0.5 * 20 + 0.5 * old.tokens[0])
    np.testing.assert_array_equal(fp.y[1:], old.y[1:])      # untouched
    np.testing.assert_array_equal(fp.tokens[1:], old.tokens[1:])
    assert fp.tokens.dtype.kind == "i"          # library.add-compatible
    assert library.get(model) is old            # refresh never mutates


# ---------------------------------------------------------------------------
# model_drift fault site
# ---------------------------------------------------------------------------
def test_model_drift_spec_validation():
    with pytest.raises(ValueError, match="must name a model"):
        FaultSpec("model_drift", 0)
    with pytest.raises(ValueError, match="model_drift cannot be rate-drawn"):
        FaultPlan.seeded(0, rates={"model_drift": 0.5})
    FaultSpec("model_drift", 0, arg=1.0, model="m")     # well-formed


def test_corrupt_outcome_persistent_from_index():
    inj = FaultInjector(FaultPlan([FaultSpec("model_drift", 2, arg=1.0,
                                             model="m")]))
    assert inj.corrupt_outcome("m", 1.0, 10, 0.5) == (1.0, 10, 0.5)  # ev 0
    assert inj.corrupt_outcome("m", 1.0, 10, 0.5) == (1.0, 10, 0.5)  # ev 1
    # event 2 arms the drift; this and every later observation degrades
    assert inj.corrupt_outcome("m", 1.0, 10, 0.5) == (0.0, 10, 1.0)
    assert inj.corrupt_outcome("m", 1.0, 12, 0.2) == (0.0, 12, 0.4)
    # other models are untouched even while the drift is active
    assert inj.corrupt_outcome("other", 1.0, 10, 0.5) == (1.0, 10, 0.5)


def test_corrupt_outcome_no_plan_is_identity():
    inj = FaultInjector(FaultPlan.none())
    for _ in range(8):
        assert inj.corrupt_outcome("m", 1.0, 10, 0.5) == (1.0, 10, 0.5)
    assert inj.fired == 0


# ---------------------------------------------------------------------------
# Cache quarantine rank: demote / heal / invalidate
# ---------------------------------------------------------------------------
def _ok(p=0.7, status=STATUS_OK, tier=1):
    return CachedPrediction(1, 12.0, True, p, 5, 49, status=status, tier=tier)


def test_cache_demote_model_and_heal():
    cache = PredictionCache()
    cache.put(1, "m", "v0", _ok(0.9))
    cache.put(2, "m", "v0", _ok(0.8))
    cache.put(3, "m", "v0", _ok(0.2, status=STATUS_DEGRADED))
    cache.put(1, "n", "v0", _ok(0.6))
    assert cache.demote_model("m") == 2         # degraded row left alone
    assert cache.get(1, "m", "v0").status == STATUS_DRIFTED
    assert cache.get(1, "m", "v0").p_conf == 0.9    # numbers kept
    assert cache.get(3, "m", "v0").status == STATUS_DEGRADED
    assert cache.get(1, "n", "v0").status == STATUS_OK  # other models kept
    # a DRIFTED write never clobbers OK; an OK write heals DRIFTED
    cache.put(1, "n", "v0", _ok(0.1, status=STATUS_DRIFTED))
    assert cache.get(1, "n", "v0").status == STATUS_OK
    cache.put(1, "m", "v0", _ok(0.75))
    assert cache.get(1, "m", "v0").status == STATUS_OK
    assert cache.get(1, "m", "v0").p_conf == 0.75
    # DRIFTED outranks DEGRADED (a stale decode beats a retrieval prior)
    cache.put(3, "m", "v0", _ok(0.4, status=STATUS_DRIFTED))
    assert cache.get(3, "m", "v0").status == STATUS_DRIFTED
    assert cache.invalidate_model("m") == 3
    assert cache.get(1, "m", "v0") is None and len(cache) == 1


# ---------------------------------------------------------------------------
# Engine integration: the closed self-healing loop
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def drift_setup(tiny_trained, world, retriever, anchor_set, library):
    """Engine factory with a *private* fingerprint library per engine —
    onboard(refresh=True) mutates it, and the session library is shared."""
    cfg, params, _ = tiny_trained
    data = build_scope_data(world, n_queries=160, seed=9)

    def mk(**kw):
        lib = FingerprintLibrary(anchor_set)
        for m in world.pool:
            if m.seen:
                lib.add(library.get(m.name))
        # 12-token budget (not the 6 most engine tests use): room for the
        # CoT span plus the YES/LEN/EOS body, so rows parse well-formed —
        # the drift detector only scores well-formed residuals
        return ScopeEngine.build(EngineConfig(
            estimator=ReasoningEstimator(cfg, params, max_new_tokens=12),
            retriever=retriever, library=lib,
            models_meta={m: world.models[m] for m in data.models}, **kw))
    return mk, data


def test_engine_builds_monitor_only_when_drift_detect(drift_setup):
    mk, _ = drift_setup
    assert mk().monitor is None
    eng = mk(drift_detect=True, drift_threshold=1.5, drift_min_obs=2,
             feedback_capacity=32)
    assert eng.monitor is not None
    assert eng.monitor.buffer.capacity == 32
    det = eng.monitor.detector("any")
    assert det.threshold == 1.5 and det.min_obs == 2


def test_serve_collects_outcomes_passively(drift_setup):
    """Detector-on serving with no fault: identical decisions to
    detector-off, one buffered outcome per executed query, no alarms."""
    mk, data = drift_setup
    qids = [int(q) for q in data.test_qids[:6]]
    pol = FixedAlphaPolicy(0.6)
    ref = mk().serve(data, qids, pol)
    eng = mk(drift_detect=True)
    got = eng.serve(data, qids, pol)
    assert [d.model for d in got.decisions] == [d.model for d in ref.decisions]
    np.testing.assert_array_equal([d.p_hat for d in got.decisions],
                                  [d.p_hat for d in ref.decisions])
    assert len(eng.monitor.buffer) == len(qids)
    assert eng.monitor.alarms == 0 and eng.monitor.drifted == set()
    row = eng.monitor.buffer.rows()[0]
    assert row.model == got.decisions[0].model
    assert row.predicted_p == got.decisions[0].p_hat
    assert row.sims.shape == row.idx.shape == (eng.config.k,)


def test_drift_closed_loop_detect_quarantine_refresh_recover(drift_setup):
    mk, data = drift_setup
    world = data.world
    qids = [int(q) for q in data.test_qids[:8]]
    queries = [data.queries[q] for q in qids]
    pol = FixedAlphaPolicy(0.6)
    # victim: the model whose estimator rows parse best on these queries
    # (the detector only scores well-formed rows)
    probe = mk().predict(RouteRequest(queries))
    victim = probe.models[int(np.argmax(probe.well_formed.sum(axis=0)))]
    # drift starts at outcome event len(qids): the first serve is clean
    eng = mk(drift_detect=True, drift_threshold=3.0, drift_delta=0.05,
             drift_min_obs=3,
             fault_plan=FaultPlan([FaultSpec("model_drift", len(qids),
                                             arg=1.0, model=victim)]))
    eng.serve(data, qids, pol, models=[victim])
    assert eng.monitor.alarms == 0          # clean traffic: no false alarm
    for _ in range(4):                      # drifted traffic until alarm
        if victim in eng.monitor.drifted:
            break
        eng.serve(data, qids, pol, models=[victim])
    assert victim in eng.monitor.drifted and eng.monitor.alarms == 1
    # quarantine: cached entries demoted in place, probes present DRIFTED
    ent = {k: e for k, e in eng.cache._store.items() if k[1] == victim}
    assert ent and all(e.status == STATUS_DRIFTED for e in ent.values())
    pool = eng.predict(RouteRequest(queries, models=[victim]))
    assert (pool.status == STATUS_DRIFTED).all()
    # heal: replay-buffer re-fingerprint + live hot-swap
    fp_before = float(np.mean(eng.library.get(victim).y))
    assert eng.monitor.can_refresh(victim)
    fp = eng.onboard(world, victim, refresh=True)
    assert eng.library.get(victim) is fp
    assert float(np.mean(fp.y)) < fp_before     # drifted outcomes pulled down
    assert victim not in eng.monitor.drifted
    assert eng.monitor.detector(victim).n == 0
    assert all(k[1] != victim for k in eng.cache._store)    # invalidated
    eng.hot_swap(eng.estimator, eng.config.estimator_version + "+heal")
    after = eng.predict(RouteRequest(queries, models=[victim]))
    assert after.cache_hits == 0                # version bump: fresh space
    assert not (after.status == STATUS_DRIFTED).any()
    report = eng.serve(data, qids, pol, models=[victim])
    assert all(d.status != "DRIFTED" for d in report.decisions)


def test_hot_swap_version_bump_and_parity(drift_setup):
    mk, data = drift_setup
    eng = mk()
    queries = [data.queries[int(q)] for q in data.test_qids[:3]]
    a = eng.predict(RouteRequest(queries))
    with pytest.raises(ValueError, match="new estimator_version"):
        eng.hot_swap(eng.estimator, "v0")
    eng.hot_swap(eng.estimator, "v0+swap")
    assert eng.config.estimator_version == "v0+swap" and eng._hot_swaps == 1
    b = eng.predict(RouteRequest(queries))
    assert b.cache_hits == 0                    # old entries unreachable
    assert b.cache_misses == a.cache_misses
    np.testing.assert_array_equal(a.p_hat, b.p_hat)     # same params, same
    np.testing.assert_array_equal(a.y_hat, b.y_hat)     # predictions


def test_hot_swap_drops_stale_tier0_and_stamps_fresh_one(drift_setup):
    from repro.models import tier0 as T0
    import jax
    mk, _ = drift_setup
    head = T0.Tier0Head(T0.init_tier0(jax.random.PRNGKey(5)))
    eng = mk(tier0=head, escalation_threshold=0.9)
    eng.hot_swap(eng.estimator, "v1")           # implicit: head dropped
    assert eng.config.tier0 is None
    head2 = T0.Tier0Head(T0.init_tier0(jax.random.PRNGKey(6)))
    eng.hot_swap(eng.estimator, "v2", tier0=head2)
    assert eng.config.tier0 is head2 and head2.version == "v2"


def test_hot_swap_at_tick_boundary_matches_fresh_engine(drift_setup):
    """Post-swap bit-parity: ticks served after a mid-stream hot_swap are
    bit-identical to a fresh engine that started on the new params
    (whole-retire, overlap off: tick boundaries align with prompt
    serialization, so the swap lands exactly between ticks)."""
    import jax
    from repro.configs.scope_estimator import TINY
    from repro.models import model as M
    mk, data = drift_setup
    pol = FixedAlphaPolicy(0.6)
    ticks = [[int(q) for q in data.test_qids[:4]],
             [int(q) for q in data.test_qids[4:8]]]
    params_b = M.init_params(jax.random.PRNGKey(1), TINY)

    eng = mk()
    reports = []
    for i, r in enumerate(eng.serve_stream(
            data, [list(t) for t in ticks], pol, use_cache=False,
            overlap=False, refill=False)):
        reports.append(r)
        if i == 0:
            eng.hot_swap(ReasoningEstimator(TINY, params_b,
                                            max_new_tokens=12), "v0+swap")
    ref = mk()
    ref.set_estimator(ReasoningEstimator(TINY, params_b,
                                       max_new_tokens=12), "v0+swap")
    want = next(iter(ref.serve_stream(data, [list(ticks[1])], pol,
                                      use_cache=False, overlap=False,
                                      refill=False)))
    got = reports[1]
    assert [d.model for d in got.decisions] == \
        [d.model for d in want.decisions]
    np.testing.assert_array_equal([d.p_hat for d in got.decisions],
                                  [d.p_hat for d in want.decisions])
    np.testing.assert_array_equal([d.cost_hat for d in got.decisions],
                                  [d.cost_hat for d in want.decisions])


# ---------------------------------------------------------------------------
# DriftAwarePolicy
# ---------------------------------------------------------------------------
def test_drift_aware_policy_validation():
    inner = FixedAlphaPolicy(0.6)
    with pytest.raises(ValueError, match="unknown mode"):
        DriftAwarePolicy(inner, mode="bogus")
    with pytest.raises(ValueError, match="weight"):
        DriftAwarePolicy(inner, mode="downweight", weight=1.5)
    assert DriftAwarePolicy(inner).name == f"drift_aware({inner.name})"


def test_drift_aware_policy_excludes_and_downweights(drift_setup):
    mk, data = drift_setup
    eng = mk(drift_detect=True)
    queries = [data.queries[int(q)] for q in data.test_qids[:6]]
    pool = eng.predict(RouteRequest(queries))
    inner = FixedAlphaPolicy(0.6)
    base = inner.decide(pool, eng)
    # empty quarantine set: decision-identical pass-through
    thru = DriftAwarePolicy(inner).decide(pool, eng)
    np.testing.assert_array_equal(thru.choices, base.choices)
    assert "drift_excluded" not in thru.info
    # quarantine the most-chosen model: exclude routes around it
    counts = np.bincount(np.asarray(base.choices, int),
                         minlength=len(pool.models))
    victim = pool.models[int(np.argmax(counts))]
    eng.monitor.drifted.add(victim)
    excl = DriftAwarePolicy(inner).decide(pool, eng)
    assert victim not in {pool.models[int(c)] for c in excl.choices}
    assert excl.info["drift_excluded"] == [victim]
    # downweight keeps the model in the pool at scaled p_hat
    down = DriftAwarePolicy(inner, mode="downweight",
                            weight=0.5).decide(pool, eng)
    assert down.info["drift_downweighted"] == [victim]
    assert all(0 <= int(c) < len(pool.models) for c in down.choices)
    # all models quarantined: exclude falls back to the full pool
    eng.monitor.drifted.update(pool.models)
    allq = DriftAwarePolicy(inner).decide(pool, eng)
    np.testing.assert_array_equal(allq.choices, base.choices)
    assert allq.info["drift_all_quarantined"] is True
    eng.monitor.drifted.clear()


def test_drift_aware_policy_without_monitor_is_passthrough(drift_setup):
    mk, data = drift_setup
    eng = mk()                                  # no monitor at all
    queries = [data.queries[int(q)] for q in data.test_qids[:3]]
    pool = eng.predict(RouteRequest(queries))
    inner = FixedAlphaPolicy(0.6)
    got = DriftAwarePolicy(inner).decide(pool, eng)
    np.testing.assert_array_equal(got.choices, inner.decide(pool, eng).choices)


# ---------------------------------------------------------------------------
# Tier-0 recalibration from observed outcomes (the drift hot-swap path)
# ---------------------------------------------------------------------------
def test_recalibrate_tier0_refits_temperature_shares_params():
    import jax
    from repro.models import tier0 as T0
    from repro.training.tier0 import recalibrate_tier0
    head = T0.Tier0Head(T0.init_tier0(jax.random.PRNGKey(7)))
    p = np.full(64, 0.9)
    flat = recalibrate_tier0(head, p, np.zeros(64))     # confidently wrong
    assert flat.params is head.params                   # no weight update
    assert flat.temperature == pytest.approx(4.0)       # grid max: flatten
    sharp = recalibrate_tier0(head, p, np.ones(64))     # confidently right
    assert sharp.temperature == pytest.approx(0.25)     # grid min: sharpen
    assert head.temperature == 1.0                      # input untouched
