"""Parity tests pinning the fused serve path against the legacy behavior:
scan-decode vs the per-token dispatch loop, batched parse vs the scalar
reference, batched cache probes vs per-key accounting, and the vectorized
utility/calibration math vs the per-query loops."""
import numpy as np
import pytest

import jax

from repro.api.cache import CachedPrediction, PredictionCache
from repro.configs.scope_estimator import TINY
from repro.core import calibration, utility
from repro.core.estimator import ReasoningEstimator, parse_generations
from repro.data import tokenizer as tok
from repro.models import model as M
from repro.serving import sampler

# the single pinned copy of the pre-fusion decode loop (also the benchmark
# baseline) lives in the benchmark module
from benchmarks.bench_serve_latency import legacy_generate


@pytest.fixture(scope="module")
def tiny_params():
    return M.init_params(jax.random.PRNGKey(11), TINY)


@pytest.fixture(scope="module")
def prompts():
    return np.random.default_rng(3).integers(
        3, 100, size=(4, 18)).astype(np.int32)


def _assert_decode_parity(params, prompts, **kw):
    g_old, full = legacy_generate(params, TINY, prompts, **kw)
    # warm the executables, then re-run under a device->host transfer guard:
    # the fused path must produce its device outputs without a single
    # implicit sync (the runtime complement of scopelint's static pass) —
    # the only intended syncs are the np.asarray conversions at parse time,
    # which happen outside the guard below
    sampler.generate(params, TINY, prompts, **kw)
    with jax.transfer_guard_device_to_host("disallow"):
        gen_dev, dec_dev = sampler.generate_async(params, TINY, prompts,
                                                  **kw)
    g_new, dec = np.asarray(gen_dev), np.asarray(dec_dev)
    np.testing.assert_array_equal(g_old, g_new)
    np.testing.assert_allclose(
        full[:, :, list(sampler.DECISION_TOKENS)], dec,
        atol=1e-5, rtol=1e-5)
    return g_new


def test_scan_decode_matches_loop_greedy(tiny_params, prompts):
    _assert_decode_parity(tiny_params, prompts, max_new_tokens=8)


def test_scan_decode_matches_loop_temperature(tiny_params, prompts):
    _assert_decode_parity(tiny_params, prompts, max_new_tokens=8,
                          temperature=0.7, rng=jax.random.PRNGKey(42))


def test_scan_decode_matches_loop_eos_early_stop(tiny_params, prompts):
    # bias the (tied) output embedding so EOS becomes argmax within a few
    # steps — exercises the carried done-mask, not just the no-EOS path
    biased = dict(tiny_params)
    biased["embed"] = tiny_params["embed"].at[tok.EOS].mul(40.0)
    gen = _assert_decode_parity(biased, prompts, max_new_tokens=10)
    assert (gen == tok.EOS).any(), "EOS path was not exercised"
    for row in gen:
        row = list(row)
        if tok.EOS in row:
            after = row[row.index(tok.EOS) + 1:]
            assert all(t == tok.PAD for t in after)


def test_scan_decode_no_eos_stop_when_disabled(tiny_params, prompts):
    biased = dict(tiny_params)
    biased["embed"] = tiny_params["embed"].at[tok.EOS].mul(40.0)
    _assert_decode_parity(biased, prompts, max_new_tokens=6,
                          stop_at_eos=False)


# ---------------------------------------------------------------------------
# Batched parse vs the scalar reference
# ---------------------------------------------------------------------------
def test_parse_batch_matches_parse_one_on_edge_cases():
    L = tok.LEN_BASE
    rows = [
        # well-formed CoT
        [tok.THINK, 50, 51, tok.THINK_END, tok.YES, L + 3, tok.EOS, tok.PAD],
        # well-formed NoCoT
        [tok.NO, L + 1, tok.EOS, tok.PAD, tok.PAD, tok.PAD, tok.PAD, tok.PAD],
        # THINK without THINK_END -> malformed, decision searched from 0
        [tok.THINK, tok.YES, L + 2, tok.EOS, 55, 56, 57, 58],
        # no decision token at all
        [50, 51, 52, 53, 54, 55, 56, 57],
        # YES inside the CoT span is skipped; NO after THINK_END decides
        [tok.THINK, tok.YES, tok.THINK_END, tok.NO, L + 2, tok.EOS,
         tok.PAD, tok.PAD],
        # bad length bucket
        [tok.YES, 500, tok.EOS, tok.PAD, tok.PAD, tok.PAD, tok.PAD, tok.PAD],
        # missing EOS in third body slot
        [tok.YES, L + 4, 77, tok.PAD, tok.PAD, tok.PAD, tok.PAD, tok.PAD],
        # PAD interleaved before the decision (stripped by the body filter)
        [tok.PAD, tok.YES, tok.PAD, L + 5, tok.EOS, tok.PAD, tok.PAD,
         tok.PAD],
        # THINK_END before THINK (degenerate rationale length)
        [tok.THINK_END, tok.THINK, tok.NO, L + 1, tok.EOS, tok.PAD, tok.PAD,
         tok.PAD],
        # all PAD
        [tok.PAD] * 8,
    ]
    gen = np.asarray(rows, np.int32)
    dec = np.random.default_rng(5).normal(size=(len(rows), 8, 2))
    batch = parse_generations(gen, dec)
    for i in range(len(rows)):
        ref = ReasoningEstimator._parse_one(gen[i], dec[i])
        assert int(batch.y_hat[i]) == ref.y_hat, i
        assert float(batch.len_hat[i]) == pytest.approx(ref.len_hat), i
        assert bool(batch.well_formed[i]) == ref.well_formed, i
        assert float(batch.p_conf[i]) == pytest.approx(ref.p_conf), i
        assert int(batch.pred_tokens[i]) == ref.pred_tokens, i
        assert int(batch.rationale_len[i]) == ref.rationale_len, i


def test_parse_batch_matches_parse_one_fuzz():
    rng = np.random.default_rng(17)
    # dense over the special-token range so CoT / decision / EOS collisions
    # are frequent
    gen = rng.integers(0, 16, size=(200, 12)).astype(np.int32)
    gen[rng.random(gen.shape) < 0.2] = tok.LEN_BASE + rng.integers(
        0, tok.NUM_LEN_BUCKETS)
    dec = rng.normal(size=(200, 12, 2))
    batch = parse_generations(gen, dec)
    for i in range(len(gen)):
        ref = ReasoningEstimator._parse_one(gen[i], dec[i])
        got = (int(batch.y_hat[i]), bool(batch.well_formed[i]),
               int(batch.pred_tokens[i]), int(batch.rationale_len[i]))
        assert got == (ref.y_hat, ref.well_formed, ref.pred_tokens,
                       ref.rationale_len), i
        assert float(batch.p_conf[i]) == pytest.approx(ref.p_conf), i
        assert float(batch.len_hat[i]) == pytest.approx(ref.len_hat), i


def test_parse_batch_empty():
    batch = parse_generations(np.zeros((0, 12), np.int32),
                              np.zeros((0, 12, 2)))
    assert len(batch) == 0 and batch.to_predictions() == []


# ---------------------------------------------------------------------------
# Batched cache probes
# ---------------------------------------------------------------------------
def _entry(i):
    return CachedPrediction(y_hat=i % 2, len_hat=32.0 + i, well_formed=True,
                            p_conf=0.1 + 0.01 * i, pred_tokens=5 + i,
                            prompt_tokens=40 + i)


def test_cache_get_many_hit_miss_accounting():
    cache = PredictionCache()
    cache.put_many([(q, "m", "v0") for q in (1, 3)], [_entry(1), _entry(3)])
    col = cache.get_many([1, 2, 3, 4], "m", "v0")
    np.testing.assert_array_equal(col.mask, [True, False, True, False])
    assert (cache.stats.hits, cache.stats.misses) == (2, 2)
    np.testing.assert_allclose(col.len_hat, [33.0, 0.0, 35.0, 0.0])
    np.testing.assert_allclose(col.p_conf[col.mask], [0.11, 0.13])
    assert col.pred_tokens[2] == 8 and col.prompt_tokens[0] == 41
    # version and model are part of the key
    assert not cache.get_many([1, 3], "m", "v1").mask.any()
    assert not cache.get_many([1, 3], "other", "v0").mask.any()
    assert (cache.stats.hits, cache.stats.misses) == (2, 6)


def test_cache_get_many_matches_scalar_get_and_lru():
    a, b = PredictionCache(capacity=3), PredictionCache(capacity=3)
    for c in (a, b):
        c.put_many([(q, "m", "v") for q in (1, 2, 3)],
                   [_entry(q) for q in (1, 2, 3)])
    # same probe through both APIs -> same stats and same LRU order
    for q in (2, 9):
        a.get(q, "m", "v")
    b.get_many([2, 9], "m", "v")
    assert (a.stats.hits, a.stats.misses) == (b.stats.hits, b.stats.misses)
    # probing q=2 refreshed it; inserting one more must evict q=1
    for c in (a, b):
        c.put_many([(4, "m", "v")], [_entry(4)])
        assert c.get(1, "m", "v") is None
        assert c.get(2, "m", "v") is not None
        assert c.stats.evictions == 1


def test_put_many_eviction_and_length_mismatch():
    cache = PredictionCache(capacity=2)
    cache.put_many([(q, "m", "v") for q in range(5)],
                   [_entry(q) for q in range(5)])
    assert len(cache) == 2 and cache.stats.evictions == 3
    with pytest.raises(ValueError):
        cache.put_many([(0, "m", "v")], [])


# ---------------------------------------------------------------------------
# Vectorized decision math vs the per-query reference
# ---------------------------------------------------------------------------
def test_normalize_cost_axis_matches_per_row_loop():
    rng = np.random.default_rng(0)
    c = rng.uniform(1e-5, 2e-3, size=(6, 5))
    c[2] = 7e-4                                        # degenerate row
    got = utility.normalize_cost(c, axis=1)
    ref = np.stack([utility.normalize_cost(row) for row in c])
    np.testing.assert_allclose(got, ref, atol=1e-12)
    with pytest.raises(ValueError):
        utility.normalize_cost(c, axis=1, c_min=0.0)


def test_calibration_batch_matches_per_query_loop(library, retriever, world):
    models = [m.name for m in world.pool if m.seen][:4]
    fps = {m: library.get(m) for m in models}
    rng = np.random.default_rng(1)
    Q, K = 7, 5
    embs = rng.normal(size=(Q, 32)).astype(np.float32)   # EMBED_DIM
    sims, idx = retriever.retrieve(embs, K)
    got = calibration.calibration_utilities_batch(fps, models, idx, sims,
                                                  0.6)
    ref = np.stack([
        calibration.calibration_utilities(fps, models, idx[q], sims[q], 0.6)
        for q in range(Q)])
    np.testing.assert_allclose(got, ref, atol=1e-12)
