"""Per-kernel correctness: Pallas (interpret) and XLA twins vs oracles,
swept over shapes, dtypes and feature flags."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_pallas
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.topk_retrieval import topk_retrieval as topk_pallas


def _qkv(key, b, hq, hkv, sq, sk, d, dv=None, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, dv or d), dtype)
    return q, k, v


ATTN_CASES = [
    # b, hq, hkv, sq, sk, d, causal, window, softcap
    (1, 2, 2, 128, 128, 32, True, 0, 0.0),
    (2, 4, 2, 256, 256, 64, True, 0, 0.0),
    (2, 4, 1, 192, 192, 64, True, 64, 0.0),
    (1, 8, 4, 128, 128, 32, True, 0, 50.0),
    (2, 2, 2, 96, 160, 32, False, 0, 0.0),
    (1, 4, 4, 256, 256, 64, True, 100, 30.0),
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_pallas_vs_ref(case):
    b, hq, hkv, sq, sk, d, causal, window, cap = case
    q, k, v = _qkv(jax.random.PRNGKey(0), b, hq, hkv, sq, sk, d)
    want = ref.attention(q, k, v, causal=causal, window=window, softcap=cap)
    got = fa_pallas(q, k, v, causal=causal, window=window, softcap=cap,
                    block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 4, 2, 128, 128, 64, dtype=dtype)
    want = ref.attention(q, k, v, causal=True)
    got = fa_pallas(q, k, v, causal=True, block_q=64, block_k=64)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol,
                               rtol=atol)


@pytest.mark.parametrize("case", ATTN_CASES[:4])
def test_xla_blocked_attention_vs_ref(case):
    b, hq, hkv, sq, sk, d, causal, window, cap = case
    q, k, v = _qkv(jax.random.PRNGKey(2), b, hq, hkv, sq, sk, d)
    want = ref.attention(q, k, v, causal=causal, window=window, softcap=cap)
    if window > 0:
        got = ops._banded_window_attention(
            q, k, v, window=window, causal=causal, softcap=cap, scale=None,
            q_offset=0, block_q=64)
    else:
        got = ops._blocked_attention(q, k, v, causal=causal, softcap=cap,
                                     scale=None, q_offset=0, block_q=64,
                                     block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_attention_separate_v_dim():
    q, k, v = _qkv(jax.random.PRNGKey(3), 2, 4, 2, 128, 128, 48, dv=32)
    want = ref.attention(q, k, v, causal=True)
    got = fa_pallas(q, k, v, causal=True, block_q=64, block_k=64)
    assert got.shape == (2, 4, 128, 32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_attention_matches_ref():
    b, hq, hkv, S, d = 2, 4, 2, 64, 32
    key = jax.random.PRNGKey(4)
    q, kc, vc = _qkv(key, b, hq, hkv, 1, S, d)
    cache_len = 40
    want = ref.attention(q, kc[:, :, :cache_len], vc[:, :, :cache_len],
                         causal=True, q_offset=cache_len - 1)
    got = ops.decode_attention(q, kc, vc, cache_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


SSD_CASES = [
    # b, l, h, p, n, chunk
    (1, 64, 2, 16, 8, 16),
    (2, 128, 3, 16, 8, 32),
    (2, 96, 1, 32, 16, 24),
    (1, 256, 4, 8, 4, 128),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_pallas_vs_ref(case):
    b, l, h, p, n, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, n)) * 0.5
    C = jax.random.normal(ks[4], (b, l, n)) * 0.5
    yr, sr = ref.ssd(x, dt, A, B, C, chunk=chunk)
    yp, sp = ssd_scan(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), atol=2e-5)


def test_ssd_decode_consistency():
    b, l, h, p, n = 1, 32, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    x = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, n)) * 0.5
    C = jax.random.normal(ks[4], (b, l, n)) * 0.5
    y_chunk, s_chunk = ref.ssd(x, dt, A, B, C, chunk=8)
    st = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        yt, st = ref.ssd_decode_step(st, x[:, t], dt[:, t], A, B[:, t],
                                     C[:, t])
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_chunk), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(s_chunk),
                               atol=2e-5)


def test_ssd_init_state_handoff():
    """Chunked scan with init_state == one long chunked scan."""
    b, l, h, p, n = 1, 64, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, n)) * 0.5
    C = jax.random.normal(ks[4], (b, l, n)) * 0.5
    y_full, s_full = ref.ssd(x, dt, A, B, C, chunk=16)
    half = l // 2
    y1, s1 = ref.ssd(x[:, :half], dt[:, :half], A, B[:, :half], C[:, :half],
                     chunk=16)
    y2, s2 = ref.ssd(x[:, half:], dt[:, half:], A, B[:, half:], C[:, half:],
                     chunk=16, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=2e-5)


TOPK_CASES = [(16, 64, 8, 3), (37, 301, 24, 5), (128, 250, 32, 5),
              (5, 1000, 16, 10)]


@pytest.mark.parametrize("case", TOPK_CASES)
def test_topk_pallas_vs_ref(case):
    nq, na, d, k = case
    kq, ka = jax.random.split(jax.random.PRNGKey(8))
    q = jax.random.normal(kq, (nq, d))
    a = jax.random.normal(ka, (na, d))
    sr, ir = ref.topk_retrieval(q, a, k)
    sp, ip = topk_pallas(q, a, k, block_q=16, block_n=64)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), atol=1e-5)
    assert (np.asarray(ip) == np.asarray(ir)).mean() > 0.99


DECODE_CASES = [
    # b, hq, hkv, S, d, cache_len, window, softcap
    (2, 4, 2, 128, 32, 100, 0, 0.0),
    (1, 8, 4, 256, 64, 256, 0, 50.0),
    (2, 2, 1, 96, 32, 40, 16, 0.0),
    (1, 4, 4, 300, 32, 123, 0, 0.0),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_pallas_vs_ref(case):
    from repro.kernels.decode_attention import decode_attention as da_pallas
    b, hq, hkv, S, d, clen, window, cap = case
    q, kc, vc = _qkv(jax.random.PRNGKey(9), b, hq, hkv, 1, S, d)
    want = ops.decode_attention(q, kc, vc, clen, window=window, softcap=cap)
    got = da_pallas(q, kc, vc, clen, window=window, softcap=cap, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_decode_attention_pallas_per_batch_lengths():
    from repro.kernels.decode_attention import decode_attention as da_pallas
    b, hq, hkv, S, d = 3, 4, 2, 128, 32
    q, kc, vc = _qkv(jax.random.PRNGKey(10), b, hq, hkv, 1, S, d)
    lens = jnp.array([10, 77, 128])
    want = ops.decode_attention(q, kc, vc, lens)
    got = da_pallas(q, kc, vc, lens, block_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# Paged decode attention: XLA gather path vs dense oracle (bit-exact), the
# Pallas paged kernel vs both (interpret tolerance)
# ---------------------------------------------------------------------------
def _paged_layout(key, b, hkv, S, d, page_size, n_pages, perm_seed=0):
    """A dense (b, hkv, S, d) cache scattered into a (n_pages+1, ...) page
    store under a deliberately permuted page table — physical order must
    not matter."""
    kk, kv = jax.random.split(key)
    kc = jax.random.normal(kk, (b, hkv, S, d)) * 0.5
    vc = jax.random.normal(kv, (b, hkv, S, d)) * 0.5
    n_w = S // page_size
    assert b * n_w <= n_pages
    rng = np.random.default_rng(perm_seed)
    phys = rng.permutation(n_pages)[: b * n_w].reshape(b, n_w)
    k_pages = jnp.zeros((n_pages + 1, hkv, page_size, d))
    v_pages = jnp.zeros((n_pages + 1, hkv, page_size, d))
    for i in range(b):
        for w in range(n_w):
            sl = slice(w * page_size, (w + 1) * page_size)
            k_pages = k_pages.at[phys[i, w]].set(kc[i, :, sl])
            v_pages = v_pages.at[phys[i, w]].set(vc[i, :, sl])
    return kc, vc, k_pages, v_pages, jnp.asarray(phys, jnp.int32)


PAGED_CASES = [
    # b, hq, hkv, S, d, page_size, lens, softcap
    (2, 4, 2, 128, 32, 16, (100, 128), 0.0),
    (1, 8, 4, 64, 64, 8, (40,), 50.0),
    (3, 4, 2, 96, 32, 32, (10, 77, 96), 0.0),
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_decode_xla_bit_identical_to_dense(case):
    """The gather-based paged path reconstructs the dense layout exactly
    and feeds the same kernel: bitwise-equal outputs, any page
    permutation, per-row lengths included."""
    b, hq, hkv, S, d, page, lens, cap = case
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (b, hq, 1, d)) * 0.5
    kc, vc, kp, vp, table = _paged_layout(
        jax.random.PRNGKey(12), b, hkv, S, d, page, n_pages=64)
    clen = jnp.asarray(lens, jnp.int32)
    want = ops.decode_attention(q, kc, vc, clen, softcap=cap, impl="xla")
    got = ops.paged_decode_attention(q, kp, vp, clen, table,
                                     page_size=page, kv_cap=S, softcap=cap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_decode_pallas_vs_xla(case):
    from repro.kernels.decode_attention import KernelType
    b, hq, hkv, S, d, page, lens, cap = case
    key = jax.random.PRNGKey(13)
    q = jax.random.normal(key, (b, hq, 1, d)) * 0.5
    _, _, kp, vp, table = _paged_layout(
        jax.random.PRNGKey(14), b, hkv, S, d, page, n_pages=64)
    clen = jnp.asarray(lens, jnp.int32)
    want = ops.paged_decode_attention(q, kp, vp, clen, table,
                                      page_size=page, kv_cap=S, softcap=cap)
    got = ops.paged_decode_attention(q, kp, vp, clen, table,
                                     page_size=page, kv_cap=S, softcap=cap,
                                     kernel=KernelType.PALLAS)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_trash_page_is_inert():
    """Garbage in the trash page (or any unreferenced page) cannot leak
    into the output: only table-referenced, length-valid positions
    contribute."""
    b, hq, hkv, S, d, page = 1, 4, 2, 64, 32, 16
    q = jax.random.normal(jax.random.PRNGKey(15), (b, hq, 1, d)) * 0.5
    _, _, kp, vp, table = _paged_layout(
        jax.random.PRNGKey(16), b, hkv, S, d, page, n_pages=32)
    clen = jnp.asarray([40], jnp.int32)
    base = ops.paged_decode_attention(q, kp, vp, clen, table,
                                      page_size=page, kv_cap=S)
    # poison the trash page and every page the table does not reference
    used = set(np.asarray(table).ravel().tolist())
    poison_k, poison_v = kp, vp
    for pid in range(33):
        if pid not in used:
            poison_k = poison_k.at[pid].set(1e9)
            poison_v = poison_v.at[pid].set(1e9)
    # positions past clen inside a referenced page are masked to exact 0
    got = ops.paged_decode_attention(q, poison_k, poison_v, clen, table,
                                     page_size=page, kv_cap=S)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))
