"""The repro.api surface: engine facade, live registry, prediction cache,
and pluggable routing policies.

Uses a deterministic fake estimator so these tests exercise the API
contract (cache accounting, onboarding, policy behavior) without paying for
SFT; the trained-estimator path is covered by test_router_e2e.py.
"""
import warnings

import numpy as np
import pytest

from repro.api import (
    AccuracyFloorPolicy, BatchReport, CostCeilingPolicy, EngineConfig,
    FixedAlphaPolicy, PoolRegistry, PredictionCache, RouteRequest,
    ScopeEngine, SetBudgetPolicy)
from repro.api.cache import CachedPrediction
from repro.core import serialization
from repro.core.estimator import Prediction
from repro.core.fingerprint import FingerprintLibrary
from repro.data.datasets import build_scope_data


class CountingEstimator:
    """Deterministic stand-in: prediction is a pure function of the prompt."""

    def __init__(self):
        self.pairs = 0          # total (query, model) prompts predicted

    def predict(self, prompts, rng=None, **kw):
        self.pairs += len(prompts)
        out = []
        for p in prompts:
            h = sum(p) % 97
            out.append(Prediction(
                y_hat=h % 2, len_hat=64.0 + h, well_formed=True,
                p_conf=0.25 + 0.5 * (h / 97.0), pred_tokens=6,
                rationale_len=4))
        return out


@pytest.fixture()
def engine_setup(world, library, retriever):
    data = build_scope_data(world, n_queries=80, seed=5)
    est = CountingEstimator()
    engine = ScopeEngine.build(EngineConfig(
        estimator=est, retriever=retriever, library=library,
        models_meta={m: world.models[m] for m in data.models}))
    return engine, est, data


def _queries(data, n=4):
    qids = [int(q) for q in data.test_qids[:n]]
    return qids, [data.queries[q] for q in qids]


# ---------------------------------------------------------------------------
# PredictionCache
# ---------------------------------------------------------------------------
def test_cache_hit_miss_and_eviction_accounting():
    cache = PredictionCache(capacity=2)
    entry = CachedPrediction(1, 64.0, True, 0.7, 6, 49)
    assert cache.get(1, "a", "v0") is None
    cache.put(1, "a", "v0", entry)
    assert cache.get(1, "a", "v0") == entry
    assert cache.get(1, "a", "v1") is None          # version is part of the key
    cache.put(1, "b", "v0", entry)
    cache.put(1, "c", "v0", entry)                  # evicts the LRU entry
    assert len(cache) == 2
    s = cache.stats
    assert (s.hits, s.misses, s.evictions) == (1, 2, 1)
    assert cache.invalidate_model("b") == 1
    assert len(cache) == 1


def test_engine_predict_runs_estimator_only_on_misses(engine_setup):
    engine, est, data = engine_setup
    _, queries = _queries(data)
    M = len(data.models)
    pool = engine.predict(RouteRequest(queries))
    assert (pool.cache_hits, pool.cache_misses) == (0, 4 * M)
    assert est.pairs == 4 * M
    warm = engine.predict(RouteRequest(queries))
    assert (warm.cache_hits, warm.cache_misses) == (4 * M, 0)
    assert est.pairs == 4 * M                       # estimator untouched
    np.testing.assert_allclose(warm.p_hat, pool.p_hat)
    np.testing.assert_allclose(warm.cost_hat, pool.cost_hat)
    assert warm.pred_overhead.sum() == 0            # no new tokens spent
    assert pool.pred_overhead.sum() > 0


def test_estimator_version_bump_invalidates(engine_setup):
    engine, est, data = engine_setup
    _, queries = _queries(data, n=2)
    engine.predict(RouteRequest(queries))
    before = est.pairs
    engine.set_estimator(est, "v1")
    pool = engine.predict(RouteRequest(queries))
    assert pool.cache_misses == 2 * len(data.models)
    assert est.pairs == 2 * before


def test_refresh_onboard_invalidates_cache(world, anchor_set, retriever):
    # private library: refresh overwrites fingerprints, so don't share the
    # session fixture
    lib = FingerprintLibrary(anchor_set)
    data = build_scope_data(world, n_queries=40, seed=6)
    for m in data.models:
        lib.onboard(world, m, seed=3)
    engine = ScopeEngine.build(EngineConfig(
        estimator=CountingEstimator(), retriever=retriever, library=lib,
        models_meta={m: world.models[m] for m in data.models}))
    _, queries = _queries(data, n=2)
    engine.predict(RouteRequest(queries))
    drifted = data.models[0]
    engine.onboard(world, drifted, seed=123, refresh=True)
    pool = engine.predict(RouteRequest(queries))
    assert pool.cache_misses == 2                   # only the drifted model


def test_short_estimator_output_raises(engine_setup):
    engine, est, data = engine_setup
    _, queries = _queries(data, n=2)

    class TruncatingEstimator:
        def predict(self, prompts, rng=None, **kw):
            return est.predict(prompts[:-1])

    engine.set_estimator(TruncatingEstimator(), "v-short")
    with pytest.raises(RuntimeError, match="predictions"):
        engine.predict(RouteRequest(queries))


def test_cost_hat_uses_actual_prompt_length(engine_setup, world, library,
                                            retriever):
    engine, est, data = engine_setup
    _, queries = _queries(data, n=1)
    m = data.models[0]
    pool = engine.predict(RouteRequest(queries, models=[m]))
    sims, idx = retriever.retrieve(queries[0].embedding[None], engine.config.k)
    prompt = serialization.serialize_prompt(
        world.models[m], engine.registry.index(m), library.anchor_set,
        library.get(m), sims[0], idx[0], queries[0])
    meta = world.models[m]
    expect = (len(prompt) * meta.price_in
              + pool.len_hat[0, 0] * meta.price_out) / 1e6
    assert pool.cost_hat[0, 0] == pytest.approx(expect)


# ---------------------------------------------------------------------------
# PoolRegistry
# ---------------------------------------------------------------------------
def test_registry_onboards_unseen_model_mid_session(engine_setup, world):
    engine, est, data = engine_setup
    _, queries = _queries(data)
    engine.predict(RouteRequest(queries))
    pairs_before = est.pairs

    unseen = "claude-sonnet-4.5"
    assert unseen not in engine.registry
    fp = engine.onboard(world, unseen, seed=99)
    assert len(fp.y) == len(engine.library.anchor_set)
    assert unseen in engine.registry
    assert engine.registry.models()[-1] == unseen

    # re-predicting the same queries runs the estimator ONLY for new pairs
    pool = engine.predict(RouteRequest(queries))
    assert pool.cache_misses == len(queries)
    assert est.pairs == pairs_before + len(queries)
    assert pool.p_hat.shape == (len(queries), len(data.models) + 1)


def test_registry_add_remove_keeps_indices_stable(world, library):
    reg = PoolRegistry(library,
                       {m.name: m for m in world.pool if m.seen})
    first = reg.models()[0]
    idx_keep = reg.index(reg.models()[1])
    reg.remove_model(first)
    assert first not in reg
    assert reg.index(reg.models()[0]) == idx_keep   # others unmoved
    n = len(reg)
    reg.add_model(world.models[first])              # re-register
    assert len(reg) == n + 1
    with pytest.raises(KeyError):
        reg.remove_model("not-a-model")


def test_engine_removal_invalidates_cache(engine_setup, world):
    engine, est, data = engine_setup
    _, queries = _queries(data, n=2)
    engine.predict(RouteRequest(queries))
    gone = data.models[0]
    engine.remove_model(gone)
    assert gone not in engine.registry
    pool = engine.predict(RouteRequest(queries))
    assert gone not in pool.models
    assert pool.cache_misses == 0                   # survivors still cached


# ---------------------------------------------------------------------------
# RoutingPolicy implementations
# ---------------------------------------------------------------------------
def test_fixed_alpha_policy_tracks_router_math(engine_setup):
    engine, _, data = engine_setup
    _, queries = _queries(data)
    pool = engine.predict(RouteRequest(queries))
    d = engine.decide(pool, FixedAlphaPolicy(0.6))
    expect = np.argmax(engine.utilities(pool, 0.6), axis=1)
    np.testing.assert_array_equal(d.choices, expect)
    with pytest.raises(ValueError):
        FixedAlphaPolicy(1.5)


def test_set_budget_policy_edges(engine_setup):
    engine, _, data = engine_setup
    _, queries = _queries(data)
    pool = engine.predict(RouteRequest(queries))
    cheapest = float(pool.cost_hat.min(axis=1).sum())
    dearest = float(pool.cost_hat.max(axis=1).sum())

    # budget below the cheapest possible routing: infeasible, conservative
    d_lo = engine.decide(pool, SetBudgetPolicy(cheapest * 0.5))
    assert d_lo.info["feasible"] is False
    rows = np.arange(len(queries))
    lo_cost = float(pool.cost_hat[rows, d_lo.choices].sum())
    assert lo_cost <= cheapest * (1 + 1e-9)

    # budget above the most expensive routing: feasible, max expected acc
    d_hi = engine.decide(pool, SetBudgetPolicy(dearest * 2.0))
    assert d_hi.info["feasible"] is True
    assert d_hi.info["expected_cost"] <= dearest * 2.0 + 1e-12
    assert (pool.p_hat[rows, d_hi.choices].sum()
            >= pool.p_hat[rows, d_lo.choices].sum() - 1e-12)


def test_accuracy_floor_policy(engine_setup):
    engine, _, data = engine_setup
    _, queries = _queries(data)
    pool = engine.predict(RouteRequest(queries))
    reachable = float(np.mean(pool.p_hat.max(axis=1)))

    d = engine.decide(pool, AccuracyFloorPolicy(reachable * 0.5))
    assert d.info["feasible"] is True
    assert d.info["expected_acc"] >= reachable * 0.5 - 1e-12

    d_inf = engine.decide(pool, AccuracyFloorPolicy(1.0))
    assert d_inf.info["feasible"] is False          # fake conf never hits 1.0
    assert d_inf.info["expected_acc"] == pytest.approx(reachable, abs=1e-6)


def test_cost_ceiling_policy(engine_setup):
    engine, _, data = engine_setup
    _, queries = _queries(data)
    pool = engine.predict(RouteRequest(queries))
    rows = np.arange(len(queries))

    ceiling = float(np.median(pool.cost_hat))
    d = engine.decide(pool, CostCeilingPolicy(ceiling, alpha=0.7))
    assert np.all(pool.cost_hat[rows, d.choices] <= ceiling + 1e-12)

    # ceiling below every model: per-query fallback to the cheapest
    d_fb = engine.decide(pool, CostCeilingPolicy(float(pool.cost_hat.min())
                                                 * 0.5))
    assert d_fb.info["fallback_queries"] == len(queries)
    np.testing.assert_array_equal(d_fb.choices,
                                  np.argmin(pool.cost_hat, axis=1))


# ---------------------------------------------------------------------------
# Serving through the facade
# ---------------------------------------------------------------------------
def test_engine_serve_and_policy_sweep_without_estimator(engine_setup):
    engine, est, data = engine_setup
    qids, _ = _queries(data)
    rep = engine.serve(data, qids, FixedAlphaPolicy(0.7))
    assert rep.executed and rep.n_queries == len(qids)
    assert abs(sum(rep.per_model_share.values()) - 1.0) < 1e-9
    pairs = est.pairs
    budget = rep.total_cost
    for policy in (FixedAlphaPolicy(0.2), SetBudgetPolicy(budget),
                   AccuracyFloorPolicy(0.4)):
        r = engine.serve(data, qids, policy)
        assert r.policy == policy.name
        assert r.cache_misses == 0
    assert est.pairs == pairs                       # sweep was estimator-free


def test_engine_serve_empty_batch(engine_setup):
    engine, _, data = engine_setup
    rep = engine.serve(data, [], FixedAlphaPolicy(0.5))
    assert isinstance(rep, BatchReport)
    assert rep.n_queries == 0 and not rep.executed
    assert rep.accuracy == 0.0 and rep.total_cost == 0.0


def test_engine_serve_empty_is_warning_free(engine_setup):
    # ported from the removed RouterService shim contract: a zero-query
    # serve must produce an explicit report, never a np.mean([]) warning
    engine, _, data = engine_setup
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        rep = engine.serve(data, [], FixedAlphaPolicy(0.5))
    assert rep.n_queries == 0
    assert rep.accuracy == 0.0 and rep.total_cost == 0.0
    assert set(rep.per_model_share) == set(data.models)


def test_uncached_predict_matches_cached_values(engine_setup):
    # ported from the removed ScopeRouter shim-parity test: the uncached
    # path (the shim's behavior) and the cached path agree on every value
    engine, est, data = engine_setup
    _, queries = _queries(data)
    pool_raw = engine.predict(RouteRequest(queries, models=data.models),
                              use_cache=False)
    assert (pool_raw.cache_hits, pool_raw.cache_misses) == \
        (0, len(queries) * len(data.models))
    pool = engine.predict(RouteRequest(queries, models=data.models))
    np.testing.assert_allclose(pool_raw.p_hat, pool.p_hat)
    np.testing.assert_allclose(pool_raw.cost_hat, pool.cost_hat)
    # decision math: policy decide == raw argmax over utilities
    d = engine.decide(pool_raw, FixedAlphaPolicy(0.6))
    np.testing.assert_array_equal(
        d.choices, np.argmax(engine.utilities(pool_raw, 0.6), axis=1))
    d_budget = engine.decide(pool_raw, SetBudgetPolicy(1e9))
    assert d_budget.info["feasible"] and 0.0 <= d_budget.alpha <= 1.0


def test_policy_selection_is_explicit():
    # the shim's silent budget-over-alpha kwarg precedence is retired: the
    # engine takes exactly one policy object, and each is validated
    with pytest.raises(ValueError):
        FixedAlphaPolicy(-0.1)
    with pytest.raises(ValueError):
        SetBudgetPolicy(-1.0)
    assert SetBudgetPolicy(0.5).name != FixedAlphaPolicy(0.5).name
