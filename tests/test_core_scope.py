"""Unit tests for SCOPE core: fingerprints, retrieval, serialization,
calibration, baselines, evaluation."""
import numpy as np
import pytest

from repro.core import calibration, serialization, utility
from repro.core.baselines import (
    KNNRouter, LinearSVMRouter, MLPRouter, chebyshev_choices,
    highest_cost_choices, oracle_labels, tts_outcome)
from repro.core.evaluation import evaluate_choices
from repro.core.fingerprint import build_fingerprint
from repro.data import tokenizer as tok


def test_fingerprint_shapes_and_onboard(world, anchor_set, library):
    fp = library.get("qwen3-14b")
    assert len(fp.y) == len(anchor_set)
    assert set(np.unique(fp.y)) <= {0, 1}
    assert np.all(fp.cost > 0)
    # training-free onboarding of an unseen model
    fp2 = library.onboard(world, "claude-sonnet-4.5", seed=9)
    assert "claude-sonnet-4.5" in library
    assert len(fp2.y) == len(anchor_set)


def test_fingerprint_reflects_skill(world, anchor_set):
    """A stronger model should have a higher anchor accuracy."""
    strong = build_fingerprint(world, "claude-sonnet-4.5", anchor_set, seed=1)
    weak = build_fingerprint(world, "gemma-3-27b", anchor_set, seed=1)
    assert strong.y.mean() > weak.y.mean()


def test_retrieval_topk_prefers_same_domain(world, anchor_set, retriever):
    qs = world.sample_queries(40, seed=123)
    embs = np.stack([world.embed(q) for q in qs])
    sims, idx = retriever.retrieve(embs, 5)
    assert sims.shape == (40, 5) and idx.shape == (40, 5)
    assert np.all(np.diff(sims, axis=1) <= 1e-6)     # sorted descending
    same = [np.mean([anchor_set.queries[i].domain == q.domain
                     for i in idx[j]]) for j, q in enumerate(qs)]
    assert np.mean(same) > 0.6                        # domain-coherent


def test_serialize_prompt_constant_length(world, anchor_set, library,
                                          retriever):
    qs = world.sample_queries(8, seed=5)
    embs = np.stack([world.embed(q) for q in qs])
    sims, idx = retriever.retrieve(embs, 5)
    lengths = set()
    for j, q in enumerate(qs):
        for mi, m in enumerate([p.name for p in world.pool if p.seen]):
            p = serialization.serialize_prompt(
                world.models[m], mi, anchor_set, library.get(m), sims[j],
                idx[j], q)
            lengths.add(len(p))
            assert all(0 <= t < tok.VOCAB_SIZE for t in p)
    assert len(lengths) == 1


def test_teacher_target_parses_back(world, anchor_set):
    q = world.sample_queries(1, seed=6)[0]
    target = serialization.teacher_target([1, 0, 1], [100, 300, 80], 1,
                                          1500.0, q, cot=True)
    parsed = tok.parse_prediction(target)
    assert parsed["well_formed"] and parsed["y_hat"] == 1
    assert abs(np.log(parsed["len_hat"] / 1500.0)) < 0.5


def test_calibration_prefers_consistently_correct_model(library, retriever,
                                                        world):
    qs = world.sample_queries(4, seed=8)
    embs = np.stack([world.embed(q) for q in qs])
    sims, idx = retriever.retrieve(embs, 5)
    models = ["deepseek-r1t2-chimera", "gemma-3-27b"]
    fps = {m: library.get(m) for m in models}
    u = calibration.calibration_utilities(fps, models, idx[0], sims[0],
                                          alpha=1.0)
    # at alpha=1 calibration is anchor accuracy: chimera >> gemma-27b
    assert u[0] > u[1]


def test_baseline_routers_learn_something(world, scope_data):
    models = scope_data.models
    train_q = scope_data.train_qids
    test_q = scope_data.test_qids
    embs_tr = np.stack([world.embed(scope_data.queries[q]) for q in train_q])
    embs_te = np.stack([world.embed(scope_data.queries[q]) for q in test_q])
    labels = oracle_labels(scope_data, train_q, models)
    for router in (KNNRouter(k=5), MLPRouter(steps=150),
                   LinearSVMRouter(steps=150)):
        router.fit(embs_tr, labels, len(models))
        pred = router.predict(embs_te)
        assert pred.shape == (len(test_q),)
        assert set(np.unique(pred)) <= set(range(len(models)))


def test_evaluate_choices_and_pgr_bounds(scope_data):
    models = scope_data.models
    qids = scope_data.test_qids
    rng = np.random.default_rng(0)
    choices = rng.integers(0, len(models), len(qids))
    ev = evaluate_choices(scope_data, qids, models, choices)
    assert 0.0 <= ev.avg_acc <= 1.0
    assert ev.total_cost > 0
    assert abs(sum(ev.per_model_share.values()) - 1.0) < 1e-9


def test_tts_executes_all_models(scope_data):
    qid = int(scope_data.test_qids[0])
    acc, tokens, cost = tts_outcome(scope_data, qid, scope_data.models)
    single = scope_data.record(qid, scope_data.models[0]).tokens
    assert tokens > single          # strictly more than any single model
    assert acc in (0, 1)


def test_decision_rule_baselines_shapes():
    rng = np.random.default_rng(1)
    p = rng.random((6, 4))
    c = rng.random((6, 4)) * 0.01 + 1e-4
    ch = chebyshev_choices(p, c, alpha=0.5)
    hc = highest_cost_choices(c, per_query_budget=0.005)
    assert ch.shape == (6,) and hc.shape == (6,)
    # highest-cost never exceeds the budget when feasible
    for q in range(6):
        if (c[q] <= 0.005).any():
            assert c[q, hc[q]] <= 0.005


# ---------------------------------------------------------------------------
# alpha_search: vectorized breakpoint/budget math vs the loop reference
# (the pre-vectorization oracle is pinned once, in benchmarks.bench_budget)
# ---------------------------------------------------------------------------
from benchmarks.bench_budget import _breakpoints_loop  # noqa: E402


def test_breakpoints_match_loop_reference():
    from repro.core import alpha_search
    rng = np.random.default_rng(7)
    for Q, M in ((1, 2), (5, 3), (12, 6), (3, 1)):
        p = rng.random((Q, M))
        s = rng.random((Q, M))
        vec = alpha_search.breakpoints(p, s)
        loop = _breakpoints_loop(p, s)
        # every loop breakpoint is represented within the dedup tolerance
        if len(loop) == 0:
            assert len(vec) == 0
            continue
        assert len(vec) <= len(loop)
        dist = np.abs(loop[:, None] - vec[None, :]).min(axis=1)
        assert dist.max() <= alpha_search.TIE_TOL


def test_route_for_alphas_matches_scalar():
    from repro.core import alpha_search
    rng = np.random.default_rng(3)
    p, s = rng.random((9, 5)), rng.random((9, 5))
    alphas = alpha_search.candidate_alphas(p, s)
    block = alpha_search.route_for_alphas(p, s, alphas, block=4)
    for i, a in enumerate(alphas):
        np.testing.assert_array_equal(
            block[i], alpha_search.route_for_alpha(p, s, float(a)))


def test_budget_alpha_tiebreak_is_tolerant():
    from repro.core import alpha_search
    # two candidate regimes with performances equal up to float noise but
    # different costs: the cheaper one must win (exact == used to be brittle)
    p = np.array([[0.6, 0.6 + 1e-12]])
    s = np.array([[1.0, 0.0]])
    c = np.array([[1.0, 5.0]])
    alpha, choice, info = alpha_search.budget_alpha(p, s, c, budget=10.0)
    assert info["feasible"]
    assert choice[0] == 0                   # same perf within tol, cheaper
    assert info["expected_cost"] == 1.0


def test_budget_alpha_matches_loop_on_random_pools():
    from repro.core import alpha_search
    rng = np.random.default_rng(11)
    for _ in range(10):
        Q, M = int(rng.integers(2, 8)), int(rng.integers(2, 5))
        p = rng.random((Q, M))
        c = rng.random((Q, M)) * 0.01 + 1e-4
        s = 1.0 - c / c.max()
        budget = float(np.sort(c.min(axis=1)).sum() * rng.uniform(0.8, 2.0))
        a, choice, info = alpha_search.budget_alpha(p, s, c, budget)
        rows = np.arange(Q)
        cost = c[rows, choice].sum()
        perf = p[rows, choice].sum()
        # cross-check against the candidate set built from the LOOP
        # breakpoints (the pre-vectorization enumeration)
        grid = np.concatenate([[0.0], _breakpoints_loop(p, s), [1.0]])
        loop_cands = np.unique(np.concatenate(
            [grid, (grid[:-1] + grid[1:]) / 2.0]))
        loop_routes = [alpha_search.route_for_alpha(p, s, cand)
                       for cand in loop_cands]
        if info["feasible"]:
            assert cost <= budget + 1e-9
            # no loop-enumerated alpha does strictly better within budget
            for ch in loop_routes:
                if c[rows, ch].sum() <= budget:
                    assert p[rows, ch].sum() <= perf + alpha_search.TIE_TOL
        else:
            cheap = min(c[rows, ch].sum() for ch in loop_routes)
            assert cost <= cheap + 1e-9
