"""Serving substrate: sampler, batched engine, estimator plumbing."""
import jax
import numpy as np

from repro.core.estimator import ReasoningEstimator
from repro.data import tokenizer as tok
from repro.serving.engine import ServingEngine
from repro.serving.sampler import generate


def test_generate_shapes_and_determinism(tiny_trained):
    cfg, params, _ = tiny_trained
    prompts = np.random.default_rng(0).integers(
        3, 100, size=(4, 20)).astype(np.int32)
    g1, d1 = generate(params, cfg, prompts, max_new_tokens=6)
    g2, _ = generate(params, cfg, prompts, max_new_tokens=6)
    # only the (YES, NO) decision pair crosses to the host, never (b, T, V)
    assert g1.shape == (4, 6) and d1.shape == (4, 6, 2)
    np.testing.assert_array_equal(g1, g2)          # greedy is deterministic


def test_generate_stops_at_eos(tiny_trained, scope_data, library, retriever):
    from repro.core import serialization
    cfg, params, _ = tiny_trained
    world = scope_data.world
    q = scope_data.queries[int(scope_data.test_qids[0])]
    emb = world.embed(q)[None]
    sims, idx = retriever.retrieve(emb, 5)
    m = scope_data.models[0]
    prompt = serialization.serialize_prompt(
        world.models[m], 0, library.anchor_set, library.get(m), sims[0],
        idx[0], q)
    gen, _ = generate(params, cfg, np.asarray([prompt], np.int32),
                      max_new_tokens=12)
    toks = list(gen[0])
    if tok.EOS in toks:
        after = toks[toks.index(tok.EOS) + 1:]
        assert all(t == tok.PAD for t in after)


def test_engine_batches_and_preserves_request_ids(tiny_trained):
    cfg, params, _ = tiny_trained
    eng = ServingEngine(params, cfg, batch_size=4, max_new_tokens=4)
    rng = np.random.default_rng(1)
    rids = [eng.submit(rng.integers(3, 100, size=20).tolist())
            for _ in range(10)]                     # 2.5 batches
    results = eng.run()
    assert sorted(results) == sorted(rids)
    for r in results.values():
        assert r.tokens.shape == (4,)


def test_estimator_outputs_are_wellformed_mostly(tiny_trained, scope_data,
                                                 library, retriever):
    from repro.core import serialization
    cfg, params, _ = tiny_trained
    world = scope_data.world
    est = ReasoningEstimator(cfg, params)
    qids = scope_data.test_qids[:6]
    queries = [scope_data.queries[int(q)] for q in qids]
    embs = np.stack([world.embed(q) for q in queries])
    sims, idx = retriever.retrieve(embs, 5)
    prompts = []
    for j, q in enumerate(queries):
        for mi, m in enumerate(scope_data.models):
            prompts.append(serialization.serialize_prompt(
                world.models[m], mi, library.anchor_set, library.get(m),
                sims[j], idx[j], q))
    preds = est.predict(prompts)
    wf = np.mean([p.well_formed for p in preds])
    assert wf > 0.8
    for p in preds:
        assert 0.0 <= p.p_conf <= 1.0
        assert p.pred_tokens <= 12
