"""scopelint (repro.analysis): rule corpus self-test, suppression parsing,
the jaxpr poison checks, and the kwonly-static regression that keeps the
Pallas kernels' partial-bound knobs from false-positiving."""
import pathlib
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import all_rules, scan_paths, scan_source
from repro.analysis.astpass import ModuleContext
from repro.analysis.jaxpr_pass import check_closed_jaxpr, run_jaxpr_pass
from repro.analysis.manifest import is_hot_path
from repro.analysis.selftest import run_self_test
from repro.analysis.suppress import MISSING_REASON, UNUSED, Suppressions

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# Rule corpus: every rule fires on its triggers, stays silent on its twins
# ---------------------------------------------------------------------------
def test_self_test_corpus_is_green():
    assert run_self_test() == []


def test_every_rule_ships_a_corpus():
    for rule in all_rules():
        assert rule.triggers, f"{rule.id} has no trigger corpus"
        assert rule.non_triggers, f"{rule.id} has no non-trigger corpus"


def test_rule_ids_are_the_documented_five():
    assert sorted(r.id for r in all_rules()) == [
        "host-sync-in-hot-path", "pallas-kernel-contract",
        "recompile-hazard", "serve-time-nondeterminism",
        "traced-body-side-effect"]


# ---------------------------------------------------------------------------
# Suppression comments
# ---------------------------------------------------------------------------
def test_inline_suppression_absorbs_finding_and_keeps_reason():
    src = textwrap.dedent("""\
        import jax


        @jax.jit
        def f(x):
            return float(x)  # scopelint: allow[host-sync-in-hot-path] -- ok
        """)
    out = scan_source(src, "repro/serving/x.py", hot_path=True)
    assert out and all(f.suppressed for f in out)
    assert out[0].suppress_reason == "ok"
    # the same module without the waiver must fail
    raw = scan_source(src.replace(
        "  # scopelint: allow[host-sync-in-hot-path] -- ok", ""),
        "repro/serving/x.py", hot_path=True)
    assert any(not f.suppressed for f in raw)


def test_standalone_suppression_targets_next_line_and_star_matches():
    sup = Suppressions.parse(
        "# scopelint: allow[*] -- blanket\n"
        "x = 1\n")
    assert sup.match("any-rule-at-all", 2) is not None
    assert sup.match("another", 1) is None  # the comment's own line


def test_suppression_without_reason_is_itself_a_finding():
    sup = Suppressions.parse("x = 1  # scopelint: allow[recompile-hazard]\n")
    sup.match("recompile-hazard", 1)
    metas = sup.meta_findings("p.py")
    assert [m.rule for m in metas] == [MISSING_REASON]


def test_unused_suppression_is_itself_a_finding():
    sup = Suppressions.parse("x = 1  # scopelint: allow[recompile-hazard] -- r\n")
    metas = sup.meta_findings("p.py")
    assert [m.rule for m in metas] == [UNUSED]


def test_meta_findings_cannot_be_suppressed():
    sup = Suppressions.parse(
        "x = 1  # scopelint: allow[unused-suppression] -- nice try\n")
    assert sup.match(UNUSED, 1) is None
    assert sup.match(MISSING_REASON, 1) is None


def test_docstring_mention_of_syntax_is_not_a_waiver():
    src = '"""Docs: use # scopelint: allow[rule] -- reason to waive."""\n'
    sup = Suppressions.parse(src)
    assert sup.match("rule", 1) is None
    assert sup.meta_findings("p.py") == []


# ---------------------------------------------------------------------------
# Hot-path manifest
# ---------------------------------------------------------------------------
def test_hot_path_manifest():
    assert is_hot_path("src/repro/serving/sampler.py")
    assert is_hot_path("src/repro/kernels/decode_attention.py")
    assert is_hot_path("src/repro/api/engine.py")
    assert not is_hot_path("src/repro/api/cache.py")
    assert not is_hot_path("src/repro/training/grpo.py")
    assert not is_hot_path("tests/test_runtime.py")


# ---------------------------------------------------------------------------
# Kwonly-static regression: partial-bound kernel knobs are not traced
# ---------------------------------------------------------------------------
_KWONLY_KERNEL = textwrap.dedent("""\
    import functools

    import jax
    import jax.experimental.pallas as pl


    def _kernel(x_ref, o_ref, *, softcap):
        if softcap > 0.0:
            o_ref[...] = x_ref[...] / softcap
        else:
            o_ref[...] = x_ref[...]


    def run(x, softcap):
        kern = functools.partial(_kernel, softcap=float(softcap))
        return pl.pallas_call(
            kern, grid=(1,),
            in_specs=[pl.BlockSpec(x.shape, lambda i: (0, 0))],
            out_specs=pl.BlockSpec(x.shape, lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)
    """)


def test_kwonly_kernel_param_is_static_not_traced():
    """`softcap` is bound via functools.partial before pallas_call, so the
    branch on it resolves at trace time — recompile-hazard must stay silent
    (this was a 6-site false positive on the real decode kernels)."""
    ctx = ModuleContext(_KWONLY_KERNEL, "repro/kernels/k.py", hot_path=True)
    from repro.analysis.rules_recompile import RecompileHazardRule
    assert list(RecompileHazardRule().check(ctx)) == []


def test_positional_kernel_param_branch_is_flagged():
    src = _KWONLY_KERNEL.replace(
        "def _kernel(x_ref, o_ref, *, softcap):",
        "def _kernel(x_ref, o_ref, softcap_ref):").replace(
        "if softcap > 0.0:", "if softcap_ref[0] > 0.0:").replace(
        "kern = functools.partial(_kernel, softcap=float(softcap))",
        "kern = functools.partial(_kernel)")
    ctx = ModuleContext(src, "repro/kernels/k.py", hot_path=True)
    from repro.analysis.rules_recompile import RecompileHazardRule
    hits = list(RecompileHazardRule().check(ctx))
    assert hits and hits[0].rule == "recompile-hazard"


# ---------------------------------------------------------------------------
# jaxpr pass
# ---------------------------------------------------------------------------
def test_jaxpr_pass_flags_poisoned_toy_jit():
    x = jax.ShapeDtypeStruct((4,), jnp.float32)

    def poisoned(v):
        y = jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct(v.shape, v.dtype), v)
        return y.astype(jnp.float64)

    with jax.experimental.enable_x64():
        bad = jax.make_jaxpr(poisoned)(x)
    msgs = " ".join(f.message for f in check_closed_jaxpr("bad", bad))
    assert "pure_callback" in msgs and "float64" in msgs


def test_jaxpr_pass_passes_clean_toy_jit():
    x = jax.ShapeDtypeStruct((8,), jnp.float32)
    clean = jax.make_jaxpr(
        lambda v: jax.lax.scan(lambda c, t: (c + t, c), 0.0, v))(x)
    assert check_closed_jaxpr("clean", clean) == []


def test_jaxpr_pass_callback_inside_scan_body_is_found():
    """The walker must recurse into sub-jaxprs (scan bodies), where a
    callback would serialise every decode step."""
    x = jax.ShapeDtypeStruct((4,), jnp.float32)

    def body(c, t):
        t = jax.pure_callback(np.sin, jax.ShapeDtypeStruct((), t.dtype), t)
        return c + t, c

    bad = jax.make_jaxpr(lambda v: jax.lax.scan(body, 0.0, v))(x)
    msgs = " ".join(f.message for f in check_closed_jaxpr("scan", bad))
    assert "pure_callback" in msgs


def test_registered_hot_path_executables_are_clean():
    """Acceptance: fused decode, paged segment scan (both kernels) and the
    fused refills trace with abstract inputs and contain no host callbacks,
    f64 promotions, or staged host transfers."""
    findings = run_jaxpr_pass()
    assert findings == [], [f.render() for f in findings]


# ---------------------------------------------------------------------------
# The repo itself is clean (AST layer; the jaxpr layer is the test above)
# ---------------------------------------------------------------------------
def test_src_tree_has_no_unsuppressed_findings():
    findings = scan_paths([str(REPO / "src")])
    hard = [f for f in findings if not f.suppressed]
    assert hard == [], [f.render() for f in hard]
