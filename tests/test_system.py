"""System-level behaviour: registry, plans, shapes matrix, data pipeline."""
import numpy as np
import pytest

from repro.configs import (
    ASSIGNED_ARCHS, INPUT_SHAPES, get_config, list_configs, shape_applicable)
from repro.configs.base import long_context_variant
from repro.data.datasets import build_scope_data, ood_queries, stratified_anchors
from repro.data.pipeline import batches, make_lm_batch
from repro.data.worldsim import DOMAIN_WEIGHTS, NUM_DOMAINS, World


def test_registry_contains_all_assigned():
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        assert cfg.name == a and cfg.source


def test_assigned_matrix_skips_match_design_doc():
    long_ok = {a for a in ASSIGNED_ARCHS
               if shape_applicable(get_config(a), INPUT_SHAPES["long_500k"])[0]}
    assert long_ok == {"zamba2-7b", "gemma2-9b", "gemma2-2b", "mamba2-1.3b"}
    for a in ASSIGNED_ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), INPUT_SHAPES[s])[0]


def test_long_context_variant_windows_everything():
    cfg = long_context_variant(get_config("gemma2-9b"))
    assert cfg.force_window == cfg.long_context_window > 0


def test_world_heterogeneity():
    """Fig. 16/17: models must differ in accuracy and verbosity."""
    world = World(seed=0)
    qs = world.sample_queries(200, seed=1)
    accs, toks = {}, {}
    for m in world.pool:
        accs[m.name] = np.mean([world.correct_prob(m, q) for q in qs])
        toks[m.name] = np.mean([world.expected_tokens(m, q) for q in qs])
    assert max(accs.values()) - min(accs.values()) > 0.2
    assert max(toks.values()) / min(toks.values()) > 1.5
    # the premium unseen model is the strongest (Tab. 4 structure)
    assert max(accs, key=accs.get) == "claude-sonnet-4.5"


def test_anchor_set_mirrors_domain_distribution():
    world = World(seed=0)
    anchors = stratified_anchors(world, n=250, seed=7)
    counts = np.bincount([a.domain for a in anchors], minlength=NUM_DOMAINS)
    target = DOMAIN_WEIGHTS / DOMAIN_WEIGHTS.sum() * 250
    assert np.abs(counts - target).max() <= 2   # Fig. 15 alignment


def test_ood_queries_are_harder():
    world = World(seed=0)
    easy = world.sample_queries(300, seed=3)
    hard = ood_queries(world, n=300, seed=3)
    assert (np.mean([q.difficulty for q in hard])
            > np.mean([q.difficulty for q in easy]) + 0.5)


def test_scope_data_split_disjoint():
    world = World(seed=0)
    data = build_scope_data(world, n_queries=100, seed=0)
    assert set(data.train_qids).isdisjoint(set(data.test_qids))
    assert len(data.records) == 100 * len(data.models)


def test_make_lm_batch_masks_prompt():
    batch = make_lm_batch([[1, 2, 3]], [[4, 5]], max_len=8)
    labels = batch["labels"][0]
    # position 2 (last prompt token) predicts first target token (4)
    assert labels[2] == 4 and labels[3] == 5
    assert all(l == -100 for l in labels[:2]) and all(l == -100 for l in labels[4:])


def test_batches_iterator_shapes():
    data = {"x": np.arange(10)[:, None]}
    got = list(batches(data, 4, seed=0))
    assert len(got) == 2 and got[0]["x"].shape == (4, 1)
