"""Two-tier routing: tier-0 pre-router head, confidence-gated escalation
in the engine, cache tier rules, the scheduler tier ledger, and the
quarantine fallback ladder (tier-0 answer before retrieval prior)."""
import numpy as np
import pytest

from repro.api import EngineConfig, RouteRequest, ScopeEngine
from repro.api.cache import CachedPrediction, PredictionCache
from repro.core.estimator import ReasoningEstimator
from repro.core.status import STATUS_DEGRADED, STATUS_FAILED, STATUS_OK
from repro.data.datasets import build_scope_data
from repro.models import tier0 as T0
from repro.serving.faults import FaultPlan, FaultSpec
from repro.serving.scheduler import BucketConfig, MicrobatchScheduler
from repro.training.tier0 import (
    build_tier0_dataset, fit_temperature, train_tier0)


# ---------------------------------------------------------------------------
# Cache tier rules: rank = (status == OK, tier)
# ---------------------------------------------------------------------------
def _pred(tier, status=STATUS_OK, p=0.7):
    return CachedPrediction(y_hat=1, len_hat=64.0, well_formed=True,
                            p_conf=p, pred_tokens=0, prompt_tokens=49,
                            status=status, tier=tier)


def test_cache_tier1_overwrites_tier0_never_reverse():
    cache = PredictionCache()
    key = (1, "m", "v0")
    cache.put(*key, _pred(0, p=0.6))
    cache.put(*key, _pred(1, p=0.9))            # escalated decode heals
    assert cache.get(*key).tier == 1 and cache.get(*key).p_conf == 0.9
    cache.put(*key, _pred(0, p=0.1))            # tier-0 never clobbers
    assert cache.get(*key).tier == 1 and cache.get(*key).p_conf == 0.9
    cache.put(*key, _pred(1, p=0.4))            # same rank: refresh
    assert cache.get(*key).p_conf == 0.4


def test_cache_version_bump_invalidates_both_tiers():
    cache = PredictionCache()
    cache.put(1, "m", "v0", _pred(0))
    cache.put(2, "m", "v0", _pred(1))
    assert cache.get(1, "m", "v1") is None
    assert cache.get(2, "m", "v1") is None
    # the old version's entries are untouched, just unreachable by v1 keys
    assert cache.get(1, "m", "v0").tier == 0


def test_cache_degraded_interaction_with_tiers():
    cache = PredictionCache()
    key = (1, "m", "v0")
    # a tier-0 OK answer resists degraded writes of any tier
    cache.put(*key, _pred(0))
    cache.put(*key, _pred(1, status=STATUS_DEGRADED))
    assert cache.get(*key).status == STATUS_OK and cache.get(*key).tier == 0
    cache.put(*key, _pred(1, status=STATUS_FAILED))
    assert cache.get(*key).status == STATUS_OK
    # OK of either tier heals a degraded entry
    cache.put(1, "n", "v0", _pred(0, status=STATUS_DEGRADED))
    cache.put(1, "n", "v0", _pred(0, status=STATUS_OK))
    assert cache.get(1, "n", "v0").status == STATUS_OK
    cache.put(2, "n", "v0", _pred(1, status=STATUS_DEGRADED))
    cache.put(2, "n", "v0", _pred(0, status=STATUS_OK, p=0.8))
    got = cache.get(2, "n", "v0")
    assert got.status == STATUS_OK and got.tier == 0 and got.p_conf == 0.8
    # among degraded entries, a tier-1 (prior) entry resists a tier-0 one
    cache.put(3, "n", "v0", _pred(1, status=STATUS_DEGRADED, p=0.3))
    cache.put(3, "n", "v0", _pred(0, status=STATUS_DEGRADED, p=0.2))
    assert cache.get(3, "n", "v0").p_conf == 0.3


def test_cache_default_tier_is_one_and_legacy_rule_preserved():
    """Entries written without an explicit tier behave exactly like PR 7:
    OK overwrites anything, non-OK never clobbers OK."""
    cache = PredictionCache()
    key = (9, "m", "v0")
    cache.put(*key, CachedPrediction(1, 8.0, True, 0.9, 5, 49,
                                     status=STATUS_DEGRADED))
    cache.put(*key, CachedPrediction(0, 9.0, True, 0.6, 5, 49))
    assert cache.get(*key).status == STATUS_OK
    cache.put(*key, CachedPrediction(1, 8.0, True, 0.9, 5, 49,
                                     status=STATUS_DEGRADED))
    assert cache.get(*key).status == STATUS_OK and cache.get(*key).tier == 1


# ---------------------------------------------------------------------------
# Head units: shapes, determinism, bucket padding, compile counts
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def head():
    import jax
    return T0.Tier0Head(T0.init_tier0(jax.random.PRNGKey(3)))


def _rand_pairs(n, k=5, seed=0):
    r = np.random.default_rng(seed)
    return (r.normal(size=(n, T0.QUERY_FEATS)).astype(np.float32),
            r.normal(size=(n, k, T0.ANCHOR_FEATS)).astype(np.float32),
            r.normal(size=(n, T0.MODEL_FEATS)).astype(np.float32),
            r.integers(0, T0.N_MODEL_SLOTS, size=n).astype(np.int32))


def test_pair_bucket_grid():
    assert T0.pair_bucket(1) == T0.PAIR_BUCKETS[0]
    assert T0.pair_bucket(16) == 16
    assert T0.pair_bucket(17) == 64
    top = T0.PAIR_BUCKETS[-1]
    assert T0.pair_bucket(top + 1) == 2 * top


def test_head_deterministic_and_pad_invariant(head):
    qf, af, mf, mid = _rand_pairs(7)
    a = head.predict_pairs(qf, af, mf, mid)
    b = head.predict_pairs(qf, af, mf, mid)
    np.testing.assert_array_equal(a.p, b.p)
    assert len(a) == 7
    assert (a.conf >= 0.5).all() and (a.conf <= 1.0).all()
    np.testing.assert_array_equal(a.y_hat, (a.p >= 0.5).astype(int))
    # the same rows padded into a larger batch produce identical rows
    qf2, af2, mf2, mid2 = _rand_pairs(40, seed=1)
    qf2[:7], af2[:7], mf2[:7], mid2[:7] = qf, af, mf, mid
    c = head.predict_pairs(qf2, af2, mf2, mid2)
    np.testing.assert_allclose(a.p, c.p[:7], rtol=0, atol=0)


def test_head_one_compile_per_bucket(head):
    before = int(T0.COMPILE_COUNTS["tier0"])
    for n in (3, 9, 14):                    # all pad to bucket 16
        head.predict_pairs(*_rand_pairs(n, seed=n))
    mid_count = int(T0.COMPILE_COUNTS["tier0"])
    assert mid_count - before <= 1          # 16-bucket may be warm already
    for n in (3, 9, 14):
        head.predict_pairs(*_rand_pairs(n, seed=100 + n))
    assert int(T0.COMPILE_COUNTS["tier0"]) == mid_count


def test_head_empty_batch_and_temperature_validation(head):
    out = head.predict_pairs(np.zeros((0, T0.QUERY_FEATS), np.float32),
                             np.zeros((0, 5, T0.ANCHOR_FEATS), np.float32),
                             np.zeros((0, T0.MODEL_FEATS), np.float32),
                             np.zeros(0, np.int32))
    assert len(out) == 0
    with pytest.raises(ValueError, match="temperature"):
        head.with_temperature(0.0)
    # temperature flattens the calibrated probability toward chance
    qf, af, mf, mid = _rand_pairs(8, seed=5)
    sharp = head.predict_pairs(qf, af, mf, mid)
    flat = head.with_temperature(50.0).predict_pairs(qf, af, mf, mid)
    assert (flat.conf <= sharp.conf + 1e-12).all()
    np.testing.assert_array_equal(flat.y_hat, sharp.y_hat)  # sign-preserving


def test_pair_features_shapes_and_unseen_slot(world, library, scope_data):
    m_seen = next(m for m in world.pool if m.seen)
    q = scope_data.queries[0]
    sims = np.array([0.9, 0.5, 0.3, 0.2, 0.1])
    idx = np.arange(5)
    qf, af, mf, mid = T0.pair_features(
        m_seen, 2, library.anchor_set, library.get(m_seen.name),
        sims, idx, q)
    assert qf.shape == (T0.QUERY_FEATS,) and af.shape == (5, T0.ANCHOR_FEATS)
    assert mf.shape == (T0.MODEL_FEATS,) and 0 <= mid < T0.N_MODEL_SLOTS - 1
    import dataclasses
    unseen = dataclasses.replace(m_seen, seen=False)
    _, _, _, mid_u = T0.pair_features(
        unseen, 2, library.anchor_set, library.get(m_seen.name),
        sims, idx, q)
    assert mid_u == T0.N_MODEL_SLOTS - 1    # shared UNK slot


def test_fit_temperature_recovers_scale():
    r = np.random.default_rng(0)
    logit = r.normal(scale=4.0, size=4000)
    q = 1.0 / (1.0 + np.exp(-logit / 2.0))  # true temperature 2.0
    t = fit_temperature(logit, q)
    assert 1.5 < t < 2.7
    assert fit_temperature(np.zeros(0), np.zeros(0)) == 1.0


# ---------------------------------------------------------------------------
# Distillation + engine integration (shared trained setup)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tier0_setup(tiny_trained, world, retriever, library):
    cfg, params, _ = tiny_trained
    data = build_scope_data(world, n_queries=160, seed=9)
    est = ReasoningEstimator(cfg, params, max_new_tokens=6)
    ds = build_tier0_dataset(data, library, retriever, est,
                             max_pairs=240, seed=0)
    head, report = train_tier0(ds, steps=60, batch_size=128, seed=0)

    def mk(tier0=None, threshold=0.9, **kw):
        return ScopeEngine.build(EngineConfig(
            estimator=ReasoningEstimator(cfg, params, max_new_tokens=6),
            retriever=retriever, library=library,
            models_meta={m: world.models[m] for m in data.models},
            tier0=tier0, escalation_threshold=threshold, **kw))
    return mk, data, head, report


def test_distillation_trains_and_calibrates(tier0_setup):
    _, _, head, report = tier0_setup
    assert np.isfinite(report.losses).all()
    assert np.mean(report.losses[-10:]) < np.mean(report.losses[:10])
    assert report.temperature > 0.0 and report.n_val > 0
    assert head.temperature == report.temperature


def _pool_fields(pool):
    return {f: getattr(pool, f) for f in
            ("p_hat", "y_hat", "len_hat", "cost_hat", "well_formed",
             "pred_overhead", "sims", "idx")}


def test_threshold_above_one_is_bit_identical_to_no_tier0(tier0_setup):
    """100% escalation: same decisions, same cache contents, same stats —
    the gate runs but answers nothing, so the decode path sees exactly
    the traffic it would without a head."""
    mk, data, head, _ = tier0_setup
    queries = [data.queries[int(q)] for q in data.test_qids[:6]]
    ref_eng = mk(tier0=None)
    got_eng = mk(tier0=head, threshold=2.0)
    ref = ref_eng.predict(RouteRequest(queries))
    got = got_eng.predict(RouteRequest(queries))
    for f, v in _pool_fields(ref).items():
        np.testing.assert_array_equal(getattr(got, f), v, err_msg=f)
    assert got.cache_hits == ref.cache_hits
    assert got.cache_misses == ref.cache_misses
    assert got.tier0_answered == 0
    assert got.escalated == got.cache_misses > 0
    assert got_eng.cache._store == ref_eng.cache._store  # incl. tiers


def test_threshold_zero_answers_everything_no_scheduler_entry(tier0_setup):
    """0% escalation: every missing pair is answered by the head — nothing
    is ever submitted to the scheduler, so nothing can reach the in-flight
    dedup map (the leak class PR 7 fixed for dispatch faults)."""
    mk, data, head, _ = tier0_setup
    engine = mk(tier0=head, threshold=0.0)
    queries = [data.queries[int(q)] for q in data.test_qids[:6]]
    reqs = [RouteRequest(queries[:3]), RouteRequest(queries[3:])]
    sched = MicrobatchScheduler(BucketConfig(batch_sizes=(1, 2, 4, 8)))
    pools = list(engine.predict_stream(iter(reqs), scheduler=sched))
    st = sched.stats
    n_pairs = 6 * len(data.models)
    assert st.submitted == 0 and st.emitted == 0 and st.microbatches == 0
    assert st.tier0_answered == n_pairs and st.escalated == 0
    assert st.escalation_rate == 0.0
    assert st.tier0_decode_tokens_saved == n_pairs * 6
    for pool in pools:
        assert (pool.status == STATUS_OK).all()
        assert pool.well_formed.all()
        assert (pool.pred_overhead == 0).all()      # no decode tokens
        assert ((pool.p_hat >= 0.0) & (pool.p_hat <= 1.0)).all()
    # every cache entry written by the gate carries tier 0
    assert len(engine.cache) == n_pairs
    assert all(e.tier == 0 and e.status == STATUS_OK
               for e in engine.cache._store.values())
    d = st.as_dict()["tiers"]
    assert d["tier0_answered"] == n_pairs and d["escalation_rate"] == 0.0


def test_partial_threshold_splits_traffic_exactly(tier0_setup):
    """A mid-sweep threshold: answered + escalated == all missing pairs,
    and only the escalated ones are submitted to the scheduler."""
    mk, data, head, _ = tier0_setup
    queries = [data.queries[int(q)] for q in data.test_qids[:6]]
    n_pairs = 6 * len(data.models)
    # pick a threshold at the median confidence so both sides are non-empty
    probe = mk(tier0=head, threshold=0.0)       # head answers everything:
    pool = probe.predict(RouteRequest(queries), use_cache=False)
    conf = np.maximum(pool.p_hat, 1.0 - pool.p_hat)  # p_hat is the head's p
    engine = mk(tier0=head, threshold=float(np.median(conf)))
    sched = MicrobatchScheduler(BucketConfig(batch_sizes=(1, 2, 4, 8)))
    pools = list(engine.predict_stream(
        iter([RouteRequest(queries)]), scheduler=sched))
    st = sched.stats
    assert st.tier0_answered + st.escalated == n_pairs
    assert st.tier0_answered > 0 and st.escalated > 0
    assert st.submitted == st.escalated
    assert pools[0].tier0_answered == st.tier0_answered
    tiers = {e.tier for e in engine.cache._store.values()}
    assert tiers == {0, 1}
    assert 0.0 < st.escalation_rate < 1.0


def test_quarantined_escalation_falls_back_to_tier0_answer(tier0_setup):
    """An escalated pair whose decode quarantines is answered from its
    stashed tier-0 row — the head's calibrated estimate, not the
    retrieval prior — as DEGRADED with zero decode overhead."""
    mk, data, head, _ = tier0_setup
    queries = [data.queries[int(q)] for q in data.test_qids[:4]]
    # reference: what the head alone says for every pair
    t0_pool = mk(tier0=head, threshold=0.0).predict(
        RouteRequest(queries), use_cache=False)
    engine = mk(tier0=head, threshold=2.0, max_retries=0,
                fault_plan=FaultPlan([FaultSpec("dispatch", 0)]))
    sched = MicrobatchScheduler(BucketConfig(batch_sizes=(1, 2, 4, 8)))
    pools = list(engine.predict_stream(
        iter([RouteRequest(queries)]), scheduler=sched, use_cache=False))
    st = sched.stats
    assert st.quarantined > 0
    assert st.tier0_fallbacks == st.quarantined == st.degraded
    status = pools[0].status
    deg = status == STATUS_DEGRADED
    assert int(deg.sum()) == st.quarantined
    np.testing.assert_allclose(pools[0].p_hat[deg], t0_pool.p_hat[deg],
                               rtol=0, atol=0)
    np.testing.assert_array_equal(pools[0].len_hat[deg],
                                  t0_pool.len_hat[deg])
    assert pools[0].well_formed[deg].all()
    assert (pools[0].pred_overhead[deg] == 0).all()
    # degradation ledger stays balanced (PR 7 invariant)
    assert st.degraded + st.failed_pairs == \
        st.quarantined + st.deadline_expired


def test_degrade_cache_entry_from_tier0_is_tier0_and_healable(tier0_setup):
    """With the cache on, a quarantined escalation writes a DEGRADED
    tier-0 entry; a later real decode (OK tier-1) heals it."""
    mk, data, head, _ = tier0_setup
    queries = [data.queries[int(q)] for q in data.test_qids[:2]]
    engine = mk(tier0=head, threshold=2.0, max_retries=0,
                fault_plan=FaultPlan([FaultSpec("dispatch", 0)]))
    sched = MicrobatchScheduler(BucketConfig(batch_sizes=(1, 2, 4, 8)))
    list(engine.predict_stream(iter([RouteRequest(queries)]),
                               scheduler=sched))
    assert sched.stats.tier0_fallbacks > 0
    deg_entries = {k: e for k, e in engine.cache._store.items()
                   if e.status == STATUS_DEGRADED}
    assert deg_entries and all(e.tier == 0 for e in deg_entries.values())
    # clean second pass over the same queries: misses are the degraded
    # keys only... none (DEGRADED entries are hits).  Force the heal by
    # writing through put_many as _stream_fill would.
    key = next(iter(deg_entries))
    engine.cache.put_many([key], [CachedPrediction(
        1, 12.0, True, 0.8, 6, 49, status=STATUS_OK, tier=1)])
    healed = engine.cache._store[key]
    assert healed.status == STATUS_OK and healed.tier == 1


def test_stale_tier0_stash_refused_after_hot_swap(tier0_setup):
    """Regression: ``degrade()`` must refuse a tier-0 fallback row stashed
    under a pre-swap estimator version — the old head's calibration
    belongs to the old params — and fall to the retrieval-prior rung
    (still answered DEGRADED exactly once, just without the stash)."""
    from repro.api.engine import _StreamControl, _StreamEntry
    mk, data, head, _ = tier0_setup
    queries = [data.queries[int(q)] for q in data.test_qids[:1]]

    def degrade_one(engine, *, swap):
        st = engine._prepare(RouteRequest(queries), use_cache=False)
        assert st.t0_rows      # threshold 2.0: every pair escalates, stashed
        entry = _StreamEntry(st)
        sched = MicrobatchScheduler(BucketConfig(batch_sizes=(1, 2, 4, 8)))
        inflight = {}
        control = _StreamControl(engine, sched, inflight, use_cache=False)
        engine._submit_misses(st, entry, sched, inflight, False, 0, control)
        key = next(iter(control.t0_rows))
        assert control.t0_rows[key][0] == "v0"      # stamped at submit time
        if swap:
            engine.hot_swap(engine.estimator, "v0+swap")
        control.degrade(key)
        assert entry.remaining == len(st.prompts) - 1   # exactly one filled
        assert entry.status[0] == STATUS_DEGRADED
        return sched.stats

    # matching version: the stash answers on the tier-0 fallback rung
    stats = degrade_one(mk(tier0=head, threshold=2.0), swap=False)
    assert stats.degraded == 1 and stats.tier0_fallbacks == 1
    # post-swap: the stale stash is refused, the retrieval prior answers
    stats = degrade_one(mk(tier0=head, threshold=2.0), swap=True)
    assert stats.degraded == 1 and stats.tier0_fallbacks == 0


# ---------------------------------------------------------------------------
# Static enforcement + ledger surfacing
# ---------------------------------------------------------------------------
def test_tier0_registered_as_hot_path_executable():
    from repro.analysis.jaxpr_pass import registered
    from repro.analysis.manifest import is_hot_path
    assert "tier0_forward" in registered()
    assert is_hot_path("src/repro/models/tier0.py")


def test_tier0_compile_counter_surfaced():
    from repro.serving.scheduler import decode_compile_counts
    counts = decode_compile_counts()
    assert "tier0" in counts and counts["tier0"] >= 0
